"""`repro.tune` (PR 9): SweepSpec JSON round-trip + fingerprint stability,
StopRules vs hand-built traces, journal resume, the `on_eval` stop hook
halting FLRun with a well-formed History, `final_eval` correctness (the
pre-fix stale-`hist.acc[-1]` read), paired client/delay streams across
strategies, and the hillclimb promotion ladder."""
import dataclasses
import json
import math
import os
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PersAFLConfig
from repro.data.federated import ClientData
from repro.fl import DelayModel, FLRun, buffered, immediate
from repro.fl.api import _normalize_eval
from repro.fl.scenario import ScenarioSpec, Tier
from repro.tune import (AccPlateau, AnyOf, Arm, LossSpike, MedianLoss,
                        SweepSpec, Trial, TuneRunner, default_rules,
                        make_report, parse_schedule, promote,
                        promote_winners, rule_from_dict, rule_to_dict,
                        rung_arms, to_markdown, trial_key)


# ---------------------------------------------------------------------------
# tiny problem (mirrors tests/test_api.py)
# ---------------------------------------------------------------------------

def _loss(p, b):
    logits = b["images"] @ p["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 4) * logp, -1))


def _clients(n=6, d=5, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        x = rng.randn(64, d).astype(np.float32)
        y = rng.randint(0, 4, 64).astype(np.int32)
        out.append(ClientData(train_x=x, train_y=y, test_x=x[:8],
                              test_y=y[:8], classes=(0, 1, 2, 3)))
    return out


def _params(d=5):
    return {"w": jnp.zeros((d, 4))}


def _pcfg(**kw):
    base = dict(option="A", q_local=2, eta=0.05, alpha=0.05, lam=20.0,
                inner_steps=3, inner_eta=0.02)
    base.update(kw)
    return PersAFLConfig(**base)


def _eval_fn(clients):
    """Mean test accuracy + loss over clients' test sets (dict return —
    the History records both series)."""
    test = [{"images": c.test_x, "labels": c.test_y} for c in clients]

    def ev(params):
        accs, losses = [], []
        for b in test:
            logits = np.asarray(b["images"] @ np.asarray(params["w"]))
            accs.append(float(np.mean(np.argmax(logits, -1) == b["labels"])))
            losses.append(float(_loss(params, b)))
        return {"acc": float(np.mean(accs)), "loss": float(np.mean(losses))}
    return ev


def _problem_factory(clients=None, **over):
    clients = clients or _clients()
    prob = {"clients": clients, "loss_fn": _loss, "init_params": _params(),
            "eval_fn": _eval_fn(clients), "pcfg": _pcfg(),
            "batch_size": 8, "eval_every": 2}
    prob.update(over)
    return lambda arm: prob


def _arm(**kw):
    base = dict(strategy="persafl", strategy_kwargs={"option": "A"},
                schedule="immediate", seed=0, max_rounds=6, group="g")
    base.update(kw)
    return Arm(**base)


# ---------------------------------------------------------------------------
# SweepSpec / Arm: JSON round-trip + fingerprint stability
# ---------------------------------------------------------------------------

def test_sweepspec_json_roundtrip():
    spec = SweepSpec(
        strategies=({"name": "persafl", "option": "B"},
                    {"name": "fedprox", "mu": 0.3}),
        schedules=("immediate", "buffered(8)"),
        pcfg={"eta": 0.01}, pcfg_grid={"q_local": (2, 4)},
        scenario=ScenarioSpec(n_clients=6, seed=3,
                              tiers=(Tier("fast", 0.5, 0.7),
                                     Tier("slow", 0.5, 1.6))),
        seeds=(0, 1), group="mnist")
    back = SweepSpec.from_json(spec.to_json())
    assert back == spec
    # expansion is the full product, deterministic order
    arms = spec.arms(max_rounds=10, budget=50.0)
    assert len(arms) == 2 * 2 * 2 * 2
    assert arms == spec.arms(max_rounds=10, budget=50.0)


def test_arm_fingerprint_stability_and_sensitivity():
    a = _arm(budget=100.0)
    assert a.fingerprint() == _arm(budget=100.0).fingerprint()
    assert a.fingerprint() == Arm.from_dict(a.to_dict()).fingerprint()
    # every config field moves the fingerprint
    for variant in (_arm(budget=200.0), _arm(seed=1),
                    _arm(schedule="buffered(4)"),
                    _arm(strategy_kwargs={"option": "B"}),
                    _arm(pcfg={"eta": 0.01})):
        assert variant.fingerprint() != a.fingerprint()
    # stop-rule hash extends the key: exhaustive != self-stopped trial
    assert trial_key(a, None) != trial_key(a, default_rules())
    assert trial_key(a, default_rules()) == trial_key(a, default_rules())


def test_sweepspec_validation():
    with pytest.raises(ValueError):
        SweepSpec(strategies=())
    with pytest.raises(ValueError):
        SweepSpec(strategies=({"option": "B"},))        # no name
    with pytest.raises(ValueError, match="unknown strategy"):
        _arm(strategy="fedsgd-of-theseus")
    with pytest.raises(ValueError):
        _arm(schedule="eventually(8)")


def test_parse_schedule_spellings():
    assert type(parse_schedule("immediate")).__name__ == "Immediate"
    b = parse_schedule("buffered(8)")
    assert b.m == 8 and b.robust is None
    b = parse_schedule("buffered(4, robust=clip)")
    assert b.m == 4 and b.robust == "clip"
    b = parse_schedule("buffered(8, robust=trim, trim_frac=0.2)")
    assert b.robust == "trim" and b.trim_frac == 0.2
    assert parse_schedule("sync(10)").m == 10
    # fresh instance per call: policies hold per-run state
    assert parse_schedule("buffered(8)") is not parse_schedule("buffered(8)")


# ---------------------------------------------------------------------------
# stop rules vs hand-built traces
# ---------------------------------------------------------------------------

def _trace(loss=(), acc=()):
    return SimpleNamespace(loss=list(loss), acc=list(acc))


def test_loss_spike_stops_divergence():
    rule = LossSpike(factor=3.0)
    assert rule.check(_trace(loss=[1.0, 0.9, 0.8])) is None
    assert "loss_spike" in rule.check(_trace(loss=[1.0, 0.9, 3.1]))
    assert "non-finite" in rule.check(_trace(loss=[1.0, float("nan")]))
    assert "non-finite" in rule.check(_trace(loss=[1.0, float("inf")]))


def test_median_loss_stops_creep_not_noise():
    rule = MedianLoss(window=4, factor=1.3, warmup=3)
    # steady decline never fires
    assert rule.check(_trace(loss=[1.0, 0.8, 0.7, 0.65, 0.6])) is None
    # creeping back above the running median fires
    assert rule.check(_trace(loss=[1.0, 0.5, 0.5, 0.5, 0.9])) is not None
    # within warmup: silent even on bad losses
    assert rule.check(_trace(loss=[0.5, 2.0])) is None


def test_acc_plateau_patience():
    rule = AccPlateau(patience=3, min_delta=0.01)
    # monotone improver with real slope never stops, at any prefix
    ramp = [0.1 + 0.05 * i for i in range(12)]
    for k in range(1, len(ramp) + 1):
        assert rule.check(_trace(acc=ramp[:k])) is None
    # flat tail fires once patience is exhausted
    flat = [0.1, 0.3, 0.5, 0.501, 0.502, 0.5]
    assert rule.check(_trace(acc=flat)) is not None


def test_monotone_improver_survives_default_bundle():
    rules = default_rules()
    loss = [2.0 / (1 + 0.3 * i) for i in range(20)]
    acc = [0.1 + 0.04 * i for i in range(20)]
    for k in range(1, 21):
        assert rules.check(_trace(loss=loss[:k], acc=acc[:k])) is None


def test_stop_rule_serialization_roundtrip():
    bundle = AnyOf((LossSpike(factor=2.5), MedianLoss(window=5),
                    AccPlateau(patience=4, min_delta=0.01)))
    back = rule_from_dict(json.loads(json.dumps(rule_to_dict(bundle))))
    assert back == bundle
    assert rule_from_dict(None) is None and rule_to_dict(None) is None
    with pytest.raises(ValueError, match="unknown stop rule"):
        rule_from_dict({"kind": "vibes"})


def test_normalize_eval_spellings():
    assert _normalize_eval(0.5) == (0.5, None)
    assert _normalize_eval((0.5, 1.25)) == (0.5, 1.25)
    assert _normalize_eval({"acc": 0.5}) == (0.5, None)
    assert _normalize_eval({"acc": 0.5, "loss": 1.25}) == (0.5, 1.25)
    with pytest.raises(ValueError):
        _normalize_eval((1.0, 2.0, 3.0))


# ---------------------------------------------------------------------------
# on_eval stop hook + final_eval (FLRun integration)
# ---------------------------------------------------------------------------

def test_on_eval_stop_halts_flrun_with_wellformed_history():
    clients = _clients()
    run = FLRun(clients=clients, loss_fn=_loss, init_params=_params(),
                pcfg=_pcfg(), delays=DelayModel(len(clients), seed=1),
                schedule=immediate(), batch_size=8)
    seen = []

    def on_eval(hist):
        seen.append(len(hist.acc))
        return "stop" if len(hist.acc) >= 2 else None

    hist = run.run(max_rounds=500, eval_every=2,
                   eval_fn=_eval_fn(clients), on_eval=on_eval)
    # halted at the second eval, far short of max_rounds
    assert seen == [1, 2]
    assert hist.rounds == [2, 4]
    assert int(np.asarray(run.state.t)) == 4
    # History is well-formed: loss parallel to acc, end_time is the stop
    # time, the active grid is closed out to it and stays monotone
    assert len(hist.loss) == len(hist.acc) == 2
    assert hist.end_time == hist.times[-1] > 0
    assert hist.active_times == sorted(hist.active_times)
    assert hist.active_times[-1] <= hist.end_time


def test_on_eval_stop_halts_sync_rounds():
    clients = _clients()
    run = FLRun(clients=clients, loss_fn=_loss, init_params=_params(),
                pcfg=_pcfg(), delays=DelayModel(len(clients), seed=1),
                schedule=parse_schedule("sync(4)"), batch_size=8)
    hist = run.run(max_rounds=50, eval_every=1, eval_fn=_eval_fn(clients),
                   on_eval=lambda h: "stop")
    assert hist.rounds == [1]
    assert int(np.asarray(run.state.t)) == 1


def test_final_eval_fixes_stale_accuracy_read():
    """Regression (pre-fix failing): eval_every larger than the round
    count used to leave `hist.acc` empty — `hist.acc[-1]` reads crashed
    or, with a mid-grid max_time stop, silently reported a STALE grid
    point.  final_eval=True forces the end-time eval."""
    clients = _clients()

    def mk():
        return FLRun(clients=clients, loss_fn=_loss, init_params=_params(),
                     pcfg=_pcfg(), delays=DelayModel(len(clients), seed=1),
                     schedule=immediate(), batch_size=8)

    # eval_every > rounds: no grid eval ever fires
    hist = mk().run(max_rounds=6, eval_every=100, eval_fn=_eval_fn(clients))
    assert hist.acc == []                      # the pre-fix failure mode
    hist = mk().run(max_rounds=6, eval_every=100, eval_fn=_eval_fn(clients),
                    final_eval=True)
    assert len(hist.acc) == 1 and len(hist.loss) == 1
    assert hist.rounds == [6] and hist.times == [hist.end_time]
    # already-fresh last eval is NOT duplicated (params unchanged since)
    hist = mk().run(max_rounds=6, eval_every=2, eval_fn=_eval_fn(clients),
                    final_eval=True)
    assert hist.rounds == [2, 4, 6]


def test_history_loss_roundtrip_and_backcompat():
    clients = _clients()

    def scalar_ev(params):
        return 0.25                      # legacy scalar contract

    run = FLRun(clients=clients, loss_fn=_loss, init_params=_params(),
                pcfg=_pcfg(), delays=DelayModel(len(clients), seed=1),
                schedule=immediate(), batch_size=8)
    hist = run.run(max_rounds=4, eval_every=2, eval_fn=scalar_ev)
    assert hist.acc == [0.25, 0.25] and hist.loss == []
    d = hist.as_dict()
    assert d["loss"] == [] and d["acc"] == [0.25, 0.25]
    # dict round-trips through History(**d)
    from repro.fl import History
    assert History(**d) == hist


# ---------------------------------------------------------------------------
# paired streams: the contract the tuner's comparisons rely on
# ---------------------------------------------------------------------------

class _RecordingDelays(DelayModel):
    """DelayModel that logs every realized (kind, client, value) draw —
    the run's event timeline is a pure function of this log."""

    def __post_init__(self):
        super().__post_init__()
        self.log = []

    def sample_download(self, i, t=0.0):
        v = super().sample_download(i, t)
        self.log.append(("down", int(i), float(v)))
        return v

    def sample_upload(self, i, t=0.0):
        v = super().sample_upload(i, t)
        self.log.append(("up", int(i), float(v)))
        return v


@pytest.mark.parametrize("schedule_mk", [immediate, lambda: buffered(3)])
def test_paired_streams_bit_identical_across_strategies(schedule_mk):
    """Two FLRuns with different strategies but the same delay seed see
    bit-identical event timelines: delay draws, apply times, and staleness
    sequences all match.  This is the counter-based-stream contract that
    makes the tuner's paired grid cells comparable — a strategy must never
    perturb the event schedule."""
    clients = _clients()
    logs, timelines, staleness = [], [], []
    for strat in ("persafl", "scaffold"):       # stateless vs stateful
        delays = _RecordingDelays(len(clients), seed=7)
        run = FLRun(clients=clients, loss_fn=_loss, init_params=_params(),
                    pcfg=_pcfg(), delays=delays, strategy=strat,
                    schedule=schedule_mk(), batch_size=8, seed=0)
        hist = run.run(max_rounds=9)
        logs.append(delays.log)
        timelines.append([(w["window"], w["time"]) for w in run.window_log])
        staleness.append(hist.staleness)
    assert logs[0] == logs[1]                   # bit-identical draws
    assert timelines[0] == timelines[1]         # identical apply times
    assert staleness[0] == staleness[1]


# ---------------------------------------------------------------------------
# runner: journal resume, self-stopping, hillclimb
# ---------------------------------------------------------------------------

def test_runner_executes_and_journals(tmp_path):
    journal = str(tmp_path / "journal.jsonl")
    runner = TuneRunner(_problem_factory(), journal=journal)
    arms = [_arm(), _arm(schedule="buffered(3)", max_rounds=6)]
    trials = runner.run_sweep(arms)
    assert [t.status for t in trials] == ["completed", "completed"]
    assert all(not t.resumed for t in trials)
    assert all(len(t.acc) == len(t.loss) > 0 for t in trials)
    assert all(t.rounds >= 6 for t in trials)
    # one JSONL row per trial, loadable
    rows = [json.loads(l) for l in open(journal)]
    assert len(rows) == 2
    assert {Trial.from_dict(r).key for r in rows} == {t.key for t in trials}


def test_runner_resumes_by_fingerprint_skip(tmp_path):
    journal = str(tmp_path / "journal.jsonl")
    arms = [_arm(), _arm(seed=1)]
    first = TuneRunner(_problem_factory(), journal=journal).run_sweep(arms)

    # a fresh runner over the same journal executes NOTHING: the problem
    # factory raising proves resume never rebuilds a run
    def exploding_problem(arm):
        raise AssertionError("resumed trial must not re-execute")

    again = TuneRunner(exploding_problem, journal=journal).run_sweep(arms)
    assert all(t.resumed for t in again)
    assert [t.key for t in again] == [t.key for t in first]
    assert [t.final_acc for t in again] == [t.final_acc for t in first]
    # journal grew no new rows
    assert len(open(journal).read().splitlines()) == 2
    # a NEW arm still executes
    t3 = TuneRunner(_problem_factory(), journal=journal).run_arm(
        _arm(schedule="buffered(3)"))
    assert not t3.resumed
    assert len(open(journal).read().splitlines()) == 3


def test_runner_selfstop_kills_diverging_arm(tmp_path):
    """An arm whose pcfg diverges (huge eta) is stopped by the bundle;
    the journal row records the reason and the spent budget is less than
    the exhaustive twin's."""
    journal = str(tmp_path / "journal.jsonl")
    problem = _problem_factory(eval_every=1)
    bad = _arm(pcfg={"eta": 50.0}, max_rounds=40)
    ex = TuneRunner(problem, journal=journal).run_arm(bad)
    ss = TuneRunner(problem, journal=journal,
                    stop_rule=default_rules(warmup=1)).run_arm(bad)
    assert ex.status == "completed"
    assert ss.status == "stopped" and ss.stop_reason
    assert ss.rounds < ex.rounds
    assert ss.sim_time < ex.sim_time
    assert ss.stop_rule is not None          # serialized into the record
    # the stopped trial's trace is a prefix of the exhaustive twin's
    # (paired streams: same arm, same seed, same timeline)
    k = len(ss.acc) - 1                      # last entry is the final eval
    assert ss.times[:k] == ex.times[:k]
    np.testing.assert_allclose(ss.acc[:k], ex.acc[:k])


def test_runner_scenario_arm(tmp_path):
    spec = ScenarioSpec(n_clients=6, seed=2, dropout=0.2)
    t = TuneRunner(_problem_factory(),
                   journal=str(tmp_path / "j.jsonl")).run_arm(
        _arm(scenario=spec, max_rounds=4))
    assert t.status == "completed"
    assert t.stats["dropouts"] >= 0 and "windows" in t.stats


def test_hillclimb_promote_and_ladder(tmp_path):
    # pure promotion: top ceil(n/eta), NaN sorts last, deterministic
    arms = [_arm(seed=s) for s in range(4)]
    kept = promote(list(zip(arms, [0.1, float("nan"), 0.9, 0.5])), eta=2.0)
    assert len(kept) == 2
    assert kept[0] == arms[2] and kept[1] == arms[3]
    assert promote([(arms[0], float("nan"))]) == [arms[0]]  # never empty
    # re-budgeting re-fingerprints
    rb = rung_arms(arms[:1], 123.0)
    assert rb[0].budget == 123.0
    assert rb[0].fingerprint() != arms[0].fingerprint()

    # a 2-rung ladder over a real problem: rung sizes halve, every trial
    # journaled, and resuming the ladder re-executes nothing
    runner = TuneRunner(_problem_factory(), journal=str(tmp_path / "j.jsonl"))
    pop = [_arm(seed=s, max_rounds=200) for s in range(4)]
    rungs = runner.run_hillclimb(pop, budgets=[30.0, 60.0], eta=2.0)
    assert [len(r) for r in rungs] == [4, 2]
    assert all(t.sim_time <= b + 1e-9 for r, b in zip(rungs, [30.0, 60.0])
               for t in r)
    rungs2 = TuneRunner(_problem_factory(),
                        journal=str(tmp_path / "j.jsonl")).run_hillclimb(
        pop, budgets=[30.0, 60.0], eta=2.0)
    assert all(t.resumed for r in rungs2 for t in r)


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def test_report_and_winner_promotion(tmp_path):
    runner = TuneRunner(_problem_factory(),
                        journal=str(tmp_path / "j.jsonl"))
    trials = runner.run_sweep([
        _arm(group="d/grid"), _arm(group="d/grid", schedule="buffered(3)")])
    rep = make_report(trials)
    g = rep["groups"]["d/grid"]
    assert g["n_arms"] == 2
    accs = [r["final_acc"] for r in g["rows"]]
    assert g["winner"]["final_acc"] == max(accs)
    md = to_markdown(rep)
    assert "d/grid" in md and "winner" in md
    path = str(tmp_path / "winners.json")
    blob = promote_winners(rep, path, extra={"note": "t"})
    assert os.path.exists(path)
    assert blob["winners"]["d/grid"]["strategy"] == \
        g["winner"]["strategy"]
    assert blob["note"] == "t"
