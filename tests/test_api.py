"""Strategy/Scheduler API (PR 4): registry construction, FedProx /
SCAFFOLD cohort-path vs old sequential-path parity, the typed ServerState
pytree, and the PR-10 removal breadcrumbs for the retired simulator
shims."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PersAFLConfig, ServerState, init_server_state,
                        apply_update)
from repro.data.federated import ClientData, sample_batches
from repro.fl import (CohortEngine, DelayModel, FLRun, History, Strategy,
                      buffered, immediate, register_strategy, strategy,
                      strategy_names, sync_barrier)
from repro.fl.algorithms import fedprox_update, scaffold_update
from repro.fl.api import resolve_schedule, resolve_strategy
from repro.kernels.fused_update.ops import apply_delta_tree


def _loss(p, b):
    logits = b["images"] @ p["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 4) * logp, -1))


def _clients(n=6, d=5, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        x = rng.randn(64, d).astype(np.float32)
        y = rng.randint(0, 4, 64).astype(np.int32)
        out.append(ClientData(train_x=x, train_y=y, test_x=x[:8],
                              test_y=y[:8], classes=(0, 1, 2, 3)))
    return out


def _params(d=5):
    return {"w": jnp.zeros((d, 4))}


def _pcfg(**kw):
    base = dict(option="A", q_local=2, eta=0.05, alpha=0.05, lam=20.0,
                inner_steps=3, inner_eta=0.02)
    base.update(kw)
    return PersAFLConfig(**base)


def _leaves_equal(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def _run(strategy_spec, schedule, *, rounds=6, seed=0, pcfg=None,
         clients=None, **kw):
    clients = clients if clients is not None else _clients()
    run = FLRun(clients=clients, loss_fn=_loss, init_params=_params(),
                pcfg=pcfg or _pcfg(), delays=DelayModel(len(clients), seed=1),
                strategy=strategy_spec, schedule=schedule, batch_size=8,
                seed=seed, **kw)
    hist = run.run(max_rounds=rounds)
    return run, hist


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_has_paper_strategies():
    names = strategy_names()
    for nm in ("persafl", "fedavg", "fedasync", "perfedavg", "pfedme",
               "fedprox", "scaffold", "personalize"):
        assert nm in names


def test_registry_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown strategy"):
        strategy("fedsgd-of-theseus")
    with pytest.raises(ValueError, match="unknown schedule"):
        resolve_schedule("eventually")
    with pytest.raises(TypeError):
        resolve_strategy(42)


def test_registry_kwargs_and_option_presets():
    pcfg = _pcfg(option="C")
    s = strategy("fedprox", mu=0.3).bind(pcfg, _loss)
    assert s.mu == 0.3 and s.pcfg.option == "A"
    s = strategy("perfedavg").bind(pcfg, _loss)
    assert s.option == "B"
    s = strategy("persafl").bind(pcfg, _loss)
    assert s.option == "C"       # defaults to the bound pcfg's option
    s = strategy("persafl", option="B").bind(pcfg, _loss)
    assert s.option == "B"


def test_register_strategy_decorator_roundtrip():
    @register_strategy("_test_null")
    class NullStrategy(Strategy):
        name = "_test_null"

        def local_update(self, params, batches, cstate):
            return jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params), None, {}

    run, hist = _run("_test_null", immediate(), rounds=3)
    _leaves_equal(run.state.params, _params())  # zero deltas move nothing
    assert len(hist.staleness) == 3


# ---------------------------------------------------------------------------
# FLRun schedule surfaces (the retired simulators' behavior contracts)
# ---------------------------------------------------------------------------

def test_flrun_immediate_runs_and_stays_on_device():
    run, h = _run("persafl", immediate(), rounds=8)
    assert int(run.final_stats["server_rounds"]) == 8
    assert len(h.staleness) == 8
    for leaf in jax.tree.leaves(run.state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_flrun_buffered_runs_and_stays_on_device():
    run, h = _run("persafl", buffered(3), rounds=9)
    assert run.engine.stats["host_materializations"] == 0
    assert int(run.final_stats["server_rounds"]) >= 9
    for leaf in jax.tree.leaves(run.state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_flrun_buffered_m_defaults_to_pcfg_buffer_size():
    run, h = _run("persafl", "buffered", rounds=8,
                  pcfg=_pcfg(buffer_size=4))
    assert run.schedule.m is None and run.schedule.m_effective == 4
    assert int(run.final_stats["server_rounds"]) % 4 == 0
    # the policy re-resolves per run instead of freezing the first pcfg
    run2, _ = _run("persafl", run.schedule, rounds=6,
                   pcfg=_pcfg(buffer_size=2))
    assert run2.schedule.m_effective == 2


@pytest.mark.parametrize("algo", ["fedavg", "perfedavg", "pfedme"])
def test_flrun_sync_barrier_runs_every_registry_algo(algo):
    run, h = _run(algo, sync_barrier(3), rounds=3)
    assert int(run.final_stats["server_rounds"]) == 3
    for leaf in jax.tree.leaves(run.state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


# ---------------------------------------------------------------------------
# FedProx / SCAFFOLD: cohort path == the old sequential path
# ---------------------------------------------------------------------------

def _legacy_sequential_sync(algo, clients, *, rounds, m, mu=0.1, seed=0):
    """The pre-PR-4 SyncSimulator fedprox/scaffold path: one jitted
    sequential dispatch per client, host-side mean, apply_delta_tree."""
    pcfg = _pcfg()
    rng = np.random.RandomState(seed)
    delays = DelayModel(len(clients), seed=1)
    params = jax.tree.map(jnp.array, _params())
    n = len(clients)
    if algo == "scaffold":
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        c_global, c_clients = zeros, [zeros for _ in clients]
        jit = jax.jit(lambda p, b, cg, ci: scaffold_update(
            pcfg, _loss, p,
            jax.tree.map(lambda x: x[:pcfg.q_local], b), cg, ci))
    else:
        jit = jax.jit(lambda p, b: fedprox_update(
            pcfg, _loss, p,
            jax.tree.map(lambda x: x[:pcfg.q_local], b), mu=mu))
    for _ in range(rounds):
        sel = rng.choice(n, m, replace=False)
        batches = [sample_batches(clients[i], rng, 3 * pcfg.q_local, 8)
                   for i in sel]
        if algo == "scaffold":
            deltas, c_updates = [], []
            for i, b in zip(sel, batches):
                delta, c_new, _ = jit(params, b, c_global, c_clients[i])
                c_updates.append((i, c_new))
                deltas.append(delta)
        else:
            deltas = [jit(params, b)[0] for b in batches]
        mean = jax.tree.map(lambda *xs: sum(xs) / len(xs), *deltas)
        [delays.sample_download(int(i)) + delays.sample_upload(int(i))
         for i in sel]
        params = apply_delta_tree(params, mean, jnp.float32(pcfg.beta))
        if algo == "scaffold":
            for i, c_new in c_updates:
                old = c_clients[i]
                c_clients[i] = c_new
                c_global = jax.tree.map(
                    lambda cg, cn, co: cg + (cn - co) / n,
                    c_global, c_new, old)
    return params, (c_global if algo == "scaffold" else None)


@pytest.mark.parametrize("algo", ["fedprox", "scaffold"])
def test_cohort_path_matches_legacy_sequential(algo):
    """Acceptance pin: strategy('fedprox'/'scaffold') through the
    CohortEngine (stacked client state, deltas in the DeltaBank) matches
    the retired sequential per-client jit loop on a fixed seed."""
    clients = _clients()
    spec = strategy("fedprox", mu=0.1) if algo == "fedprox" \
        else strategy("scaffold")
    run, _ = _run(spec, sync_barrier(3), rounds=4, clients=clients)
    ref_params, ref_cg = _legacy_sequential_sync(algo, clients, rounds=4,
                                                 m=3)
    _leaves_equal(run.state.params, ref_params, rtol=1e-6, atol=1e-7)
    # deltas landed in the bank, never crossed to the host
    assert run.engine.stats["cohort_calls"] == 4
    assert run.engine.stats["host_materializations"] == 0
    if algo == "scaffold":
        _leaves_equal(run.strategy.c_global, ref_cg, rtol=1e-6, atol=1e-7)


def test_scaffold_client_state_rides_cohort_stack():
    """Stateful dispatch: client states stack over the cohort axis and the
    bank hands updated per-client states back (device gathers)."""
    clients = _clients(4)
    run, _ = _run("scaffold", sync_barrier(4), rounds=2, clients=clients)
    assert run.engine.stateful
    for cs in run._cstates:
        assert cs is not None
        assert jax.tree.structure(cs) == jax.tree.structure(_params())
    # control variates actually moved off zero
    norm = sum(float(jnp.sum(jnp.abs(leaf)))
               for cs in run._cstates for leaf in jax.tree.leaves(cs))
    assert norm > 0


def test_scaffold_runs_under_async_schedules():
    """Beyond the legacy matrix: a stateful strategy under the buffered
    async schedule (impossible pre-PR-4) — deltas stay on device."""
    run, hist = _run("scaffold", buffered(3), rounds=6)
    assert int(run.final_stats["server_rounds"]) >= 6
    assert len(hist.staleness) >= 6
    assert run.engine.stats["host_materializations"] == 0
    for leaf in jax.tree.leaves(run.state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_fedprox_mu_zero_matches_fedavg_cohort():
    """μ=0 FedProx is plain local SGD — must coincide with the fedavg
    strategy through the same engine path."""
    r1, _ = _run(strategy("fedprox", mu=0.0), sync_barrier(3), rounds=2)
    r2, _ = _run("fedavg", sync_barrier(3), rounds=2)
    _leaves_equal(r1.state.params, r2.state.params, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# run surface
# ---------------------------------------------------------------------------

def test_max_time_bounds_simulated_time():
    run_full, h_full = _run("persafl", immediate(), rounds=40)
    budget = max(h_full.active_times) / 2
    run_cut = FLRun(clients=_clients(), loss_fn=_loss,
                    init_params=_params(), pcfg=_pcfg(),
                    delays=DelayModel(6, seed=1), strategy="persafl",
                    schedule=immediate(), batch_size=8, seed=0)
    h_cut = run_cut.run(max_rounds=40, max_time=budget)
    assert int(run_cut.final_stats["server_rounds"]) \
        < int(run_full.final_stats["server_rounds"])
    assert all(t <= budget for t in h_cut.active_times)


def test_max_time_clamps_end_time_and_closes_grid():
    """Regression: the event loop used to break only after popping an
    event PAST the budget, so hist.end_time overshot max_time (handing
    equal-simulated-time comparisons extra seconds) and the active-ratio
    grid stopped short of the boundary."""
    run = FLRun(clients=_clients(), loss_fn=_loss, init_params=_params(),
                pcfg=_pcfg(), delays=DelayModel(6, seed=1),
                strategy="persafl", schedule=immediate(), batch_size=8,
                seed=0)
    budget = 23.0
    h = run.run(max_rounds=10_000, max_time=budget,
                record_active_every=1.0)
    # a dense stream guarantees events beyond the budget: the budget binds
    assert h.end_time == budget
    assert h.active_times and max(h.active_times) <= budget
    # the grid is closed out to the boundary, not left at the last event
    assert budget - max(h.active_times) < 1.0
    assert len(h.active_times) == len(h.active_ratio)


def test_run_requires_max_rounds():
    run = FLRun(clients=_clients(2), loss_fn=_loss, init_params=_params(),
                pcfg=_pcfg(), delays=DelayModel(2, seed=1))
    with pytest.raises(TypeError, match="max_rounds"):
        run.run()


def test_history_is_shared_shape_across_schedules():
    for schedule in (immediate(), buffered(2), sync_barrier(2)):
        _, hist = _run("persafl", schedule, rounds=4)
        assert isinstance(hist, History)
        assert hist.active_times and hist.active_ratio


# ---------------------------------------------------------------------------
# ServerState
# ---------------------------------------------------------------------------

def test_server_state_is_pytree_and_dict_compatible():
    state = init_server_state({"w": jnp.zeros(3)})
    assert isinstance(state, ServerState)
    # pytree: leaves in field order, tree.map preserves the type
    leaves = jax.tree.leaves(state)
    assert len(leaves) == 4
    mapped = jax.tree.map(lambda x: x + 1, state)
    assert isinstance(mapped, ServerState)
    assert int(mapped.t) == 1
    # legacy dict-style reads still work
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.zeros(3))
    assert set(state.keys()) == {"params", "t", "staleness_sum",
                                 "staleness_max"}
    # round-trips through as_dict/from_dict and replace
    assert ServerState.from_dict(state.as_dict()) == state
    assert int(state.replace(t=jnp.int32(7)).t) == 7


def test_server_state_threads_through_jitted_apply():
    state = init_server_state({"w": jnp.zeros(4)})
    delta = {"w": jnp.ones(4)}
    state = apply_update(state, delta, 1.0, 2)
    assert isinstance(state, ServerState)
    assert int(state.t) == 1 and int(state.staleness_max) == 2
    np.testing.assert_allclose(np.asarray(state.params["w"]), -1.0)


def test_old_format_checkpoint_loads_as_server_state(tmp_path):
    """Pre-PR-4 checkpoints were raw dicts — same npz layout, so they load
    straight into the typed state."""
    from repro.checkpoint import load_server_state, save_pytree
    legacy = {"params": {"w": np.arange(3.0, dtype=np.float32)},
              "t": np.int32(5), "staleness_sum": np.float32(2.0),
              "staleness_max": np.int32(1)}
    path = str(tmp_path / "old_state")
    save_pytree(path, legacy)
    back = load_server_state(path)
    assert isinstance(back, ServerState)
    assert int(back.t) == 5
    np.testing.assert_array_equal(back.params["w"], legacy["params"]["w"])


# ---------------------------------------------------------------------------
# PR-10 removals: the PR-4 shims now raise ImportError breadcrumbs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["AsyncSimulator", "BufferedAsyncSimulator",
                                  "SyncSimulator"])
def test_removed_simulator_names_raise_with_migration_spelling(name):
    import repro.fl
    import repro.fl.simulator
    # both the package re-export and the module attribute name the FLRun
    # spelling to migrate to
    with pytest.raises(ImportError, match="FLRun"):
        getattr(repro.fl, name)
    with pytest.raises(ImportError, match="removed in PR 10"):
        getattr(repro.fl.simulator, name)
    # unknown names still fail the normal way
    with pytest.raises(AttributeError):
        repro.fl.simulator.NotAThing


def test_removed_personalize_delta_fn_raises():
    import repro.serving
    import repro.serving.batcher
    with pytest.raises(ImportError, match="personalize"):
        repro.serving.personalize_delta_fn
    with pytest.raises(ImportError, match="removed in PR 10"):
        repro.serving.batcher.personalize_delta_fn


def test_engine_client_fn_override_removed():
    with pytest.raises(TypeError, match="client_fn.*removed in PR 10"):
        CohortEngine(_pcfg(), _loss,
                     client_fn=lambda p, b: jax.tree.map(
                         lambda x: jnp.zeros_like(x, jnp.float32), p))
    # with a strategy alongside it fails the same way — the kwarg is gone
    with pytest.raises(TypeError, match="client_fn"):
        CohortEngine(_pcfg(), _loss,
                     strategy=strategy("fedavg").bind(_pcfg(), _loss),
                     client_fn=lambda p, b: p)
