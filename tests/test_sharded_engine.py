"""Sharded cohort execution: ``cohort_impl="shard_map"`` must be a layout
transform, not a semantics change.

The in-process tests run on however many devices the suite sees (1 on a
stock CPU runner; 8 in the CI job that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the shard_map
path must agree with the single-device vmap path either way.  The
subprocess test forces the 8-virtual-device split regardless of the parent
environment, so the multi-device psum path can't rot on 1-device runners.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PersAFLConfig, apply_buffered_rows, init_server_state
from repro.fl import CohortEngine, DelayModel, FLRun, buffered
from repro.kernels.fused_update.ops import apply_rows_tree


def quad_loss(w, batch):
    r = batch["a"] @ w["w"] - batch["y"]
    return 0.5 * jnp.mean(r ** 2)


def _client_batches(seed, q3=6, m=8, d=5):
    rng = np.random.RandomState(seed)
    return {"a": jnp.asarray(rng.randn(q3, m, d).astype(np.float32)),
            "y": jnp.asarray(rng.randn(q3, m).astype(np.float32))}


def _pcfg(option):
    return PersAFLConfig(option=option, q_local=2, eta=0.05, alpha=0.05,
                         lam=20.0, inner_steps=5, inner_eta=0.02,
                         maml_mode="full")


@pytest.mark.parametrize("option", ["A", "B", "C"])
def test_shard_map_cohort_matches_vmap(option):
    params = {"w": jnp.arange(1.0, 6.0) * 0.1}
    batch_list = [_client_batches(seed) for seed in range(32)]
    e_ref = CohortEngine(_pcfg(option), quad_loss, cohort_impl="vmap")
    e_sh = CohortEngine(_pcfg(option), quad_loss, cohort_impl="shard_map")
    ref = list(e_ref.update_cohort(params, batch_list))
    got = list(e_sh.update_cohort(params, batch_list))
    assert len(got) == 32
    for r, g in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(r["w"]),
                                   rtol=1e-5, atol=1e-5)


def test_shard_map_mean_single_psum_matches_vmap():
    """Masked mean inside the sharded region (one psum per leaf) ==
    unsharded masked mean, non-divisible cohort (padding masked)."""
    params = {"w": jnp.arange(1.0, 6.0) * 0.1}
    batch_list = [_client_batches(seed) for seed in range(13)]
    e_ref = CohortEngine(_pcfg("A"), quad_loss, cohort_impl="vmap")
    e_sh = CohortEngine(_pcfg("A"), quad_loss, cohort_impl="shard_map")
    ref = e_ref.update_cohort_mean(params, batch_list)
    got = e_sh.update_cohort_mean(params, batch_list)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(ref["w"]),
                               rtol=1e-5, atol=1e-6)


def test_shard_map_bank_feeds_apply_rows():
    """A sharded DeltaBank is consumable by the fused stacked apply."""
    params = {"w": jnp.arange(1.0, 6.0) * 0.1}
    batch_list = [_client_batches(seed) for seed in range(8)]
    engine = CohortEngine(_pcfg("A"), quad_loss, cohort_impl="shard_map")
    bank = engine.update_cohort(params, batch_list)
    weights = np.zeros(bank.capacity, np.float32)
    weights[:8] = 1.0 / 8
    out = apply_rows_tree(params, bank.stacked, weights)
    rows = list(CohortEngine(_pcfg("A"), quad_loss,
                             cohort_impl="vmap").update_cohort(params,
                                                               batch_list))
    mean = jax.tree.map(lambda *xs: sum(xs) / len(xs), *rows)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(params["w"] - mean["w"]),
                               rtol=1e-5, atol=1e-6)


def test_shard_map_buffered_simulator_end_to_end():
    """The buffered scheduler runs unchanged on a sharded engine and still
    never materializes deltas to the host."""
    rng = np.random.RandomState(0)
    from repro.data.federated import ClientData
    clients = []
    for _ in range(8):
        x = rng.randn(64, 5).astype(np.float32)
        y = rng.randint(0, 4, 64).astype(np.int32)
        clients.append(ClientData(train_x=x, train_y=y, test_x=x[:8],
                                  test_y=y[:8], classes=(0, 1, 2, 3)))

    def loss(p, b):
        logits = b["images"] @ p["w"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 4) * logp, -1))

    params = {"w": jnp.zeros((5, 4))}
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.05, buffer_size=4)
    sim = FLRun(clients=clients, loss_fn=loss,
                init_params=params, pcfg=pcfg,
                delays=DelayModel(len(clients), seed=1),
                strategy="persafl", schedule=buffered(),
                batch_size=8, seed=0, cohort_impl="shard_map")
    sim.run(max_rounds=8)
    assert sim.engine.stats["host_materializations"] == 0
    assert int(sim.final_stats["server_rounds"]) >= 8
    for leaf in jax.tree.leaves(sim.state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_shard_map_stateful_strategy_matches_vmap():
    """PR 4: stacked client state (SCAFFOLD control variates) threads
    through the sharded cohort exactly like the vmap path — deltas AND
    updated per-client states agree."""
    from repro.fl import strategy
    params = {"w": jnp.arange(1.0, 6.0) * 0.1}
    batch_list = [_client_batches(seed) for seed in range(8)]
    pcfg = _pcfg("A")
    banks = {}
    for impl in ("vmap", "shard_map"):
        strat = strategy("scaffold").bind(pcfg, quad_loss)
        eng = CohortEngine(pcfg, quad_loss, cohort_impl=impl,
                           strategy=strat)
        cstates = [strat.dispatch_state(strat.init_client_state(params))
                   for _ in batch_list]
        banks[impl] = eng.update_cohort(params, batch_list,
                                        cstate_list=cstates)
    for i in range(8):
        np.testing.assert_allclose(
            np.asarray(banks["shard_map"].client_state(i)["w"]),
            np.asarray(banks["vmap"].client_state(i)["w"]),
            rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(banks["shard_map"][i]["w"]),
                                   np.asarray(banks["vmap"][i]["w"]),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("k", [5, 13, 17])
def test_padding_waste_matches_vmap_at_non_pow2_cohorts(k):
    """Bucket accounting parity: at non-pow2 cohort sizes the shard_map
    bucket (device-count multiple) coincides with the vmap pow2 bucket
    whenever pow2(k) ≥ n_devices, so ``padding_waste`` must match; below
    that the sharded bucket is exactly the device count."""
    params = {"w": jnp.arange(1.0, 6.0) * 0.1}
    batch_list = [_client_batches(seed) for seed in range(k)]
    e_ref = CohortEngine(_pcfg("A"), quad_loss, cohort_impl="vmap")
    e_sh = CohortEngine(_pcfg("A"), quad_loss, cohort_impl="shard_map")
    e_ref.update_cohort(params, batch_list)
    e_sh.update_cohort(params, batch_list)
    pow2 = 1 << (k - 1).bit_length()
    assert e_ref.stats["padding_waste"] == pow2 - k
    if pow2 >= e_sh._ndev:
        assert e_sh.stats["padding_waste"] == e_ref.stats["padding_waste"]
    else:
        assert e_sh.stats["padding_waste"] == e_sh._ndev - k


def test_sharded_buffered_flush_keeps_deltas_on_device():
    """A buffered flush consumed straight from a sharded bank does zero
    host materializations — and materializing a row afterwards counts."""
    params = {"w": jnp.arange(1.0, 6.0) * 0.1}
    batch_list = [_client_batches(seed) for seed in range(6)]
    engine = CohortEngine(_pcfg("A"), quad_loss, cohort_impl="shard_map")
    state = init_server_state(jax.tree.map(jnp.array, params))
    bank = engine.update_cohort(state["params"], batch_list)
    weights = np.zeros(bank.capacity, np.float32)
    weights[:6] = 0.5 / 6
    state = apply_buffered_rows(state, bank.stacked, weights, 6,
                                staleness_max=0)
    jax.block_until_ready(jax.tree.leaves(state["params"])[0])
    assert engine.stats["host_materializations"] == 0
    bank.row(0)
    assert engine.stats["host_materializations"] == 1


_SUBPROC = textwrap.dedent("""
    import jax, numpy as np, jax.numpy as jnp
    assert jax.device_count() == 8, jax.device_count()
    from repro.core import PersAFLConfig
    from repro.fl import CohortEngine

    def quad_loss(w, batch):
        r = batch["a"] @ w["w"] - batch["y"]
        return 0.5 * jnp.mean(r ** 2)

    def batches(seed):
        rng = np.random.RandomState(seed)
        return {"a": jnp.asarray(rng.randn(6, 8, 5).astype(np.float32)),
                "y": jnp.asarray(rng.randn(6, 8).astype(np.float32))}

    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.05)
    params = {"w": jnp.arange(1.0, 6.0) * 0.1}
    bl = [batches(s) for s in range(32)]
    e_sh = CohortEngine(pcfg, quad_loss, cohort_impl="shard_map")
    assert e_sh._ndev == 8
    bank = e_sh.update_cohort(params, bl)
    assert bank.capacity == 32 and bank.capacity % 8 == 0
    ref = list(CohortEngine(pcfg, quad_loss,
                            cohort_impl="vmap").update_cohort(params, bl))
    for r, g in zip(ref, bank):
        np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(r["w"]),
                                   rtol=1e-5, atol=1e-5)
    # non-pow2 cohort on a real 8-way split: device-multiple bucket ==
    # pow2 bucket, so padding accounting matches the vmap path
    e13 = CohortEngine(pcfg, quad_loss, cohort_impl="shard_map")
    e13.update_cohort(params, bl[:13])
    ev13 = CohortEngine(pcfg, quad_loss, cohort_impl="vmap")
    ev13.update_cohort(params, bl[:13])
    assert e13.stats["padding_waste"] == ev13.stats["padding_waste"] == 3
    print("SHARDED8-OK")
""")


def test_shard_map_8_virtual_devices_subprocess():
    """Force an 8-way host-device split and pin shard_map == vmap there."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SHARDED8-OK" in res.stdout
