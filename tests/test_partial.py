"""Partial-model personalization (head-only deltas end-to-end).

SubsetSpec spellings/transforms, pruned-form closure under the npz codec,
subset deltas from the personalize strategy (backbone frozen), subset
window applies (backbone bit-parity), the PersonalizationServer serving
subset heads with shrunken ring residency, transport subset negotiation,
the sharded cohort path on subset-shaped deltas, and subset-restricted
personalized evaluation.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PersAFLConfig, SubsetSpec, merge_subset
from repro.core.moreau import solve_prox
from repro.core.subset import leaf_paths, subset_like
from repro.serving import PersonalizationServer
from repro.serving.transport import (AsyncTransportClient, TransportError,
                                     TransportServer, decode_pytree,
                                     encode_pytree)


def loss(p, b):
    logits = b["x"] @ p["w"] + p["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(jax.nn.one_hot(b["y"], 4) * logp, -1))


def user_batch(seed, n=8, d=5):
    rng = np.random.RandomState(seed)
    return {"x": rng.randn(n, d).astype(np.float32),
            "y": rng.randint(0, 4, n).astype(np.int32)}


def _params(seed=0, d=5):
    rng = np.random.RandomState(seed)
    return {"w": jnp.asarray(0.1 * rng.randn(d, 4).astype(np.float32)),
            "b": jnp.zeros((4,))}


def _pcfg(**kw):
    base = dict(option="C", lam=20.0, inner_steps=5, inner_eta=0.05,
                alpha=0.1, beta=0.5)
    base.update(kw)
    return PersAFLConfig(**base)


def _close(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=kw.get("rtol", 1e-5),
                                   atol=kw.get("atol", 1e-6))


def _cnn_tree():
    """fig2-CNN-shaped nested tree: conv stack + two FC layers."""
    rng = np.random.RandomState(3)
    layer = lambda *s: jnp.asarray(rng.randn(*s).astype(np.float32))
    return {"conv": [{"w": layer(3, 3, 1, 4), "b": layer(4)},
                     {"w": layer(3, 3, 4, 8), "b": layer(8)}],
            "fc": [{"w": layer(32, 16), "b": layer(16)},
                   {"w": layer(16, 10), "b": layer(10)}]}


# -- SubsetSpec spellings and transforms ------------------------------------

def test_resolve_accepts_every_spelling():
    tree = _cnn_tree()
    want = SubsetSpec(("fc/#1",))
    assert SubsetSpec.resolve("fc/#1", tree) == want
    assert SubsetSpec.resolve(("fc/#1",), tree) == want
    assert SubsetSpec.resolve(["fc/#1"], tree) == want
    assert SubsetSpec.resolve(want, tree) is want
    assert SubsetSpec.resolve(None) is None
    # pytree bool mask spelling resolves to the matched leaf paths
    mask = jax.tree.map(lambda _: False, tree)
    mask["fc"][1] = {"w": True, "b": True}
    got = SubsetSpec.resolve(mask, tree)
    assert set(got.prefixes) == {"fc/#1/b", "fc/#1/w"}
    assert got.validate(tree) == want.validate(tree)


def test_resolve_rejects_typos_and_empty():
    tree = _cnn_tree()
    with pytest.raises(ValueError, match="matches no param leaf"):
        SubsetSpec.resolve("fc/#7", tree)
    with pytest.raises(ValueError, match="no leaves"):
        SubsetSpec.resolve("", tree)
    with pytest.raises(TypeError):
        SubsetSpec.resolve(42)


def test_extract_merge_mask_roundtrip():
    tree = _cnn_tree()
    spec = SubsetSpec.resolve("fc/#1")
    sub = spec.extract(tree)
    # pruned form: conv dropped entirely, fc keeps a gap-None for slot 0
    assert set(sub) == {"fc"}
    assert sub["fc"][0] is None and set(sub["fc"][1]) == {"b", "w"}
    assert leaf_paths(sub) == ("fc/#1/b", "fc/#1/w")
    # merge restores the original bit-for-bit
    merged = merge_subset(tree, sub)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(merged)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # a modified subset lands ONLY on its own leaves
    sub2 = jax.tree.map(lambda x: x + 1.0, sub)
    merged2 = merge_subset(tree, sub2)
    assert np.array_equal(np.asarray(merged2["conv"][0]["w"]),
                          np.asarray(tree["conv"][0]["w"]))
    assert np.allclose(np.asarray(merged2["fc"][1]["w"]),
                       np.asarray(tree["fc"][1]["w"]) + 1.0)
    # mask mirrors the full structure with Python bools
    mask = spec.mask(tree)
    assert mask["fc"][1] == {"w": True, "b": True}
    assert mask["fc"][0] == {"w": False, "b": False}
    # subset_like re-arranges full-tree leaves into the pruned structure
    like = subset_like(tree, sub)
    assert jax.tree_util.tree_structure(like) \
        == jax.tree_util.tree_structure(sub)


def test_pruned_form_closed_under_npz_codec():
    """decode(encode(extract(t))) must have extract(t)'s exact treedef —
    the property that lets bank rows, checkpoints and wire frames share
    one structure (gap-preserving list rebuild in checkpoint.store)."""
    tree = _cnn_tree()
    for prefixes in ("fc/#1", "conv/#0/b,fc/#1/w", "fc"):
        sub = SubsetSpec.resolve(prefixes).extract(tree)
        back = decode_pytree(encode_pytree(sub))
        assert jax.tree_util.tree_structure(back) \
            == jax.tree_util.tree_structure(sub), prefixes
        for a, b in zip(jax.tree.leaves(sub), jax.tree.leaves(back)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_descriptor_roundtrip():
    tree = _cnn_tree()
    spec = SubsetSpec.resolve("fc/#1")
    desc = spec.descriptor(tree)
    assert desc == ["fc/#1/b", "fc/#1/w"]
    spec2 = SubsetSpec.from_descriptor(desc)
    assert spec2.validate(tree) == spec.validate(tree)
    # descriptors survive JSON (the checkpoint meta / wire header path)
    import json
    assert SubsetSpec.resolve(json.loads(json.dumps(desc)), tree) \
        .validate(tree) == spec.validate(tree)


# -- strategy: subset deltas against a frozen backbone ----------------------

def test_mode_b_subset_delta_is_alpha_grad_of_subset():
    from repro.serving.batcher import personalize_strategy
    params = _params()
    pcfg = _pcfg()
    batch = user_batch(0)
    strat = personalize_strategy(pcfg, loss, "B", personal_subset=("b",))
    delta, _, _ = strat.local_update(params, batch, None)
    assert set(delta) == {"b"}                        # pruned: no backbone
    g = jax.grad(lambda b_sub, bt: loss(merge_subset(params, b_sub), bt))(
        {"b": params["b"]}, batch)
    _close(delta, jax.tree.map(lambda x: pcfg.alpha * x, g))


def test_mode_c_subset_delta_is_prox_gap_with_frozen_backbone():
    from repro.serving.batcher import personalize_strategy
    params = _params()
    pcfg = _pcfg()
    batch = user_batch(1)
    strat = personalize_strategy(pcfg, loss, "C", personal_subset=("b",))
    delta, _, _ = strat.local_update(params, batch, None)
    theta, _ = solve_prox(
        lambda s, bt: loss(merge_subset(params, s), bt),
        {"b": params["b"]}, batch, pcfg.lam, pcfg.inner_eta,
        pcfg.inner_steps)
    _close(delta, {"b": params["b"] - theta["b"]})


# -- subset window apply: backbone bit-parity -------------------------------

def test_subset_apply_rows_freezes_backbone_bitwise():
    from repro.core import apply_admitted_rows, init_server_state
    params = _params()
    state = init_server_state(params)
    rng = np.random.RandomState(5)
    stack = {"b": jnp.asarray(rng.randn(4, 4).astype(np.float32))}
    weights = jnp.asarray([0.25, 0.25, 0.0, 0.0])
    new = apply_admitted_rows(state, stack, weights, 2, staleness_max=0)
    # backbone leaf: BIT-identical, not approximately equal
    assert np.array_equal(np.asarray(new.params["w"]),
                          np.asarray(params["w"]))
    expect_b = np.asarray(params["b"]) \
        - 0.25 * (np.asarray(stack["b"][0]) + np.asarray(stack["b"][1]))
    np.testing.assert_allclose(np.asarray(new.params["b"]), expect_b,
                               rtol=1e-6, atol=1e-7)
    assert int(new["t"]) == 2


# -- PersonalizationServer end-to-end ---------------------------------------

def test_server_serves_subset_heads_end_to_end():
    params = _params()
    pcfg = _pcfg()
    srv = PersonalizationServer(params, loss, pcfg,
                                personal_subset=("b",), windows=3)
    full = PersonalizationServer(params, loss, pcfg, windows=3)
    w0 = np.asarray(params["w"])

    tickets = [srv.submit(f"u{i}", user_batch(i)) for i in range(4)]
    srv.flush()
    for i, t in enumerate(tickets):
        head = srv.poll(t)
        assert set(head) == {"b"}                     # subset pytree
        theta, _ = solve_prox(
            lambda s, bt: loss(merge_subset(params, s), bt),
            {"b": params["b"]}, user_batch(i), pcfg.lam, pcfg.inner_eta,
            pcfg.inner_steps)
        _close(head, theta)
    # stacked heads carry the subset structure too
    stacked = srv.stacked_heads([t.user for t in tickets])
    assert set(stacked) == {"b"} and stacked["b"].shape[0] == 4

    # ring residency: a subset row is head-sized, and the full-model
    # server's row is strictly larger
    for i in range(4):
        full.submit(f"u{i}", user_batch(i))
    full.flush()
    assert srv.stats["ring_row_bytes"] == 4 * 4       # b: f32[4]
    assert srv.stats["ring_bytes_per_user"] == 2 * srv.ring.row_nbytes
    assert full.stats["ring_row_bytes"] == 4 * (5 * 4 + 4)
    assert full.stats["ring_bytes_per_user"] \
        > srv.stats["ring_bytes_per_user"]

    # several window advances: subset applies move b, never touch w
    for k in range(3):
        srv.submit("fresh", user_batch(10 + k))
        srv.advance_window()
        assert np.array_equal(np.asarray(srv.params["w"]), w0)  # bitwise
    assert not np.array_equal(np.asarray(srv.params["b"]),
                              np.asarray(params["b"]))
    assert srv.stats["host_materializations"] == 0


def test_server_subset_straggler_uses_merged_snapshot():
    """A straggler's cohort runs against snapshot(stamp) — in subset mode
    that is merge(backbone, stored subset), and since subset applies never
    move the backbone the recombination is exact."""
    pcfg = _pcfg(staleness_damping=0.5)
    params = _params()
    srv = PersonalizationServer(params, loss, pcfg,
                                personal_subset=("b",), windows=3)
    srv.submit("a", user_batch(1))
    srv.flush()
    srv.submit("late", user_batch(2))                 # stamped window 0
    srv.advance_window(flush=False)
    params1 = jax.tree.map(np.asarray, srv.params)
    t_late = srv.submit("late2", user_batch(3))       # fresh in window 1
    srv.advance_window()                              # drains both
    assert srv.stats["ring_stragglers"] == 1
    # the straggler's head solves against the ORIGINAL window-0 params
    theta0, _ = solve_prox(
        lambda s, bt: loss(merge_subset(params, s), bt),
        {"b": params["b"]}, user_batch(2), pcfg.lam, pcfg.inner_eta,
        pcfg.inner_steps)
    _close(srv.head("late"), theta0)
    # and the fresh one against window-1 params
    theta1, _ = solve_prox(
        lambda s, bt: loss(merge_subset(
            jax.tree.map(jnp.asarray, params1), s), bt),
        {"b": jnp.asarray(params1["b"])}, user_batch(3), pcfg.lam,
        pcfg.inner_eta, pcfg.inner_steps)
    _close(srv.poll(t_late), theta1)


def test_server_subset_save_restore_roundtrip(tmp_path):
    pcfg = _pcfg()
    srv = PersonalizationServer(_params(), loss, pcfg,
                                personal_subset=("b",), windows=3)
    users = [f"u{i}" for i in range(3)]
    for w in range(2):
        for i, u in enumerate(users):
            srv.submit(u, user_batch(10 * w + i))
        srv.advance_window()
    heads_before = {u: jax.tree.map(np.asarray, srv.head(u))
                    for u in users}
    path = str(tmp_path / "subset_state")
    srv.save(path)

    srv2 = PersonalizationServer.restore(path, loss, pcfg)
    # the subset survives the round trip (resolved descriptor form)
    assert srv2.personal_subset is not None
    assert srv2.personal_subset.validate(srv2.params) \
        == srv.personal_subset.validate(srv.params)
    _close(srv2.params, srv.params)
    # subset snapshots round-trip with their pruned structure
    for w in srv.ring._snapshots:
        assert jax.tree_util.tree_structure(srv2.ring.subset_snapshot(w)) \
            == jax.tree_util.tree_structure(srv.ring.subset_snapshot(w))
        _close(srv2.ring.snapshot(w), srv.ring.snapshot(w))
    for u in users:
        got = srv2.head(u)
        assert set(got) == {"b"}
        _close(got, heads_before[u])
    # the restored server keeps serving subset heads
    t = srv2.submit("fresh", user_batch(42))
    srv2.advance_window()
    assert t.status == "done"
    assert np.array_equal(np.asarray(srv2.params["w"]),
                          np.asarray(srv.params["w"]))


# -- transport subset negotiation -------------------------------------------

def test_transport_subset_negotiation_and_heads():
    params = _params()
    pcfg = _pcfg()

    ref = PersonalizationServer(params, loss, pcfg,
                                personal_subset=("b",), max_pending=64)
    t_ref = ref.submit("u0", user_batch(0))
    ref.flush()
    expected = jax.tree.map(np.asarray, ref.poll(t_ref))

    async def go():
        srv = PersonalizationServer(params, loss, pcfg,
                                    personal_subset=("b",), max_pending=64)
        ts = await TransportServer(srv, flush_ms=60_000.0).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        # a client that does NOT declare subset_ok gets a typed ERR on
        # every head-carrying op (old clients must not silently treat a
        # partial pytree as the full model)
        for hdr in ({"op": "SUBMIT", "user": "x", "mode": "C"},
                    {"op": "POLL", "ticket": 0},
                    {"op": "HEAD", "user": "x"}):
            with pytest.raises(TransportError) as ei:
                await c._rpc(hdr, encode_pytree(user_batch(0))
                             if hdr["op"] == "SUBMIT" else b"")
            assert ei.value.code == "subset_unsupported"
        # the subset-aware client path: served heads are subset pytrees
        # and the reply header stamps the resolved leaf descriptor
        tid = await c.submit("u0", user_batch(0))
        await c.flush()
        head = await c.poll(tid, wait_ms=10_000)
        assert c.last_subset == ["b"]
        again = await c.head("u0")
        stats = await c.stats()
        await c.close()
        await ts.stop()
        return head, again, stats

    head, again, stats = asyncio.run(go())
    assert set(head) == {"b"}
    for got in (head, again):
        for a, b in zip(jax.tree.leaves(expected), jax.tree.leaves(got)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert stats["host_materializations"] == 0
    # a client can reconstruct its full personalized model from the
    # descriptor + shared backbone
    merged = merge_subset(params, head)
    assert np.array_equal(np.asarray(merged["w"]), np.asarray(params["w"]))
    assert np.array_equal(np.asarray(merged["b"]), np.asarray(head["b"]))


def test_transport_full_model_server_ignores_subset_negotiation():
    """A full-model server never refuses: subset_ok is forward-compatible
    and the reply carries no subset key."""
    async def go():
        srv = PersonalizationServer(_params(), loss, _pcfg(),
                                    max_pending=64)
        ts = await TransportServer(srv, flush_ms=60_000.0).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        # no subset_ok: still fine against a full-model server
        h, _ = await c._rpc({"op": "SUBMIT", "user": "u", "mode": "C"},
                            encode_pytree(user_batch(0)))
        assert h["op"] == "OK"
        await c.flush()
        head = await c.poll(int(h["ticket"]), wait_ms=10_000)
        assert set(head) == {"b", "w"}
        assert c.last_subset is None
        await c.close()
        await ts.stop()

    asyncio.run(go())


# -- sharded cohort path on subset deltas -----------------------------------

def test_shard_map_cohort_handles_subset_deltas():
    """The stateless shard_map cohort body must carry pruned subset
    outputs (pytree-prefix out_specs) and agree with the vmap path."""
    from repro.fl.engine import CohortEngine
    from repro.serving.batcher import personalize_strategy
    params = _params()
    pcfg = _pcfg()
    batches = [user_batch(i) for i in range(8)]
    e_ref = CohortEngine(pcfg, loss, cohort_impl="vmap",
                         strategy=personalize_strategy(
                             pcfg, loss, "C", personal_subset=("b",)))
    e_sh = CohortEngine(pcfg, loss, cohort_impl="shard_map",
                        strategy=personalize_strategy(
                            pcfg, loss, "C", personal_subset=("b",)))
    ref = e_ref.update_cohort(params, batches)
    got = e_sh.update_cohort(params, batches)
    assert set(got.stacked) == {"b"}
    np.testing.assert_allclose(np.asarray(got.stacked["b"]),
                               np.asarray(ref.stacked["b"]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("cohort_impl", ["shard_map"])
def test_server_subset_sharded_serving(cohort_impl):
    """Subset serving over the sharded cohort path (exercised with 8
    virtual devices in the CI partial-smoke job; degenerates to a
    1-device mesh elsewhere)."""
    params = _params()
    pcfg = _pcfg()
    srv = PersonalizationServer(params, loss, pcfg,
                                cohort_impl=cohort_impl,
                                personal_subset=("b",))
    tickets = [srv.submit(f"u{i}", user_batch(i)) for i in range(5)]
    srv.flush()
    for i, t in enumerate(tickets):
        theta, _ = solve_prox(
            lambda s, bt: loss(merge_subset(params, s), bt),
            {"b": params["b"]}, user_batch(i), pcfg.lam, pcfg.inner_eta,
            pcfg.inner_steps)
        _close(srv.poll(t), theta)
    srv.advance_window()
    assert np.array_equal(np.asarray(srv.params["w"]),
                          np.asarray(params["w"]))
    assert srv.stats["host_materializations"] == 0


# -- personalized evaluation over a subset ----------------------------------

def test_personalized_eval_subset_freezes_backbone():
    from repro.data.federated import make_federated_dataset
    from repro.fl.evaluate import make_personalized_eval

    clients = make_federated_dataset("mnist", n_clients=4,
                                     classes_per_client=2, seed=0)

    def mnist_loss(p, b):
        x = b["images"].reshape(b["images"].shape[0], -1)
        logits = x @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(
            jax.nn.one_hot(b["labels"], 10) * logp, -1))

    def acc(p, b):
        x = b["images"].reshape(b["images"].shape[0], -1)
        return jnp.mean((jnp.argmax(x @ p["w"] + p["b"], -1)
                         == b["labels"]).astype(jnp.float32))

    rng = np.random.RandomState(0)
    dim = int(np.prod(clients[0].train_x.shape[1:]))
    params = {"w": jnp.asarray(0.01 * rng.randn(dim, 10)
                               .astype(np.float32)),
              "b": jnp.zeros((10,))}
    ev_full = make_personalized_eval(mnist_loss, acc, clients, ft_steps=2,
                                     ft_lr=0.05, seed=0)
    ev_head = make_personalized_eval(mnist_loss, acc, clients, ft_steps=2,
                                     ft_lr=0.05, seed=0,
                                     personal_subset=("b",))
    a_full, a_head = ev_full(params), ev_head(params)
    assert 0.0 <= a_head <= 1.0 and 0.0 <= a_full <= 1.0
    # an all-leaves subset IS full-model fine-tuning
    ev_all = make_personalized_eval(mnist_loss, acc, clients, ft_steps=2,
                                    ft_lr=0.05, seed=0,
                                    personal_subset=("b", "w"))
    assert abs(ev_all(params) - a_full) < 1e-6
    # typo'd subsets fail loudly at evaluate time
    ev_typo = make_personalized_eval(mnist_loss, acc, clients,
                                     personal_subset=("nope",))
    with pytest.raises(ValueError, match="matches no param leaf"):
        ev_typo(params)
