"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# no reason= kwarg: that importorskip parameter needs pytest>=8.2, and the
# dev floor is 7.0 — hypothesis itself comes from requirements-dev.txt
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (PersAFLConfig, apply_update, client_update,
                        init_server_state, solve_prox)
from repro.models.moe import expert_capacity, moe_forward
from repro.configs import get_config, reduce_for_smoke

SET = settings(max_examples=20, deadline=None)


def quad_loss(w, batch):
    r = batch["a"] @ w["w"] - batch["y"]
    return 0.5 * jnp.mean(r ** 2)


@st.composite
def quadratic(draw):
    d = draw(st.integers(2, 6))
    m = draw(st.integers(8, 24))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.RandomState(seed)
    A = rng.randn(m, d).astype(np.float32)
    y = rng.randn(m).astype(np.float32)
    return jnp.asarray(A), jnp.asarray(y)


@SET
@given(quadratic(), st.floats(8.0, 64.0))
def test_prox_contraction_toward_w_as_lambda_grows(q, lam):
    """Lemma-6 regime: as λ→∞, θ̃(w) → w (‖θ−w‖ ≤ ‖∇f(w)‖/(λ−L))."""
    A, y = q
    batch = {"a": A, "y": y}
    w = {"w": jnp.zeros(A.shape[1])}
    t_small, _ = solve_prox(quad_loss, w, batch, lam, 1.0 / (4 * lam), 200)
    t_big, _ = solve_prox(quad_loss, w, batch, 4 * lam, 1.0 / (16 * lam), 200)
    d_small = float(jnp.linalg.norm(t_small["w"] - w["w"]))
    d_big = float(jnp.linalg.norm(t_big["w"] - w["w"]))
    assert d_big <= d_small + 1e-5


@SET
@given(quadratic(), st.integers(1, 6), st.floats(0.001, 0.05))
def test_delta_scales_linearly_with_eta_first_order(q, q_local, eta):
    """For Option A, Δ(η)/η → Σ∇f as η→0 (telescoping consistency)."""
    A, y = q
    batches = {"a": jnp.stack([A] * q_local), "y": jnp.stack([y] * q_local)}
    w = {"w": jnp.ones(A.shape[1])}
    d1, _ = client_update(PersAFLConfig(option="A", q_local=q_local, eta=eta),
                          quad_loss, w, batches)
    d2, _ = client_update(PersAFLConfig(option="A", q_local=q_local,
                                        eta=eta / 2), quad_loss, w, batches)
    # halving eta at least halves the delta norm (up to curvature terms)
    n1 = float(jnp.linalg.norm(d1["w"]))
    n2 = float(jnp.linalg.norm(d2["w"]))
    assert n2 <= 0.75 * n1 + 1e-6


@SET
@given(st.integers(0, 10), st.integers(0, 10), st.floats(0.1, 2.0))
def test_server_counter_and_staleness_accounting(s1, s2, beta):
    state = init_server_state({"w": jnp.zeros(3)})
    state = apply_update(state, {"w": jnp.ones(3)}, beta, s1)
    state = apply_update(state, {"w": jnp.ones(3)}, beta, s2)
    assert int(state["t"]) == 2
    assert int(state["staleness_max"]) == max(s1, s2)
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               -2 * beta, rtol=1e-6)


@SET
@given(st.integers(2, 64), st.integers(2, 8), st.integers(1, 4),
       st.floats(1.0, 2.0))
def test_expert_capacity_bounds(tokens, experts, topk, cf):
    from repro.configs.base import MoEConfig
    mo = MoEConfig(n_experts=experts, top_k=min(topk, experts),
                   expert_d_ff=8, capacity_factor=cf)
    C = expert_capacity(tokens, mo)
    assert C >= mo.top_k
    assert C * experts >= tokens * mo.top_k  # can host all assignments at cf>=1


@SET
@given(st.integers(0, 2 ** 16))
def test_moe_forward_finite_and_bounded(seed):
    cfg = reduce_for_smoke(get_config("granite-moe-1b-a400m"))
    key = jax.random.PRNGKey(seed)
    from repro.models.moe import init_moe
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, aux = moe_forward(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0.0


@SET
@given(st.integers(0, 2 ** 16), st.integers(1, 3))
def test_checkpoint_roundtrip(seed, depth):
    from repro.checkpoint import load_pytree, save_pytree
    import tempfile, os
    rng = np.random.RandomState(seed)

    def build(d):
        if d == 0:
            return rng.randn(*rng.randint(1, 4, size=2)).astype(np.float32)
        return {f"k{i}": build(d - 1) for i in range(2)} if rng.rand() < 0.7 \
            else [build(d - 1), build(d - 1)]

    tree = {"root": build(depth), "scalar": np.float32(rng.randn())}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        save_pytree(path, tree)
        back = load_pytree(path)
    flat1 = jax.tree.leaves(tree)
    flat2 = jax.tree.leaves(back)
    assert len(flat1) == len(flat2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- admission_weights invariants (serving-ring apply math) -----------------

@st.composite
def admissions(draw):
    """Random (capacity, [(row, tau), ...]) with duplicates allowed."""
    capacity = draw(st.integers(1, 16))
    rows = draw(st.lists(
        st.tuples(st.integers(0, capacity - 1), st.integers(0, 6)),
        min_size=1, max_size=12))
    return capacity, rows


@SET
@given(admissions(), st.floats(0.1, 2.0), st.floats(0.0, 2.0))
def test_admission_weights_accumulate_per_admission(adm, beta, damping):
    """w == Σ over admissions of β/count·(1+τ)^{-damping} onto each slot —
    duplicates ACCUMULATE (the w[idx] = wt overwrite bug's invariant)."""
    from repro.core import admission_weights
    capacity, rows = adm
    count = len(rows)
    w = admission_weights(capacity, rows, beta=beta, count=count,
                          damping=damping)
    expect = np.zeros(capacity, np.float64)
    for idx, tau in rows:
        expect[idx] += beta / count * (1.0 + tau) ** (-damping)
    np.testing.assert_allclose(w, expect.astype(np.float32), rtol=1e-5)


@SET
@given(admissions(), st.floats(0.1, 2.0), st.integers(0, 3))
def test_admission_weights_tau_max_zeroes_stale_rows(adm, beta, tau_max):
    """Rows past the bound contribute exactly zero; within the bound the
    total weight never exceeds β (bounded-staleness admission)."""
    from repro.core import admission_weights
    capacity, rows = adm
    count = len(rows)
    w = admission_weights(capacity, rows, beta=beta, count=count,
                          tau_max=tau_max)
    only_stale = [i for i in range(capacity)
                  if all(t > tau_max for r, t in rows if r == i)]
    assert all(w[i] == 0.0 for i in only_stale)
    # damping <= 1 per row and #admitted <= count => sum(w) <= beta
    assert float(np.sum(w)) <= beta + 1e-5


@SET
@given(st.integers(0, 2 ** 16), st.integers(2, 4), st.floats(0.1, 1.5),
       st.floats(0.0, 1.0))
def test_ring_advance_composes_like_sequential_oracle(seed, windows, beta,
                                                      damping):
    """DeltaRing.advance over several windows == a numpy step-by-step
    oracle applying the same admitted/capped/duplicate/stale row mix."""
    from repro.core import init_server_state
    from repro.fl.engine import DeltaBank
    from repro.serving import DeltaRing

    rng = np.random.RandomState(seed)
    d = 3
    params = {"w": jnp.asarray(rng.randn(d).astype(np.float32))}
    ring = DeltaRing(params, windows=windows, user_cap=2)
    state = init_server_state(params)
    oracle = np.asarray(params["w"], np.float64)

    for _ in range(3):
        k = rng.randint(1, 4)
        stack = rng.randn(k, d).astype(np.float32)
        bank = DeltaBank(stacked={"w": jnp.asarray(stack)}, k=k)
        ring.retain(bank)
        # admissions: random rows, random staleness, one duplicate
        reqs = [(rng.randint(0, k), int(rng.randint(0, windows + 1)))
                for _ in range(rng.randint(1, 4))]
        reqs.append(reqs[0])               # duplicate slot, same user
        verdicts = [ring.admit_row(f"u{i % 2}", bank, r, t)
                    for i, (r, t) in enumerate(reqs)]
        admitted = [(r, t) for (r, t), v in zip(reqs, verdicts)
                    if v == "admitted"]
        state = ring.advance(state, beta=beta, damping=damping)
        m = len(admitted)
        for r, t in admitted:
            oracle -= (beta / m * (1.0 + t) ** (-damping)
                       * stack[r].astype(np.float64))
    np.testing.assert_allclose(np.asarray(state.params["w"]), oracle,
                               rtol=1e-4, atol=1e-5)


@SET
@given(st.integers(0, 2 ** 16), st.integers(1, 48))
def test_flash_attention_property_random_shapes(seed, s_mult):
    """Kernel == oracle on randomly drawn (block-aligned) shapes."""
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    from repro.kernels.flash_attention.ref import attention_ref
    rng = np.random.RandomState(seed)
    S = 32 * (1 + seed % 4)
    Hkv = int(rng.choice([1, 2]))
    Hq = Hkv * int(rng.choice([1, 2, 4]))
    hd = int(rng.choice([16, 32]))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, Hq, hd))
    k = jax.random.normal(ks[1], (1, S, Hkv, hd))
    v = jax.random.normal(ks[2], (1, S, Hkv, hd))
    out = flash_attention_fwd(q, k, v, block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


@st.composite
def robust_case(draw):
    """Random admission set: norms (some non-finite), rows, knobs."""
    cap = draw(st.integers(2, 24))
    k = draw(st.integers(1, cap))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.RandomState(seed)
    norms = np.abs(rng.randn(cap)) * 10 ** rng.randint(-1, 3, cap)
    bad = rng.rand(cap) < 0.2
    norms[bad] = rng.choice([np.nan, np.inf], bad.sum())
    idxs = rng.choice(cap, k, replace=False)
    rows = [(int(i), int(rng.randint(0, 4))) for i in idxs]
    beta = draw(st.floats(0.1, 2.0))
    return cap, rows, norms, beta


@SET
@given(robust_case(), st.floats(0.0, 1.5))
def test_robust_clip_weights_vs_oracle(case, damping):
    """Clip == plain admission_weights with a per-row norm cap: every
    weight equals β/count·(1+τ)^-a scaled by min(1, c/norm), zero on
    non-finite rows, and keep mirrors finiteness."""
    from repro.core import robust_admission_weights
    cap, rows, norms, beta = case
    w, keep, info = robust_admission_weights(
        cap, rows, norms, beta=beta, count=len(rows), damping=damping,
        method="clip")
    np.testing.assert_array_equal(keep, np.isfinite(norms))
    finite = [(i, t) for i, t in rows if np.isfinite(norms[i])]
    assert info["nonfinite"] == len(rows) - len(finite)
    oracle = np.zeros(cap)
    if finite:
        c = info["clip_norm"]
        assert c == pytest.approx(
            2.0 * np.median([norms[i] for i, _ in finite]))
        for i, t in finite:
            wt = beta / len(rows) * (1.0 + t) ** (-damping)
            if norms[i] > c and norms[i] > 0.0:
                wt *= c / norms[i]
            oracle[i] += wt
    np.testing.assert_allclose(w, oracle, rtol=1e-5, atol=1e-12)
    # clipping never increases any admission's contribution norm
    contrib = w * np.where(np.isfinite(norms), norms, 0.0)
    if finite and info["clip_norm"] > 0:
        assert contrib.max() <= beta / len(rows) * info["clip_norm"] \
            * max((1.0 + t) ** (-damping) for _, t in finite) * (1 + 1e-6)


@SET
@given(robust_case(), st.floats(0.05, 0.45))
def test_robust_trim_weights_vs_oracle(case, trim_frac):
    """Trim == numpy trimmed mean over the finite admissions: the norm
    tails get weight 0, survivors split β evenly, ≥1 survives."""
    from repro.core import robust_admission_weights
    cap, rows, norms, beta = case
    w, keep, info = robust_admission_weights(
        cap, rows, norms, beta=beta, count=len(rows), method="trim",
        trim_frac=trim_frac)
    finite = [(i, t) for i, t in rows if np.isfinite(norms[i])]
    if not finite:
        assert not w.any()
        return
    k = len(finite)
    cut = int(np.ceil(trim_frac * k))
    if 2 * cut >= k:
        cut = (k - 1) // 2
    order = np.argsort([norms[i] for i, _ in finite], kind="stable")
    survivors = [finite[j][0] for j in order[cut: k - cut]]
    assert len(survivors) >= 1
    assert info["trimmed"] == k - len(survivors)
    oracle = np.zeros(cap)
    for i in survivors:
        oracle[i] += beta / len(survivors)
    np.testing.assert_allclose(w, oracle, rtol=1e-6, atol=1e-12)
    # total admitted mass is exactly β (a trimmed MEAN, not a down-scale)
    assert w.sum() == pytest.approx(beta, rel=1e-5)
