"""Scenario engine (PR 8): counter-based delay streams, ChurnModel,
heap-vs-vectorized event parity, the DeviceScheduler, and robust
admission against adversarial rows."""
import heapq
import json
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PersAFLConfig, bank_row_norms, mask_rows,
                        robust_admission_weights, robust_flush_weights,
                        scale_rows)
from repro.data.federated import ClientData
from repro.fl import (Adversarial, ChurnModel, DelayModel, DeviceScheduler,
                      Diurnal, EventStream, FLRun, ScenarioSpec, Tier,
                      buffered, immediate, sync_barrier)
from repro.fl.delays import hash_u01, hash_u32
from repro.fl.scenario import KIND_DOWN, KIND_UP


def _loss(p, b):
    logits = b["images"] @ p["w"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 4) * logp, -1))


def _clients(n, d=5, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        x = rng.randn(64, d).astype(np.float32)
        y = rng.randint(0, 4, 64).astype(np.int32)
        out.append(ClientData(train_x=x, train_y=y, test_x=x[:8],
                              test_y=y[:8], classes=(0, 1, 2, 3)))
    return out


def _pcfg():
    return PersAFLConfig(option="A", q_local=2, eta=0.05, alpha=0.05,
                         lam=20.0, inner_steps=3, inner_eta=0.02)


def _churn_spec(n, seed=3, **kw):
    base = dict(tiers=(Tier("fast", 0.5, 0.7), Tier("slow", 0.5, 1.6)),
                diurnal=Diurnal(period=40.0, floor=0.3), dropout=0.15)
    base.update(kw)
    return ScenarioSpec(n_clients=n, seed=seed, **base)


# ---------------------------------------------------------------------------
# counter-based hash streams
# ---------------------------------------------------------------------------

def test_hash_np_jnp_bit_parity():
    """The numpy (host schedulers) and jax (device scheduler) backends of
    the counter hash must agree bit-for-bit — this is what lets the
    DeviceScheduler draw the same jitter as the heap."""
    ids = np.arange(257)
    for tag in (1, 2, 5):
        for k in (0, 1, 1000):
            h_np = hash_u32(7, ids, k, tag, np)
            h_j = np.asarray(hash_u32(7, jnp.asarray(ids), k, tag, jnp))
            np.testing.assert_array_equal(h_np, h_j)
            u_np = hash_u01(7, ids, k, tag, np)
            u_j = np.asarray(hash_u01(7, jnp.asarray(ids), k, tag, jnp))
            # u01 uses 24 bits so f32 and f64 represent it exactly
            np.testing.assert_array_equal(u_np.astype(np.float32), u_j)


def test_hash_decorrelates_tags_and_counters():
    u1 = hash_u01(0, np.arange(64), 0, 1)
    u2 = hash_u01(0, np.arange(64), 0, 2)
    u3 = hash_u01(0, np.arange(64), 1, 1)
    assert not np.array_equal(u1, u2)
    assert not np.array_equal(u1, u3)
    assert 0.0 <= u1.min() and u1.max() < 1.0


def test_delay_stream_invariant_to_n_clients():
    """Regression for the shared-RNG bug: client i's realized delay
    sequence must depend only on (seed, i) — never on how many other
    clients exist or how their events interleave."""
    a = DelayModel(8, seed=5)
    b = DelayModel(100, seed=5)
    for i in range(8):
        seq_a = [a.sample_download(i, 0.0) for _ in range(6)] \
            + [a.sample_upload(i, 1.0) for _ in range(6)]
        seq_b = [b.sample_download(i, 0.0) for _ in range(6)] \
            + [b.sample_upload(i, 1.0) for _ in range(6)]
        assert seq_a == seq_b


def test_delay_stream_independent_of_other_clients_draws():
    a = DelayModel(8, seed=5)
    b = DelayModel(8, seed=5)
    for i in range(1, 8):
        for _ in range(4):
            b.sample_download(i, 0.0)
            b.sample_upload(i, 0.0)
    assert a.sample_download(0, 0.0) == b.sample_download(0, 0.0)
    assert a.sample_upload(0, 0.0) == b.sample_upload(0, 0.0)


def test_upload_download_ratio_preserved():
    m = DelayModel(200, seed=0)
    downs = np.array([m.sample_download(i, 0.0) for i in range(200)])
    ups = np.array([m.sample_upload(i, 0.0) for i in range(200)])
    ratio = ups.mean() / downs.mean()
    assert 3.5 < ratio < 6.5


# ---------------------------------------------------------------------------
# ScenarioSpec
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip():
    spec = _churn_spec(32, adversarial=Adversarial(
        frac=0.1, kinds=("scale", "nan"), magnitude=25.0))
    j = spec.to_json()
    json.loads(j)                      # well-formed JSON
    back = ScenarioSpec.from_json(j)
    assert back == spec
    m = back.build()
    assert isinstance(m, ChurnModel)
    assert m.n_clients == 32 and m.dropout == spec.dropout


def test_spec_validation():
    with pytest.raises(ValueError):
        ScenarioSpec(n_clients=0)
    with pytest.raises(ValueError):
        ScenarioSpec(n_clients=4, dropout=1.5)
    with pytest.raises(ValueError):
        ScenarioSpec(n_clients=4, tiers=())
    with pytest.raises(ValueError):
        ScenarioSpec(n_clients=4,
                     adversarial=Adversarial(frac=0.1, kinds=("bogus",)))


def test_churn_model_tiers_and_adversaries_are_hash_assigned():
    m = _churn_spec(4000, adversarial=Adversarial(frac=0.05)).build()
    fast = float(np.mean(m.tier_mult == 0.7))
    assert 0.4 < fast < 0.6            # ~half the population per tier
    adv = len(m.adversary_ids) / 4000
    assert 0.02 < adv < 0.08           # ~5% adversaries
    fac = m.corruption_factors(np.arange(4000))
    assert np.all(fac[np.setdiff1d(np.arange(4000), m.adversary_ids)]
                  == 1.0)
    assert np.all(fac[m.adversary_ids] != 1.0)
    # availability stays in [floor, 1]
    av = m.availability(np.arange(4000), 13.7)
    assert np.all(av >= m.diurnal.floor - 1e-12) and np.all(av <= 1.0)


# ---------------------------------------------------------------------------
# event ordering: heap oracle vs vectorized EventStream
# ---------------------------------------------------------------------------

def _heap_oracle(model, n_events):
    """The per-event heap under the documented (time, client, kind) total
    order — the exact loop FLRun._heap_events runs."""
    heap = []
    for i in range(model.n_clients):
        heapq.heappush(heap, (model.sample_download(i, 0.0), i, KIND_DOWN))
    out = []
    while len(out) < n_events:
        now, i, kind = heapq.heappop(heap)
        if kind == KIND_DOWN:
            dropped = model.drops(i)
            t_up = now + model.sample_upload(i, now)
            if dropped:
                heapq.heappush(
                    heap, (t_up + model.sample_download(i, t_up), i,
                           KIND_DOWN))
            else:
                heapq.heappush(heap, (t_up, i, KIND_UP))
            out.append((now, i, KIND_DOWN, dropped, t_up))
        else:
            heapq.heappush(
                heap, (now + model.sample_download(i, now), i, KIND_DOWN))
            out.append((now, i, KIND_UP, False, now))
    return out


@pytest.mark.parametrize("make", [
    lambda: DelayModel(24, seed=1),
    lambda: _churn_spec(24, adversarial=Adversarial(frac=0.2)).build(),
])
def test_eventstream_bit_equal_to_heap(make):
    """EventStream must emit the heap's exact event tuples — times
    bit-equal (same float64 ops in the same order), same total order."""
    oracle = _heap_oracle(make(), 400)
    stream = EventStream(make(), chunk=3).events()
    got = [next(stream) for _ in range(400)]
    assert got == oracle


def test_event_order_is_time_client_kind():
    """The documented deterministic total order: sorted by (t, i, kind),
    KIND_DOWN before KIND_UP on ties."""
    stream = EventStream(_churn_spec(16).build()).events()
    evs = [next(stream) for _ in range(300)]
    keys = [(t, i, k) for t, i, k, _, _ in evs]
    assert keys == sorted(keys)


def test_eventstream_dropout_suppresses_uploads():
    def frac_up(dropout):
        spec = ScenarioSpec(n_clients=64, seed=2, dropout=dropout)
        stream = EventStream(spec.build()).events()
        evs = [next(stream) for _ in range(800)]
        downs = sum(1 for e in evs if e[2] == KIND_DOWN)
        ups = sum(1 for e in evs if e[2] == KIND_UP)
        return ups / downs
    # warm-up transient (first downloads outnumber landed uploads) keeps
    # the no-dropout ratio a bit under 1; dropout must cut well below it
    f0, f4 = frac_up(0.0), frac_up(0.4)
    assert f0 > 0.8
    assert f4 < f0 - 0.15


# ---------------------------------------------------------------------------
# FLRun: heap scheduler vs device scheduler, bit-equal
# ---------------------------------------------------------------------------

def _flrun(n, schedule, scheduler, delays, rounds=18):
    run = FLRun(clients=_clients(n), loss_fn=_loss,
                init_params={"w": jnp.zeros((5, 4))}, pcfg=_pcfg(),
                delays=delays, strategy="persafl", schedule=schedule,
                batch_size=8, seed=0, scheduler=scheduler)
    hist = run.run(max_rounds=rounds)
    return run, hist


@pytest.mark.parametrize("n", [16, 100])
@pytest.mark.parametrize("make_schedule,make_delays", [
    (immediate, lambda n: DelayModel(n, seed=1)),
    (lambda: buffered(4), lambda n: DelayModel(n, seed=1)),
    (immediate, lambda n: _churn_spec(n).build()),
    (lambda: buffered(4), lambda n: _churn_spec(n).build()),
])
def test_flrun_heap_vs_device_bit_equal(n, make_schedule, make_delays):
    """scheduler="device" replays the heap's exact simulation: identical
    History (times, staleness, active-ratio grid) and identical final
    params, at small and at heap-comfortable n, with and without churn."""
    rh, hh = _flrun(n, make_schedule(), "heap", make_delays(n))
    rd, hd = _flrun(n, make_schedule(), "device", make_delays(n))
    assert hh.as_dict() == hd.as_dict()
    for a, b in zip(jax.tree.leaves(rh.state.params),
                    jax.tree.leaves(rd.state.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert rh.stats["dropouts"] == rd.stats["dropouts"]
    assert rh.stats["windows"] == rd.stats["windows"]


def test_flrun_sync_ignores_scheduler_flag():
    rh, hh = _flrun(16, sync_barrier(4), "heap", DelayModel(16, seed=1),
                    rounds=3)
    rd, hd = _flrun(16, sync_barrier(4), "device", DelayModel(16, seed=1),
                    rounds=3)
    assert hh.as_dict() == hd.as_dict()
    for a, b in zip(jax.tree.leaves(rh.state.params),
                    jax.tree.leaves(rd.state.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_flrun_scheduler_arg_validated():
    with pytest.raises(ValueError):
        FLRun(clients=_clients(2), loss_fn=_loss,
              init_params={"w": jnp.zeros((5, 4))}, pcfg=_pcfg(),
              delays=DelayModel(2), scheduler="gpu")


def test_flrun_stats_surface():
    run, _ = _flrun(16, buffered(4), "auto", _churn_spec(16).build())
    s = run.stats
    for key in ("scheduler", "windows", "cohort_fill_sum",
                "cohort_fill_max", "mean_cohort_fill", "dropouts",
                "corrupted_rows", "robust_clipped", "robust_trimmed",
                "robust_nonfinite", "cohort_calls",
                "host_materializations"):
        assert key in s, key
    assert s["scheduler"] == "heap"         # auto resolves at 16 clients
    assert s["windows"] > 0
    assert s["mean_cohort_fill"] == pytest.approx(4.0)
    assert run.window_log and run.window_log[0]["window"] == 1


# ---------------------------------------------------------------------------
# robust admission
# ---------------------------------------------------------------------------

def _stack(norm_per_row, d=6):
    """A one-leaf [M, d] stack whose rows have the given L2 norms."""
    m = len(norm_per_row)
    rows = np.zeros((m, d), np.float32)
    for j, nrm in enumerate(norm_per_row):
        rows[j, 0] = nrm
    return {"w": jnp.asarray(rows)}


def test_bank_row_norms_matches_numpy():
    rng = np.random.RandomState(0)
    stack = {"a": jnp.asarray(rng.randn(8, 3, 2).astype(np.float32)),
             "b": jnp.asarray(rng.randn(8, 5).astype(np.float32))}
    got = bank_row_norms(stack)
    want = np.sqrt((np.asarray(stack["a"]).reshape(8, -1) ** 2).sum(1)
                   + (np.asarray(stack["b"]) ** 2).sum(1))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_robust_weights_clip_oracle():
    norms = np.array([1.0, 10.0, np.nan, 1.0])
    w, keep, info = robust_admission_weights(
        4, [(0, 0), (1, 0), (2, 0)], norms, beta=1.0, count=2,
        method="clip", clip_norm=2.0)
    assert keep.tolist() == [True, True, False, True]
    assert info == {"clipped": 1, "nonfinite": 1, "trimmed": 0,
                    "clip_norm": 2.0}
    np.testing.assert_allclose(w, [0.5, 0.5 * 2.0 / 10.0, 0.0, 0.0],
                               rtol=1e-6)


def test_robust_weights_clip_self_calibrates_on_median():
    norms = np.array([1.0, 1.0, 1.0, 50.0])
    w, _, info = robust_admission_weights(
        4, [(j, 0) for j in range(4)], norms, beta=1.0, count=4,
        method="clip")
    assert info["clip_norm"] == pytest.approx(2.0)  # 2 x median
    assert info["clipped"] == 1
    np.testing.assert_allclose(w[3], 0.25 * 2.0 / 50.0, rtol=1e-6)
    np.testing.assert_allclose(w[:3], 0.25, rtol=1e-6)


def test_robust_weights_trim_oracle():
    norms = np.array([0.1, 1.0, 1.1, 1.2, 100.0])
    w, keep, info = robust_admission_weights(
        5, [(j, 0) for j in range(5)], norms, beta=1.0, count=5,
        method="trim", trim_frac=0.2)
    assert keep.all()
    assert info["trimmed"] == 2                 # one from each tail
    np.testing.assert_allclose(w, [0.0, 1 / 3, 1 / 3, 1 / 3, 0.0],
                               rtol=1e-6)


def test_robust_weights_trim_always_keeps_one():
    w, _, info = robust_admission_weights(
        2, [(0, 0), (1, 0)], np.array([1.0, 2.0]), beta=1.0, count=2,
        method="trim", trim_frac=0.9)
    assert (w > 0).sum() == 1 or (w > 0).sum() == 2
    assert info["trimmed"] < 2


def test_robust_weights_respect_tau_max_and_damping():
    norms = np.ones(3)
    w, _, _ = robust_admission_weights(
        3, [(0, 0), (1, 5)], norms, beta=1.0, count=2, damping=1.0,
        tau_max=2, method="clip", clip_norm=10.0)
    assert w[1] == 0.0                           # past tau_max
    np.testing.assert_allclose(w[0], 0.5, rtol=1e-6)


def test_robust_flush_calibrates_across_banks():
    """A corrupted row alone in its own bank group must still be caught.

    A buffered flush's rows split across banks (in-flight clients were
    computed in an earlier window's bank).  Calibrating per group, the
    lone corrupted row sets its OWN clip median (never clipped) and a
    1-row group cannot be trimmed at all — robust_flush_weights ranks
    and calibrates over the whole flush instead."""
    honest = types.SimpleNamespace(stacked=_stack([1.0, 1.0, 1.2]),
                                   capacity=3)
    lone = types.SimpleNamespace(stacked=_stack([50.0]), capacity=1)
    groups = {"honest": (honest, [(0, 0), (1, 0), (2, 0)]),
              "lone": (lone, [(0, 1)])}

    per_bank, info = robust_flush_weights(groups, beta=1.0, count=4,
                                          method="clip")
    assert info["clip_norm"] == pytest.approx(2.2)  # 2 x median of ALL 4
    assert info["clipped"] == 1
    w_lone, keep_lone = per_bank["lone"]
    assert keep_lone.all()
    np.testing.assert_allclose(w_lone, [0.25 * 2.2 / 50.0], rtol=1e-6)
    w_honest, _ = per_bank["honest"]
    np.testing.assert_allclose(w_honest, 0.25, rtol=1e-6)
    # the per-group function, for contrast, cannot see the outlier
    _, _, solo = robust_admission_weights(
        1, [(0, 1)], bank_row_norms(lone.stacked), beta=1.0, count=4,
        method="clip")
    assert solo["clipped"] == 0

    # trim: global rank over k=4 norms [1, 1, 1.2, 50], cut=1 per tail
    per_bank, info = robust_flush_weights(groups, beta=1.0, count=4,
                                          method="trim", trim_frac=0.25)
    assert info["trimmed"] == 2
    w_lone, _ = per_bank["lone"]
    assert w_lone[0] == 0.0
    w_honest, _ = per_bank["honest"]
    np.testing.assert_allclose(sorted(w_honest), [0.0, 0.5, 0.5],
                               rtol=1e-6)


def test_mask_rows_neutralizes_nan_rows():
    stack = _stack([1.0, np.nan, 3.0])
    keep = np.array([True, False, True])
    masked = mask_rows(stack, keep)
    arr = np.asarray(masked["w"])
    assert np.isfinite(arr).all()
    np.testing.assert_array_equal(arr[0], np.asarray(stack["w"])[0])
    np.testing.assert_array_equal(arr[2], np.asarray(stack["w"])[2])
    assert (arr[1] == 0).all()


def test_scale_rows_applies_per_row_factors():
    stack = _stack([1.0, 2.0, 3.0])
    out = scale_rows(stack, np.array([1.0, -50.0, np.nan], np.float32))
    arr = np.asarray(out["w"])
    assert arr[0, 0] == 1.0
    assert arr[1, 0] == -100.0
    assert np.isnan(arr[2, 0])


def test_nan_adversaries_poison_plain_but_not_robust():
    """End-to-end: 25% NaN-bombing clients destroy the plain buffered
    flush; clip and trim admissions keep the params finite."""
    spec = ScenarioSpec(n_clients=16, seed=5,
                        adversarial=Adversarial(frac=0.25, kinds=("nan",)))

    def go(schedule):
        run, _ = _flrun(16, schedule, "heap", spec.build(), rounds=24)
        finite = all(np.isfinite(np.asarray(x)).all()
                     for x in jax.tree.leaves(run.state.params))
        return run, finite

    r0, f0 = go(buffered(4))
    r1, f1 = go(buffered(4, robust="clip"))
    r2, f2 = go(buffered(4, robust="trim", trim_frac=0.3))
    assert r0.stats["corrupted_rows"] > 0
    assert not f0
    assert f1 and f2
    assert r1.stats["robust_nonfinite"] > 0
    assert r2.stats["robust_nonfinite"] > 0


def test_buffered_robust_arg_validated():
    with pytest.raises(ValueError):
        buffered(4, robust="median")


# ---------------------------------------------------------------------------
# DeviceScheduler
# ---------------------------------------------------------------------------

def test_device_scheduler_deterministic():
    spec = _churn_spec(512, seed=9)
    a = DeviceScheduler.from_spec(spec, window_len=30.0, cohort_cap=64)
    b = DeviceScheduler.from_spec(spec, window_len=30.0, cohort_cap=64)
    for _ in range(4):
        ia, ta = a.next_window()
        ib, tb = b.next_window()
        np.testing.assert_array_equal(ia, ib)
        np.testing.assert_array_equal(ta, tb)
    assert a.stats == b.stats


def test_device_scheduler_cohort_matches_eventstream_oracle():
    """First-window cohort = the clients whose first non-dropped upload
    lands inside the window, times f32-close to the float64 EventStream."""
    spec = ScenarioSpec(n_clients=64, seed=4, dropout=0.1)
    window = 60.0
    sched = DeviceScheduler.from_spec(spec, window_len=window,
                                      cohort_cap=64, cycles_per_window=8)
    ids, times = sched.next_window()
    # float64 oracle: replay events, keep first completion per client
    stream = EventStream(spec.build()).events()
    first = {}
    for t, i, kind, dropped, t_up in stream:
        if t >= window:
            break
        if kind == KIND_UP and i not in first:
            first[i] = t
    want = sorted(first.items(), key=lambda kv: kv[1])
    # exclude boundary-ambiguous completions (f32 vs f64 window edge)
    certain = [(i, t) for i, t in want if abs(t - window) > 1e-3]
    got = dict(zip(ids.tolist(), times.tolist()))
    for i, t in certain:
        assert i in got, (i, t)
        assert got[i] == pytest.approx(t, rel=1e-4)


def test_device_scheduler_counts_dropouts_and_overflow():
    spec = ScenarioSpec(n_clients=256, seed=7, dropout=0.3)
    sched = DeviceScheduler.from_spec(spec, window_len=100.0,
                                      cohort_cap=16)
    ids, _ = sched.next_window()
    st = sched.stats
    assert st["dropouts"] > 0
    assert st["arrivals"] > 16
    assert st["overflow_arrivals"] == st["arrivals"] - len(ids)
    assert len(ids) <= 16
    assert sched.window_log[0]["window"] == 1


def test_device_scheduler_1e4_smoke():
    """10^4 clients advance in a handful of jitted window calls; the host
    only ever sees [cohort_cap]-sized vectors."""
    spec = _churn_spec(10_000, seed=11, dropout=0.05)
    sched = DeviceScheduler.from_spec(spec, window_len=25.0,
                                      cohort_cap=512)
    total = 0
    for _ in range(3):
        ids, times = sched.next_window()
        assert len(ids) == len(times) <= 512
        assert np.all(np.diff(times) >= 0)
        total += len(ids)
    assert total > 0
    assert sched.stats["windows"] == 3


def test_delta_ring_robust_survives_nan_rows():
    """The serving ring's window apply under robust admission: a NaN row
    poisons the plain apply, clip/trim drop it and stay finite."""
    import types
    from repro.core import init_server_state
    from repro.serving import DeltaRing
    stack = _stack([1.0, np.nan, 2.0], d=6)
    bank = types.SimpleNamespace(stacked=stack, capacity=3)
    for robust, want_finite in ((None, False), ("clip", True),
                                ("trim", True)):
        ring = DeltaRing({"w": jnp.zeros(6)}, windows=2, robust=robust)
        state = init_server_state({"w": jnp.zeros(6)})
        for user, row in (("a", 0), ("b", 1), ("c", 2)):
            assert ring.admit(user, bank, row, 0)
        state = ring.advance(state, beta=0.5)
        finite = bool(np.isfinite(np.asarray(state.params["w"])).all())
        assert finite == want_finite, robust
        if robust is not None:
            assert ring.stats["robust_nonfinite"] == 1


def test_delta_ring_robust_arg_validated():
    from repro.serving import DeltaRing
    with pytest.raises(ValueError):
        DeltaRing({"w": jnp.zeros(2)}, robust="median")
