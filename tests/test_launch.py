"""Launch-layer tests: microbatching equivalence, specs, hlo_cost analyzer,
roofline math, train/serve drivers at smoke scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_shape, reduce_for_smoke
from repro.launch import hlo_cost, roofline as rl
from repro.launch.specs import (decode_specs, params_struct,
                                prefill_batch_specs, train_batch_specs)
from repro.launch.steps import make_loss, make_train_step, microbatched
from repro.models import api


def test_microbatched_grad_equals_full_grad():
    cfg = reduce_for_smoke(get_config("minitron-8b"))
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    toks = jax.random.randint(key, (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    loss_full = make_loss(cfg, 1)
    loss_mb = make_loss(cfg, 4)
    g1 = jax.grad(loss_full)(params, batch)
    g2 = jax.grad(loss_mb)(params, batch)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=2e-3)


def test_train_step_applies_server_update():
    from repro.core import PersAFLConfig
    cfg = reduce_for_smoke(get_config("codeqwen1.5-7b"))
    key = jax.random.PRNGKey(1)
    params = api.init_params(cfg, key)
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.01, beta=1.0)
    step = make_train_step(cfg, pcfg, n_microbatches=1)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    new_params, metrics = step(params, params, batch)
    # server moved in the -delta direction: w_new = w - beta*delta
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert moved > 0
    # staleness decoupling: delta computed at stale params, applied to server
    stale = jax.tree.map(lambda x: x + 0.01 if x.ndim >= 2 else x, params)
    new2, _ = step(params, stale, batch)
    diff = sum(float(jnp.sum(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(new_params),
                               jax.tree.leaves(new2)))
    assert diff > 0  # different download point -> different delta


def test_cohort_step_equals_pjit_on_one_device():
    """The FedBuff cohort shard_map round degenerates to the paper-faithful
    step when the cohort has one member (1-device mesh)."""
    from repro.core import PersAFLConfig
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_cohort_train_step
    cfg = reduce_for_smoke(get_config("mamba2-130m"))
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.01)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    mesh = make_host_mesh()
    with mesh:
        p1, _ = jax.jit(make_train_step(cfg, pcfg, 1))(params, params, batch)
        p2, _ = jax.jit(make_cohort_train_step(cfg, pcfg, mesh, 1))(
            params, params, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_input_specs_shapes():
    cfg = get_config("internvl2-76b")
    shape = get_shape("train_4k")
    b = train_batch_specs(cfg, shape)
    assert b["tokens"].shape == (256, 4096 - 1024)
    assert b["visual"].shape == (256, 1024, 8192)
    p = prefill_batch_specs(cfg, get_shape("prefill_32k"))
    assert "labels" not in p
    wcfg = get_config("whisper-large-v3")
    wb = train_batch_specs(wcfg, shape)
    assert wb["frames"].shape == (256, 1500, 1280)


def test_decode_specs_cache_struct():
    cfg = reduce_for_smoke(get_config("gemma2-2b"))
    p_struct = params_struct(cfg, cast=False)
    cache, tok, pos = decode_specs(cfg, get_shape("decode_32k"), p_struct)
    k = cache["layers"]["k"]
    assert k.shape[0] == cfg.n_layers and k.shape[2] == 32768
    assert tok.shape == (128, 1) and pos.shape == ()


def test_hlo_cost_counts_nested_trip_counts():
    def f(x, w):
        def outer(c, _):
            def inner(cc, _):
                return jnp.tanh(cc @ w), None
            cc, _ = jax.lax.scan(inner, c, None, length=4)
            return cc, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    r = hlo_cost.analyze(compiled.as_text())
    # dot flops dominate; the tanh adds 1 flop/element (~0.8%)
    assert r["flops"] == pytest.approx(2 * 64 * 64 * 64 * 12, rel=2e-2)


def test_roofline_terms_math():
    rec = {
        "n_devices": 256,
        "hlo_cost": {"flops": 197e12, "bytes": 819e9,
                     "collective_bytes": {"all-reduce": 50e9,
                                          "all-gather": 0,
                                          "reduce-scatter": 0,
                                          "all-to-all": 0,
                                          "collective-permute": 0}},
        "cost_analysis": {},
        "collective_bytes": {},
        "model_flops": 197e12 * 256,
    }
    r = rl.roofline_terms(rec)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(1.0)
    assert r["collective_s"] == pytest.approx(1.0)
    assert r["useful_ratio"] == pytest.approx(1.0)


def test_grad_evals_accounting():
    assert rl.grad_evals("A", 10, "full", 5) == 10
    assert rl.grad_evals("B", 10, "fo", 5) == 20
    assert rl.grad_evals("B", 10, "full", 5) == 40
    assert rl.grad_evals("C", 10, "full", 5) == 60


def test_collective_bytes_parser():
    hlo = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %ar = f32[4]{0} all-reduce(%p), replica_groups={}
  ROOT %ag = f32[8]{0} all-gather(%ar), dimensions={0}
}
"""
    got = rl.collective_bytes(hlo)
    assert got["all-reduce"] == 16
    assert got["all-gather"] == 32
