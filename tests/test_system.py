"""End-to-end behaviour tests: the paper's system over the discrete-event
simulator + data pipeline + optimizers + checkpointing working together."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset, sample_batches
from repro.fl import DelayModel, FLRun, immediate, make_personalized_eval, \
    sync_barrier
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn


@pytest.fixture(scope="module")
def fed():
    clients = make_federated_dataset("mnist", n_clients=6,
                                     classes_per_client=3, seed=0)
    params = init_cnn(MNIST_CNN, jax.random.PRNGKey(0))
    loss = lambda p, b: cnn_loss(MNIST_CNN, p, b, train=False)
    acc = lambda p, b: cnn_accuracy(MNIST_CNN, p, b)
    return clients, params, loss, acc


def test_partition_heterogeneity(fed):
    clients, *_ = fed
    for c in clients:
        assert set(np.unique(c.train_y)).issubset(set(c.classes))
        assert len(c.classes) == 3
        assert c.n_train > 0 and len(c.test_y) > 0
    sizes = [c.n_train for c in clients]
    assert max(sizes) > min(sizes)  # unbalanced


def test_sample_batches_fixed_shape(fed):
    clients, *_ = fed
    rng = np.random.RandomState(0)
    for c in clients:
        b = sample_batches(c, rng, 6, 16)
        assert b["images"].shape[:2] == (6, 16)
        assert b["labels"].shape == (6, 16)


def test_async_persafl_improves_accuracy(fed):
    clients, params, loss, acc = fed
    ev = make_personalized_eval(loss, acc, clients, ft_steps=1, ft_lr=0.01)
    acc0 = ev(params)
    pcfg = PersAFLConfig(option="C", q_local=5, eta=0.01, lam=25.0,
                         inner_steps=5, inner_eta=0.02)
    sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(len(clients)),
                strategy="persafl", schedule=immediate(),
                batch_size=16, seed=0)
    hist = sim.run(max_rounds=60, eval_every=60, eval_fn=ev)
    assert hist.acc, "no eval recorded"
    assert hist.acc[-1] > acc0 + 0.1, (acc0, hist.acc)
    # staleness is recorded and non-negative
    assert all(s >= 0 for s in hist.staleness)
    assert int(sim.final_stats["server_rounds"]) == 60


def test_async_concurrency_exceeds_sync(fed):
    """Paper Figure 2a: async active-client ratio >> sync."""
    clients, params, loss, acc = fed
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.02)
    asim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                 pcfg=pcfg, delays=DelayModel(len(clients)),
                 strategy="persafl", schedule=immediate(),
                 batch_size=8, seed=0)
    ah = asim.run(max_rounds=30)
    ssim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                 pcfg=pcfg, delays=DelayModel(len(clients)),
                 strategy="fedavg", schedule=sync_barrier(3), batch_size=8,
                 seed=0)
    sh = ssim.run(max_rounds=6)
    a_ratio = float(np.mean(ah.active_ratio))
    s_ratio = float(np.mean(sh.active_ratio))
    assert a_ratio > s_ratio + 0.2, (a_ratio, s_ratio)
    assert a_ratio > 0.5


@pytest.mark.parametrize("algo", ["fedavg", "perfedavg", "pfedme", "fedprox",
                                  "scaffold"])
def test_sync_baselines_run(fed, algo):
    clients, params, loss, acc = fed
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.01, alpha=0.01,
                         lam=25.0, inner_steps=3, inner_eta=0.02,
                         maml_mode="full")
    sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(len(clients)),
                strategy=algo, schedule=sync_barrier(3), batch_size=8, seed=0)
    ev = make_personalized_eval(loss, acc, clients, ft_steps=1, ft_lr=0.02)
    hist = sim.run(max_rounds=4, eval_every=4, eval_fn=ev)
    assert hist.acc and np.isfinite(hist.acc[-1])


def test_staleness_grows_with_delay_spread(fed):
    """Assumption 1 diagnostics: wider delay spread -> larger max staleness."""
    clients, params, loss, _ = fed
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.01)

    def run(spread):
        dm = DelayModel(len(clients), seed=1,
                        down_range=(1.0, 1.0 + spread),
                        up_factor_range=(4.0, 4.0 + spread))
        sim = FLRun(clients=clients, loss_fn=loss,
                    init_params=params, pcfg=pcfg, delays=dm,
                    strategy="persafl", schedule=immediate(),
                    batch_size=8, seed=0)
        h = sim.run(max_rounds=40)
        return max(h.staleness)

    assert run(12.0) >= run(0.0)


def test_checkpoint_server_state_roundtrip(fed, tmp_path):
    from repro.checkpoint import load_server_state, save_server_state
    from repro.core import init_server_state
    clients, params, loss, _ = fed
    state = init_server_state(params)
    path = str(tmp_path / "state")
    save_server_state(path, state, meta={"note": "test"})
    back = load_server_state(path)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_optimizers_descend():
    from repro.optim import adam, momentum, sgd, apply_updates
    w = {"w": jnp.ones(4) * 5.0}
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for opt in (sgd(0.1), momentum(0.05), adam(0.3)):
        params = w
        state = opt.init(params)
        for _ in range(50):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(loss(params)) < 0.1 * float(loss(w))
