"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
sweeping shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_update import kernel as FK
from repro.kernels.fused_update import ref as FR
from repro.kernels.ssd.kernel import ssd_fwd
from repro.kernels.ssd.ref import ssd_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    # (B, S, Hq, Hkv, hd, block)
    (1, 128, 4, 4, 32, 64),      # MHA
    (2, 256, 4, 2, 64, 128),     # GQA 2:1
    (1, 256, 8, 1, 64, 64),      # MQA
    (2, 128, 2, 2, 128, 128),    # wide head
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype):
    B, S, Hq, Hkv, hd, blk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    out = flash_attention_fwd(q, k, v, block_q=blk, block_k=blk,
                              interpret=True)
    ref = attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_sliding_window(window):
    B, S, H, hd = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    out = flash_attention_fwd(q, k, v, window=window, block_q=64, block_k=64,
                              interpret=True)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_softcap():
    B, S, H, hd = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
    out = flash_attention_fwd(q, k, v, softcap=30.0, block_q=64, block_k=64,
                              interpret=True)
    ref = attention_ref(q, k, v, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD (mamba2)
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (B, S, H, P, G, N, chunk)
    (1, 64, 4, 16, 1, 16, 16),
    (2, 128, 6, 32, 2, 16, 32),
    (1, 128, 8, 64, 1, 32, 64),
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_kernel_matches_sequential_ref(shape):
    B, S, H, P, G, N, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, H))
    Bm = jax.random.normal(ks[2], (B, S, G, N))
    Cm = jax.random.normal(ks[3], (B, S, G, N))
    ref = ssd_ref(x, dt, a_log, Bm, Cm)
    out = ssd_fwd(x, dt, a_log, Bm, Cm, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4,
                               rtol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_dtypes(dtype):
    B, S, H, P, G, N = 1, 64, 4, 16, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    a_log = jnp.log(jnp.linspace(1.0, 8.0, H))
    Bm = jax.random.normal(ks[2], (B, S, G, N), dtype)
    Cm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    ref = ssd_ref(x, dt, a_log, Bm, Cm)
    out = ssd_fwd(x, dt, a_log, Bm, Cm, chunk=16, interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol,
                               rtol=tol)


def test_ssd_chunk_invariance():
    """Output must not depend on the chunking (the kernel's key invariant)."""
    B, S, H, P, G, N = 1, 128, 4, 16, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, H))
    Bm = jax.random.normal(ks[2], (B, S, G, N))
    Cm = jax.random.normal(ks[3], (B, S, G, N))
    outs = [np.asarray(ssd_fwd(x, dt, a_log, Bm, Cm, chunk=c, interpret=True))
            for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(17,), (1000, 257), (3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_sgd_step(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    w = jax.random.normal(ks[0], shape, dtype)
    g = jax.random.normal(ks[1], shape, dtype)
    out = FK.sgd_step(w, g, 0.01)
    ref = FR.sgd_step_ref(w, g, 0.01)
    assert out.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-6)


def test_fused_prox_chain_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    w = jax.random.normal(ks[0], (511,))
    th = jax.random.normal(ks[1], (511,))
    g = jax.random.normal(ks[2], (511,))
    np.testing.assert_allclose(
        np.asarray(FK.prox_inner(th, g, w, 0.02, 20.0)),
        np.asarray(FR.prox_inner_ref(th, g, w, 0.02, 20.0)), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(FK.prox_outer(w, th, 0.01, 20.0)),
        np.asarray(FR.prox_outer_ref(w, th, 0.01, 20.0)), atol=1e-6)


def test_fused_update_tree_ops():
    from repro.kernels.fused_update import ops
    tree = {"a": jnp.ones((64,)), "b": {"c": jnp.full((8, 8), 2.0)}}
    g = jax.tree.map(jnp.ones_like, tree)
    out = ops.sgd_step_tree(tree, g, 0.5, mode="ref")
    np.testing.assert_allclose(np.asarray(out["a"]), 0.5)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), 1.5)


@pytest.mark.parametrize("shape", [(17,), (1000, 257), (3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_apply_scaled(shape, dtype):
    """The server-apply kernel (traced scale in SMEM) vs the jnp oracle."""
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    w = jax.random.normal(ks[0], shape, dtype)
    d = jax.random.normal(ks[1], shape, dtype)
    out = FK.apply_scaled(w, d, 0.37)
    ref = FR.apply_scaled_ref(w, d, 0.37)
    assert out.dtype == w.dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)
    # the scale must stay traced — one compile serves every staleness value
    jit_apply = jax.jit(FK.apply_scaled)
    out2 = jit_apply(w, d, jnp.float32(1.8))
    ref2 = FR.apply_scaled_ref(w, d, 1.8)
    np.testing.assert_allclose(np.asarray(out2, np.float32),
                               np.asarray(ref2, np.float32), atol=5 * tol)


def test_fused_apply_delta_tree_matches_manual():
    from repro.kernels.fused_update import ops
    tree = {"a": jnp.ones((64,)), "b": {"c": jnp.full((8, 8), 2.0)}}
    d = jax.tree.map(jnp.ones_like, tree)
    out = ops.apply_delta_tree(tree, d, 0.25)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.75)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), 1.75)


@pytest.mark.parametrize("m", [1, 4, 32, 300])
@pytest.mark.parametrize("shape", [(17,), (1000, 257), (3, 5, 7)])
def test_fused_apply_rows_matches_ref(m, shape):
    """Stacked DeltaBank apply (row-chunked grid, f32 accumulation) vs the
    jnp oracle — m=300 exercises the output-revisiting multi-chunk path."""
    if m == 300 and shape == (1000, 257):
        pytest.skip("large interpret-mode case, covered by (17,)/(3,5,7)")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    w = jax.random.normal(ks[0], shape)
    d = jax.random.normal(ks[1], (m,) + shape)
    s = jax.random.normal(ks[2], (m,))
    out = FK.apply_rows(w, d, s)
    ref = FR.apply_rows_ref(w, d, s)
    assert out.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_apply_rows_dtypes_and_traced_weights(dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    w = jax.random.normal(ks[0], (513,), dtype)
    d = jax.random.normal(ks[1], (8, 513), dtype)
    s = jax.random.normal(ks[2], (8,))
    # weights must stay traced: one compile serves every flush composition
    out = jax.jit(FK.apply_rows)(w, d, s)
    ref = FR.apply_rows_ref(w, d, s)
    assert out.dtype == dtype
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_fused_apply_rows_masked_padding_rows_are_inert():
    """Zero-weight rows (bucket padding / non-buffered in-flight clients)
    must not leak into the apply, whatever garbage they hold."""
    w = jnp.ones((257,))
    d = jnp.stack([jnp.full((257,), 2.0),
                   jnp.full((257,), 123.0),   # padding rows: huge values
                   jnp.full((257,), -999.0)])
    out = FK.apply_rows(w, d, jnp.asarray([0.5, 0.0, 0.0]))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
    ref = FR.apply_rows_ref(w, d, jnp.asarray([0.5, 0.0, 0.0]))
    np.testing.assert_allclose(np.asarray(ref), 0.0, atol=1e-6)


def test_apply_rows_tree_matches_per_row_applies():
    """apply_rows_tree == sequential apply_delta_tree over the same rows."""
    from repro.kernels.fused_update import ops
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    tree = {"a": jax.random.normal(ks[0], (64,)),
            "b": {"c": jax.random.normal(ks[1], (8, 8))}}
    stack = jax.tree.map(
        lambda x: jax.random.normal(ks[2], (4,) + x.shape), tree)
    weights = jnp.asarray([0.1, 0.0, 0.3, 0.2])
    fused = ops.apply_rows_tree(tree, stack, weights)
    seq = tree
    for i, wgt in enumerate(np.asarray(weights)):
        row = jax.tree.map(lambda x: x[i], stack)
        seq = ops.apply_delta_tree(seq, row, float(wgt))
    for a, b in zip(jax.tree.leaves(fused), jax.tree.leaves(seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
