"""Unit tests for the PersA-FL core (Algorithms 1 & 2, Options A/B/C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PersAFLConfig, apply_buffered, apply_update,
                        client_update, init_server_state, maml_grad, me_grad,
                        personalize_me, solve_prox, split_batches_for_option)
from repro.core.server import staleness_stats


def quad_loss(w, batch):
    """f(w) = 0.5 ||A w - y||^2 / m  (smooth, known gradients)."""
    r = batch["a"] @ w["w"] - batch["y"]
    return 0.5 * jnp.mean(r ** 2)


@pytest.fixture(scope="module")
def quad():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (64, 8))
    xstar = jnp.arange(1.0, 9.0)
    return A, A @ xstar, xstar


def _batches(quad, q, seed=0):
    A, y, _ = quad
    idx = np.random.RandomState(seed).choice(64, q * 8).reshape(q, 8)
    return {"a": A[idx], "y": y[idx]}


def test_option_a_delta_telescopes(quad):
    """Δ from client_update == w0 - wQ of the naive Algorithm-2 loop."""
    pcfg = PersAFLConfig(option="A", q_local=4, eta=0.05)
    params = {"w": jnp.zeros(8)}
    batches = _batches(quad, 4)
    delta, _ = client_update(pcfg, quad_loss, params, batches)
    w = params
    for qi in range(4):
        b = jax.tree.map(lambda x: x[qi], batches)
        g = jax.grad(quad_loss)(w, b)
        w = jax.tree.map(lambda ww, gg: ww - pcfg.eta * gg, w, g)
    np.testing.assert_allclose(np.asarray(delta["w"]),
                               np.asarray(params["w"] - w["w"]), rtol=1e-5)


def test_maml_grad_matches_analytic_quadratic(quad):
    """For quadratic f, ∇F(w) = (I-αH) ∇f(w-α∇f(w)) exactly."""
    A, y, _ = quad
    batch = {"a": A, "y": y}
    w = {"w": jnp.ones(8) * 0.5}
    alpha = 0.1
    H = A.T @ A / 64
    g_w = H @ w["w"] - A.T @ y / 64
    adapted = w["w"] - alpha * g_w
    g_ad = H @ adapted - A.T @ y / 64
    expected = (jnp.eye(8) - alpha * H) @ g_ad
    got = maml_grad(quad_loss, w, batch, batch, batch, alpha, mode="full")
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(expected),
                               rtol=1e-4)


def test_maml_variants_approximate_full(quad):
    A, y, _ = quad
    batch = {"a": A, "y": y}
    w = {"w": jnp.ones(8) * 0.3}
    full = maml_grad(quad_loss, w, batch, batch, batch, 0.05, mode="full")
    fo = maml_grad(quad_loss, w, batch, batch, batch, 0.05, mode="fo")
    hf = maml_grad(quad_loss, w, batch, batch, batch, 0.05, mode="hf")
    full_v, fo_v, hf_v = (np.asarray(x["w"]) for x in (full, fo, hf))
    # hf (central difference of a quadratic) is exact up to fp error
    np.testing.assert_allclose(hf_v, full_v, rtol=1e-2, atol=1e-4)
    # fo drops the curvature term: close but not equal
    assert np.linalg.norm(fo_v - full_v) < 0.1 * np.linalg.norm(full_v) + 1e-3
    assert np.linalg.norm(fo_v - full_v) > 0


def test_me_prox_matches_closed_form(quad):
    """θ̂(w) = (H + λI)^{-1} (λ w + A^T y / m) for the quadratic."""
    A, y, _ = quad
    batch = {"a": A, "y": y}
    w = {"w": jnp.zeros(8)}
    lam = 20.0
    H = A.T @ A / 64
    theta_hat = jnp.linalg.solve(H + lam * jnp.eye(8),
                                 lam * w["w"] + A.T @ y / 64)
    theta, nu = solve_prox(quad_loss, w, batch, lam, inner_eta=0.04,
                           inner_steps=300)
    np.testing.assert_allclose(np.asarray(theta["w"]), np.asarray(theta_hat),
                               rtol=1e-3, atol=1e-3)
    assert float(nu) < 1e-2


def test_me_grad_is_lambda_scaled_displacement(quad):
    A, y, _ = quad
    batch = {"a": A, "y": y}
    w = {"w": jnp.ones(8)}
    lam = 25.0
    g, nu = me_grad(quad_loss, w, batch, lam, inner_eta=0.03, inner_steps=200)
    theta, _ = solve_prox(quad_loss, w, batch, lam, inner_eta=0.03,
                          inner_steps=200)
    np.testing.assert_allclose(np.asarray(g["w"]),
                               lam * np.asarray(w["w"] - theta["w"]),
                               rtol=1e-5)


def test_me_nu_decreases_with_inner_steps(quad):
    A, y, _ = quad
    batch = {"a": A, "y": y}
    w = {"w": jnp.ones(8)}
    nus = []
    for k in (1, 5, 25, 100):
        _, nu = me_grad(quad_loss, w, batch, 30.0, inner_eta=0.02,
                        inner_steps=k)
        nus.append(float(nu))
    assert nus == sorted(nus, reverse=True)
    assert nus[-1] < 0.05 * nus[0]  # geometric: (λ−L)-strong convexity


def test_server_apply_and_staleness():
    state = init_server_state({"w": jnp.zeros(4)})
    delta = {"w": jnp.ones(4)}
    state = apply_update(state, delta, beta=0.5, staleness=3)
    state = apply_update(state, delta, beta=0.5, staleness=1)
    np.testing.assert_allclose(np.asarray(state["params"]["w"]), -1.0)
    stats = staleness_stats(state)
    assert int(stats["server_rounds"]) == 2
    assert int(stats["max_staleness"]) == 3
    assert float(stats["mean_staleness"]) == 2.0


def test_buffered_apply_matches_mean_of_singles():
    params = {"w": jnp.zeros(4)}
    d1, d2 = {"w": jnp.ones(4)}, {"w": 3 * jnp.ones(4)}
    s_buf = apply_buffered(init_server_state(params),
                           {"w": d1["w"] + d2["w"]},
                           jnp.asarray(2), beta=1.0, staleness_max=2)
    np.testing.assert_allclose(np.asarray(s_buf["params"]["w"]), -2.0)
    assert int(s_buf["t"]) == 2


def test_buffered_apply_accounts_staleness_sum():
    """Regression: t advances by M per flush, so the buffer's Σ τ must enter
    staleness_sum or mean_staleness under-reports for buffered runs."""
    state = init_server_state({"w": jnp.zeros(4)})
    state = apply_buffered(state, {"w": jnp.ones(4)}, jnp.asarray(3),
                           beta=1.0, staleness_max=4, staleness_sum=2 + 4 + 0)
    state = apply_buffered(state, {"w": jnp.ones(4)}, jnp.asarray(3),
                           beta=1.0, staleness_max=2, staleness_sum=1 + 2 + 0)
    stats = staleness_stats(state)
    assert int(stats["server_rounds"]) == 6
    assert int(stats["max_staleness"]) == 4
    assert float(stats["mean_staleness"]) == pytest.approx(9 / 6)


def test_apply_buffered_rows_matches_summed_apply():
    """The stacked-buffer overload == apply_buffered on the summed deltas,
    with β/M+damping folded into the row weights and padding rows masked."""
    from repro.core import apply_buffered_rows
    params = {"w": jnp.zeros(4)}
    stack = {"w": jnp.stack([jnp.ones(4), 3 * jnp.ones(4),
                             999.0 * jnp.ones(4)])}   # row 2 = padding
    weights = jnp.asarray([0.5, 0.5, 0.0])            # β/M with β=1, M=2
    s_rows = apply_buffered_rows(init_server_state(params), stack, weights,
                                 jnp.asarray(2), staleness_max=2,
                                 staleness_sum=3.0)
    s_ref = apply_buffered(init_server_state(params),
                           {"w": jnp.ones(4) + 3 * jnp.ones(4)},
                           jnp.asarray(2), beta=1.0, staleness_max=2,
                           staleness_sum=3.0)
    np.testing.assert_allclose(np.asarray(s_rows["params"]["w"]),
                               np.asarray(s_ref["params"]["w"]), rtol=1e-6)
    assert int(s_rows["t"]) == int(s_ref["t"]) == 2
    stats = staleness_stats(s_rows)
    assert int(stats["max_staleness"]) == 2
    assert float(stats["mean_staleness"]) == pytest.approx(1.5)


def test_apply_update_staleness_damping():
    """a>0 discounts the server step by (1+tau)^-a (FedAsync-style)."""
    state = init_server_state({"w": jnp.zeros(2)})
    state = apply_update(state, {"w": jnp.ones(2)}, beta=1.0, staleness=3,
                         damping=1.0)
    np.testing.assert_allclose(np.asarray(state["params"]["w"]), -0.25,
                               rtol=1e-6)


def test_split_batches_layout():
    b3q = {"x": jnp.arange(12).reshape(6, 2)}
    a = split_batches_for_option("A", b3q)
    assert a["x"].shape == (2, 2)
    b = split_batches_for_option("B", b3q)
    assert set(b) == {"d", "dp", "dpp"}
    np.testing.assert_array_equal(np.asarray(b["dpp"]["x"]),
                                  np.arange(8, 12).reshape(2, 2))


@pytest.mark.parametrize("option", ["A", "B", "C"])
def test_all_options_descend_on_quadratic(quad, option):
    A, y, xstar = quad
    pcfg = PersAFLConfig(option=option, q_local=5, eta=0.05, alpha=0.05,
                         lam=20.0, inner_steps=30, inner_eta=0.02,
                         maml_mode="full")
    state = init_server_state({"w": jnp.zeros(8)})
    for t in range(60):
        b3q = _batches(quad, 15, seed=t)
        batches = split_batches_for_option(option, b3q)
        delta, _ = client_update(pcfg, quad_loss, state["params"], batches)
        state = apply_update(state, delta, pcfg.beta, staleness=0)
    err = float(jnp.linalg.norm(state["params"]["w"] - xstar))
    assert err < 0.5, f"option {option} err={err}"


def test_personalize_me_moves_toward_local_optimum(quad):
    A, y, _ = quad
    batch = {"a": A, "y": y}
    w = {"w": jnp.zeros(8)}
    theta = personalize_me(quad_loss, w, batch, lam=10.0, inner_eta=0.03,
                           inner_steps=100)
    assert quad_loss(theta, batch) < quad_loss(w, batch)
