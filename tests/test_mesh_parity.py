"""2-D ("cohort", "model") mesh parity (PR 10 tentpole).

The mesh is a *layout* choice, never a semantics choice: an FLRun or a
PersonalizationServer driven on the 1-D ``("cohort",)`` mesh, the 2-D
``(8, 1)`` mesh (degenerate model axis) and the 2-D ``(2, 4)`` mesh
(model-sharded storage) must produce bit-identical params, histories and
served heads.  The engine guarantees this by construction — cohort
compute runs full-Manual with model-replicated params; the model axis
only re-homes storage (bank rows, snapshots, params at rest) after the
fact — and this suite pins the contract on a forced 8-virtual-device
split via the same subprocess re-exec pattern as
``tests/test_sharded_engine.py``.

In-process (any device count): mesh memoization (PR 10 satellite — one
mesh object per layout, ``reset_mesh_cache`` as the one invalidation
point), ``cohort_model_mesh`` validation, and the ``use_mesh`` context.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.sharding.ctx import (active_mesh, cohort_axis_size, cohort_mesh,
                                cohort_model_mesh, reset_mesh_cache,
                                use_mesh)


# -- mesh memoization + validation (in-process, any device count) -----------

def test_cohort_mesh_is_memoized():
    reset_mesh_cache()
    m1 = cohort_mesh()
    assert cohort_mesh() is m1
    # the two spellings of the 1-D mesh share one cache entry
    assert cohort_model_mesh(None) is m1


def test_reset_mesh_cache_invalidates():
    from repro.sharding import ctx
    reset_mesh_cache()
    m1 = cohort_mesh()
    assert len(ctx._MESH_CACHE) == 1
    reset_mesh_cache()
    assert len(ctx._MESH_CACHE) == 0
    # note: jax may intern equal Mesh objects, so the re-built mesh can be
    # the same object — the contract is the CACHE was dropped and rebuilt
    m2 = cohort_mesh()
    assert len(ctx._MESH_CACHE) == 1
    assert m2.axis_names == m1.axis_names
    assert m2.devices.shape == m1.devices.shape


def test_engines_share_one_mesh_object():
    """Two engines constructed without an explicit mesh= land on the SAME
    memoized mesh — jit caches and NamedSharding equality key on mesh
    identity, so a fresh mesh per engine defeated both."""
    import jax.numpy as jnp
    from repro.core import PersAFLConfig
    from repro.fl import CohortEngine
    reset_mesh_cache()
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.05)
    loss = lambda p, b: 0.5 * jnp.mean((b["a"] @ p["w"] - b["y"]) ** 2)
    e1 = CohortEngine(pcfg, loss, cohort_impl="shard_map")
    e2 = CohortEngine(pcfg, loss, cohort_impl="shard_map")
    assert e1._mesh is e2._mesh


def test_cohort_model_mesh_validates_divisibility():
    n = jax.device_count()
    with pytest.raises(ValueError, match="divide"):
        cohort_model_mesh(n + 1)
    with pytest.raises(ValueError, match="divide"):
        cohort_model_mesh(0)


def test_cohort_model_mesh_degenerate_axis():
    m = cohort_model_mesh(1)
    assert m.axis_names == ("cohort", "model")
    assert m.devices.shape == (jax.device_count(), 1)
    assert cohort_axis_size(m) == jax.device_count()
    # memoized per layout: (n,1) and the 1-D mesh are distinct entries
    assert cohort_model_mesh(1) is m
    assert m is not cohort_mesh()


def test_use_mesh_context_installs_and_restores():
    assert active_mesh() is None
    m = cohort_mesh()
    with use_mesh(m):
        assert active_mesh() is m
        # engines constructed inside the context pick it up
        import jax.numpy as jnp
        from repro.core import PersAFLConfig
        from repro.fl import CohortEngine
        e = CohortEngine(PersAFLConfig(option="A", q_local=2, eta=0.05),
                         lambda p, b: jnp.sum(p["w"]),
                         cohort_impl="shard_map")
        assert e._mesh is m
    assert active_mesh() is None


# -- 8-virtual-device bit-parity (subprocess re-exec) ------------------------

def _run_subproc(body: str, timeout: int = 540) -> str:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", body], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


_FLRUN_PARITY = textwrap.dedent("""
    import jax, numpy as np, jax.numpy as jnp
    assert jax.device_count() == 8, jax.device_count()
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import PersAFLConfig
    from repro.data.federated import ClientData
    from repro.fl import DelayModel, FLRun, buffered
    from repro.sharding.ctx import (cohort_axis_size, cohort_mesh,
                                    cohort_model_mesh)

    def loss(p, b):
        logits = b["images"] @ p["w"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 4) * logp, -1))

    rng = np.random.RandomState(0)
    clients = []
    for _ in range(6):
        x = rng.randn(64, 8).astype(np.float32)
        y = rng.randint(0, 4, 64).astype(np.int32)
        clients.append(ClientData(train_x=x, train_y=y, test_x=x[:8],
                                  test_y=y[:8], classes=(0, 1, 2, 3)))
    params = {"w": jnp.zeros((8, 4))}
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.05)

    def drive(mesh, shardings=None):
        run = FLRun(clients=clients, loss_fn=loss, init_params=params,
                    pcfg=pcfg, delays=DelayModel(6, seed=1),
                    strategy="persafl", schedule=buffered(2), batch_size=8,
                    seed=0, cohort_impl="shard_map", mesh=mesh,
                    param_shardings=shardings)
        hist = run.run(max_rounds=4)
        return run, hist

    r1, h1 = drive(cohort_mesh())                       # 1-D ("cohort",)
    m81 = cohort_model_mesh(1)                          # (8, 1)
    assert cohort_axis_size(m81) == 8
    r81, h81 = drive(m81)
    m24 = cohort_model_mesh(4)                          # (2, 4)
    assert cohort_axis_size(m24) == 2
    sh = {"w": NamedSharding(m24, P(None, "model"))}
    r24, h24 = drive(m24, sh)

    a = np.asarray(r1.state.params["w"])
    for tag, r, h in (("(8,1)", r81, h81), ("(2,4)", r24, h24)):
        assert np.array_equal(a, np.asarray(r.state.params["w"])), tag
        assert h.staleness == h1.staleness, tag
        assert h.times == h1.times and h.rounds == h1.rounds, tag
    # the 2-D run's params stay model-sharded after every server apply
    spec = r24.state.params["w"].sharding.spec
    assert "model" in jax.tree.leaves(tuple(spec)), spec
    assert r24.engine.stats["host_materializations"] == 0
    print("FLRUN-PARITY-OK")
""")


_SERVE_PARITY = textwrap.dedent("""
    import jax, numpy as np, jax.numpy as jnp
    assert jax.device_count() == 8, jax.device_count()
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import PersAFLConfig
    from repro.serving.server import PersonalizationServer
    from repro.sharding.ctx import cohort_mesh, cohort_model_mesh

    rng = np.random.RandomState(0)
    d, classes = 64, 64
    params = {"w": jnp.asarray(rng.randn(d, classes) * 0.1, jnp.float32),
              "b": jnp.zeros((classes,), jnp.float32)}

    def loss(p, b):
        logits = b["images"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(
            jax.nn.one_hot(b["labels"], classes) * logp, -1))

    pcfg = PersAFLConfig(option="C", eta=0.05, alpha=0.05, lam=20.0,
                         inner_steps=2, inner_eta=0.02)
    # crc32-balanced user ids: distinct residues mod 8 AND 2/2 mod 2, so
    # both the 1-D (8-slice) and the 2x4 (2-slice) batcher keyings bucket
    # them without cross-slice collisions
    users = ["user000", "user004", "user003", "user007"]
    batches = {u: {"images": jnp.asarray(rng.randn(8, d), jnp.float32),
                   "labels": jnp.asarray(rng.randint(0, classes, 8),
                                         jnp.int32)}
               for u in users}

    def per_device_bytes(srv):
        dev = {}
        def add(x):
            if not hasattr(x, "addressable_shards"):
                return
            for s in x.addressable_shards:
                dev[s.device.id] = dev.get(s.device.id, 0) + s.data.nbytes
        for banks in srv.ring._banks.values():
            for bank in banks:
                jax.tree.map(add, bank.stacked)
        for snap in srv.ring._snapshots.values():
            jax.tree.map(add, snap)
        jax.tree.map(add, srv.params)
        return dev

    def drive(mesh, shardings, windows=4):
        srv = PersonalizationServer(params, loss, pcfg, windows=windows,
                                    cohort_impl="shard_map", mesh=mesh,
                                    param_shardings=shardings)
        heads = {}
        for w in range(windows):        # fill the ring to steady state
            tickets = {u: srv.submit(u, batches[u], mode="C")
                       for u in users}
            srv.flush()
            heads = {u: jax.tree.map(np.asarray, srv.poll(t))
                     for u, t in tickets.items()}
            srv.advance_window()
        return srv, heads

    srv1, h1 = drive(cohort_mesh(), None)
    m24 = cohort_model_mesh(4)
    sh = {"w": NamedSharding(m24, P(None, "model")),
          "b": NamedSharding(m24, P("model"))}
    srv2, h2 = drive(m24, sh)

    p1 = jax.tree.map(np.asarray, srv1.params)
    p2 = jax.tree.map(np.asarray, srv2.params)
    for k in p1:
        assert np.array_equal(p1[k], p2[k]), k
    for u in users:
        for k in h1[u]:
            assert np.array_equal(h1[u][k], h2[u][k]), (u, k)
    # steady-state serving never materializes a bank to the host
    assert srv1.stats["host_materializations"] == 0
    assert srv2.stats["host_materializations"] == 0
    # the 2-D server's params remain model-sharded after window advances
    spec = srv2.params["w"].sharding.spec
    assert "model" in jax.tree.leaves(tuple(spec)), spec
    # model-sharded storage: per-device peak delta/head/snapshot residency
    # on the 2x4 mesh is <= 0.6x the 1-D peak at equal users (the ISSUE
    # acceptance gate; measured ~0.39)
    peak1 = max(per_device_bytes(srv1).values())
    peak2 = max(per_device_bytes(srv2).values())
    ratio = peak2 / peak1
    assert ratio <= 0.6, (peak1, peak2, ratio)
    print("RESIDENCY-RATIO", round(ratio, 4))
    print("SERVE-PARITY-OK")
""")


def test_flrun_bit_parity_across_mesh_layouts():
    """FLRun histories + final params bit-equal on 1-D / (8,1) / (2,4)."""
    out = _run_subproc(_FLRUN_PARITY)
    assert "FLRUN-PARITY-OK" in out


def test_serving_bit_parity_and_residency_across_mesh_layouts():
    """Served heads + params bit-equal 1-D vs 2x4; zero host
    materializations; model-sharded storage cuts per-device peak
    residency to <= 0.6x the 1-D baseline."""
    out = _run_subproc(_SERVE_PARITY)
    assert "SERVE-PARITY-OK" in out
