"""Quantized delta banking + compressed wire (int8 codec, error feedback).

The apply_rows_q kernel against its jnp oracle (pow2/non-pow2 cohorts,
all-padding rows, bf16 weights), the oracle against dequant-then-apply,
quantizer error bounds, the error-feedback recurrence keeping the running
quantized sum near the fp32 sum over many windows (hypothesis when
available, a seeded sweep otherwise), the wire codec (int8 bodies
self-describing and smaller, non-float dtypes exact), the npz dtype
regression (bf16 through encode/decode AND save/load_pytree), quantized
serving end-to-end (lazy heads ≈ fp32 twin, residency ≥ 3.5x smaller,
stragglers, zero host materializations), transport codec negotiation, and
bit-exact save/restore of quantized snapshots + residuals.
"""
import asyncio
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import load_pytree, save_pytree
from repro.core import PersAFLConfig, init_server_state
from repro.core.quant import (QuantStack, QuantTree, QuantizedBank,
                              QuantizedHeads, dequantize_stack,
                              dequantize_tree, ef_quantize_stack,
                              fp32_row_nbytes, quantize_stack,
                              quantize_tree)
from repro.core.server import apply_admitted_rows
from repro.kernels.fused_update.kernel import apply_rows_q
from repro.kernels.fused_update.ops import apply_rows_q_tree
from repro.kernels.fused_update.ref import apply_rows_q_ref, apply_rows_ref
from repro.serving import PersonalizationServer
from repro.serving.transport import (AsyncTransportClient, TransportServer,
                                     decode_pytree, encode_pytree)


def _quant_leaves(stack):
    qs = quantize_stack(stack)
    return (jax.tree.leaves(qs.q)[0], jax.tree.leaves(qs.scales)[0])


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,shape", [(1, (33,)), (3, (128, 7)),
                                     (5, (1000,)), (8, (64, 64)),
                                     (32, (257,))])
def test_apply_rows_q_matches_oracle(m, shape):
    rng = np.random.RandomState(m)
    w = jnp.asarray(rng.randn(*shape).astype(np.float32))
    stack = jnp.asarray(0.01 * rng.randn(m, *shape).astype(np.float32))
    q, sc = _quant_leaves(stack)
    weights = jnp.asarray(rng.rand(m).astype(np.float32))
    got = apply_rows_q(w, q, sc, weights, interpret=True)
    want = apply_rows_q_ref(w, q, sc, weights)
    assert got.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=0)


def test_apply_rows_q_bf16_params():
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(96).astype(np.float32)).astype(jnp.bfloat16)
    stack = jnp.asarray(0.01 * rng.randn(4, 96).astype(np.float32))
    q, sc = _quant_leaves(stack)
    weights = jnp.asarray(rng.rand(4).astype(np.float32))
    got = apply_rows_q(w, q, sc, weights, interpret=True)
    want = apply_rows_q_ref(w, q, sc, weights)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=1e-2)


def test_apply_rows_q_all_padding_rows_identity():
    """Zero weights on zero rows (the pow2 bucket padding) leave w as-is."""
    w = jnp.arange(50, dtype=jnp.float32)
    q = jnp.zeros((4, 50), jnp.int8)
    sc = jnp.zeros((4,), jnp.float32)
    weights = jnp.zeros((4,), jnp.float32)
    got = apply_rows_q(w, q, sc, weights, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))


def test_apply_rows_q_ref_is_dequant_then_apply():
    """The quantized oracle == dequantize + the fp32 rows oracle."""
    rng = np.random.RandomState(1)
    w = jnp.asarray(rng.randn(64).astype(np.float32))
    stack = jnp.asarray(0.02 * rng.randn(6, 64).astype(np.float32))
    qs = quantize_stack(stack)
    q, sc = jax.tree.leaves(qs.q)[0], jax.tree.leaves(qs.scales)[0]
    weights = jnp.asarray(rng.rand(6).astype(np.float32))
    got = apply_rows_q_ref(w, q, sc, weights)
    deq = jax.tree.leaves(dequantize_stack(qs))[0]
    want = apply_rows_ref(w, deq, weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_apply_rows_q_tree_modes_agree():
    rng = np.random.RandomState(2)
    w = {"a": jnp.asarray(rng.randn(40).astype(np.float32)),
         "b": jnp.asarray(rng.randn(8, 5).astype(np.float32))}
    stack = jax.tree.map(
        lambda x: jnp.asarray(0.01 * rng.randn(3, *x.shape)
                              .astype(np.float32)), w)
    qs = quantize_stack(stack)
    weights = jnp.asarray(rng.rand(3).astype(np.float32))
    got_k = apply_rows_q_tree(w, qs.q, qs.scales, weights, mode="kernel")
    got_r = apply_rows_q_tree(w, qs.q, qs.scales, weights, mode="ref")
    for a, b in zip(jax.tree.leaves(got_k), jax.tree.leaves(got_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_apply_admitted_rows_dispatches_quant_stack():
    """A QuantStack delta bank applies without materializing fp32 rows and
    matches the fp32 apply of the dequantized stack."""
    rng = np.random.RandomState(3)
    params = {"w": jnp.asarray(rng.randn(30, 4).astype(np.float32))}
    stack = {"w": jnp.asarray(0.05 * rng.randn(4, 30, 4)
                              .astype(np.float32))}
    qs = quantize_stack(stack)
    weights = jnp.asarray([0.2, 0.3, 0.0, 0.1], jnp.float32)
    s_q = apply_admitted_rows(init_server_state(params), qs, weights, 3, 1)
    s_f = apply_admitted_rows(init_server_state(params),
                              dequantize_stack(qs), weights, 3, 1)
    np.testing.assert_allclose(np.asarray(s_q.params["w"]),
                               np.asarray(s_f.params["w"]), atol=1e-6)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >1 device for a sharded stack")
def test_apply_admitted_rows_quant_sharded_stack():
    """A QuantStack whose leaves span devices takes the ref path (Pallas
    interpret can't trace through shard_map) and matches single-device."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    rng = np.random.RandomState(4)
    ndev = jax.device_count()
    params = {"w": jnp.asarray(rng.randn(64).astype(np.float32))}
    stack = {"w": jnp.asarray(0.05 * rng.randn(ndev, 64)
                              .astype(np.float32))}
    qs = quantize_stack(stack)
    mesh = Mesh(np.array(jax.devices()), ("cohort",))
    sharded = QuantStack(
        q=jax.device_put(qs.q, NamedSharding(mesh, P("cohort"))),
        scales=jax.device_put(qs.scales, NamedSharding(mesh, P("cohort"))))
    weights = jnp.asarray(rng.rand(ndev).astype(np.float32))
    s_sh = apply_admitted_rows(init_server_state(params), sharded,
                               weights, ndev, 0)
    s_1d = apply_admitted_rows(init_server_state(params), qs,
                               weights, ndev, 0)
    np.testing.assert_allclose(np.asarray(s_sh.params["w"]),
                               np.asarray(s_1d.params["w"]), atol=1e-6)


# ---------------------------------------------------------------------------
# quantizer + error feedback
# ---------------------------------------------------------------------------

def test_quantize_stack_error_bound_and_zero_rows_exact():
    rng = np.random.RandomState(5)
    stack = {"x": jnp.asarray(
        np.concatenate([rng.randn(3, 17), np.zeros((2, 17))])
        .astype(np.float32))}
    qs = quantize_stack(stack)
    assert jax.tree.leaves(qs.q)[0].dtype == jnp.int8
    deq = jax.tree.leaves(dequantize_stack(qs))[0]
    x = np.asarray(stack["x"])
    for i in range(3):   # symmetric absmax: error ≤ scale/2 per element
        bound = np.max(np.abs(x[i])) / 127.0 * 0.500001
        assert np.max(np.abs(np.asarray(deq)[i] - x[i])) <= bound
    np.testing.assert_array_equal(np.asarray(deq)[3:], x[3:])  # zeros exact


def test_quantize_tree_roundtrip_bound():
    rng = np.random.RandomState(6)
    tree = {"w": jnp.asarray(rng.randn(9, 3).astype(np.float32)),
            "b": jnp.zeros((3,), jnp.float32)}
    qt = quantize_tree(tree)
    assert isinstance(qt, QuantTree)
    deq = dequantize_tree(qt)
    err = float(jnp.max(jnp.abs(deq["w"] - tree["w"])))
    assert err <= float(jnp.max(jnp.abs(tree["w"]))) / 127.0 * 0.500001
    np.testing.assert_array_equal(np.asarray(deq["b"]),
                                  np.asarray(tree["b"]))


def _ef_drift(seed: int, windows: int, n: int) -> float:
    """Max |Σ dequant(quant_EF(delta)) − Σ delta| after ``windows`` EF
    steps, relative to the quantization step of one window."""
    rng = np.random.RandomState(seed)
    exact = np.zeros(n, np.float32)
    applied = np.zeros(n, np.float32)
    residual = None
    step = 0.0
    for _ in range(windows):
        raw = {"x": jnp.asarray(0.1 * rng.randn(1, n).astype(np.float32))}
        qs, res_q = ef_quantize_stack(raw, residual)
        residual = dequantize_stack(res_q)  # stored int8, fed back as fp32
        deq = np.asarray(jax.tree.leaves(dequantize_stack(qs))[0][0])
        exact += np.asarray(raw["x"])[0]
        applied += deq
        step = max(step, float(np.max(np.abs(np.asarray(raw["x"])))) / 127)
    return float(np.max(np.abs(applied - exact))) / max(step, 1e-12)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 16), st.integers(4, 24),
           st.integers(8, 200))
    def test_ef_running_sum_stays_bounded(seed, windows, n):
        # without EF the worst case drifts ~windows/2 steps; WITH EF the
        # carried residual keeps the total within ~2 steps regardless of
        # window count (1 step current error + quantized-residual dust)
        assert _ef_drift(seed, windows, n) <= 2.0
except ImportError:     # hypothesis is a dev extra — seeded sweep fallback
    @pytest.mark.parametrize("seed,windows,n",
                             [(0, 4, 8), (1, 12, 64), (2, 24, 200),
                              (3, 16, 33), (4, 20, 128)])
    def test_ef_running_sum_stays_bounded(seed, windows, n):
        assert _ef_drift(seed, windows, n) <= 2.0


def test_ef_beats_plain_quantization_over_windows():
    """The point of the residual: cumulative EF error stays ~flat while
    plain re-quantization error can accumulate with window count."""
    rng = np.random.RandomState(7)
    n, windows = 64, 32
    bias = 0.004 * rng.randn(n).astype(np.float32)  # sub-step per-window
    exact = np.zeros(n, np.float32)
    plain = np.zeros(n, np.float32)
    ef = np.zeros(n, np.float32)
    residual = None
    for _ in range(windows):
        raw = (bias + 0.001 * rng.randn(n).astype(np.float32)) \
            .astype(np.float32)
        # force a coarse shared scale: one large element dominates absmax
        row = np.concatenate([raw, [1.0]]).astype(np.float32)[None]
        tree = {"x": jnp.asarray(row)}
        exact += raw
        deq_p = np.asarray(jax.tree.leaves(
            dequantize_stack(quantize_stack(tree)))[0][0][:n])
        plain += deq_p
        qs, res_q = ef_quantize_stack(tree, residual)
        residual = dequantize_stack(res_q)
        ef += np.asarray(jax.tree.leaves(
            dequantize_stack(qs))[0][0][:n])
    err_plain = float(np.max(np.abs(plain - exact)))
    err_ef = float(np.max(np.abs(ef - exact)))
    # sub-step deltas vanish without EF (quantize to 0 every window)
    assert err_plain > 5 * err_ef


def test_quantized_bank_handles():
    rng = np.random.RandomState(8)
    stack = {"w": jnp.asarray(rng.randn(4, 6).astype(np.float32))}
    qs = quantize_stack(stack)
    bank = QuantizedBank(qs, k=3)
    assert bank.capacity == 4 and len(bank) == 3
    rows = bank.rows(jnp.asarray([0, 2], jnp.int32))
    deq = dequantize_stack(qs)
    np.testing.assert_allclose(np.asarray(rows["w"][1]),
                               np.asarray(deq["w"][2]), atol=1e-7)
    assert fp32_row_nbytes(qs) == 6 * 4
    snap = {"w": jnp.asarray(rng.randn(6).astype(np.float32))}
    heads = QuantizedHeads(snap, bank)
    head0 = heads.row(0)
    np.testing.assert_allclose(
        np.asarray(head0["w"]),
        np.asarray(snap["w"] - deq["w"][0]), atol=1e-6)


# ---------------------------------------------------------------------------
# wire codec + npz dtype regression (satellite bugfix)
# ---------------------------------------------------------------------------

def test_wire_codec_int8_roundtrip_and_size():
    rng = np.random.RandomState(9)
    tree = {"x": rng.randn(32, 64).astype(np.float32),
            "y": rng.randint(0, 10, 32).astype(np.int32)}
    b32 = encode_pytree(tree)
    b8 = encode_pytree(tree, codec="int8")
    assert len(b8) < len(b32) / 2
    dec32 = decode_pytree(b32)
    np.testing.assert_array_equal(dec32["x"], tree["x"])  # fp32 bit-exact
    dec8 = decode_pytree(b8)
    np.testing.assert_array_equal(dec8["y"], tree["y"])   # ints exact
    assert dec8["y"].dtype == np.int32
    bound = np.max(np.abs(tree["x"])) / 127.0 * 0.500001
    assert np.max(np.abs(dec8["x"] - tree["x"])) <= bound


def test_wire_codec_rejects_unknown():
    with pytest.raises(ValueError):
        encode_pytree({"x": np.zeros(3, np.float32)}, codec="int4")


def test_npz_roundtrip_preserves_nonfloat_dtypes():
    """Regression (pre-fix failing): ml_dtypes leaves came back as raw
    void records (dtype ``|V2``) from npz; int8/uint8 must stay exact."""
    import ml_dtypes
    rng = np.random.RandomState(10)
    tree = {"i8": rng.randint(-127, 127, (5, 3)).astype(np.int8),
            "u8": rng.randint(0, 255, (4,)).astype(np.uint8),
            "bf16": rng.randn(6).astype(ml_dtypes.bfloat16),
            "f32": rng.randn(2, 2).astype(np.float32)}
    for codec in ("fp32", "int8"):
        dec = decode_pytree(encode_pytree(tree, codec=codec))
        for key in ("i8", "u8", "bf16"):
            assert dec[key].dtype == tree[key].dtype, (codec, key)
            np.testing.assert_array_equal(
                dec[key].view(np.uint8), tree[key].view(np.uint8))


def test_save_pytree_preserves_nonfloat_dtypes(tmp_path):
    import ml_dtypes
    rng = np.random.RandomState(11)
    tree = {"q": rng.randint(-127, 127, (3, 4)).astype(np.int8),
            "h": rng.randn(5).astype(ml_dtypes.bfloat16),
            "s": np.float32(0.25)}
    path = os.path.join(tmp_path, "ck")
    save_pytree(path, tree)
    back = load_pytree(path)
    assert back["q"].dtype == np.int8
    np.testing.assert_array_equal(back["q"], tree["q"])
    assert back["h"].dtype == tree["h"].dtype
    np.testing.assert_array_equal(back["h"].view(np.uint16),
                                  tree["h"].view(np.uint16))


# ---------------------------------------------------------------------------
# quantized serving end-to-end
# ---------------------------------------------------------------------------

def _loss(p, b):
    logits = b["x"] @ p["w"] + p["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(jax.nn.one_hot(b["y"], 4) * logp, -1))


def _params(seed=0, d=40):
    rng = np.random.RandomState(seed)
    return {"w": jnp.asarray(0.1 * rng.randn(d, 4).astype(np.float32)),
            "b": jnp.zeros((4,), jnp.float32)}


def _batch(seed, d=40, n=8):
    rng = np.random.RandomState(seed)
    return {"x": rng.randn(n, d).astype(np.float32),
            "y": rng.randint(0, 4, n).astype(np.int32)}


_PCFG = PersAFLConfig(option="C", lam=20.0, inner_steps=5,
                      inner_eta=0.05, beta=0.5)


def _drive(delta_dtype, windows=3, users=6):
    srv = PersonalizationServer(_params(), _loss, _PCFG, modes=("C",),
                                windows=4, max_pending=64,
                                delta_dtype=delta_dtype)
    heads = {}
    for w in range(windows):
        tickets = [srv.submit(f"u{i}", _batch(100 * w + i))
                   for i in range(users)]
        srv.flush()
        for i, t in enumerate(tickets):
            heads[f"u{i}"] = srv.poll(t)
        srv.advance_window()
    return srv, heads


def test_quant_serving_matches_fp32_twin():
    s32, h32 = _drive("fp32")
    s8, h8 = _drive("int8")
    for user in h32:
        for key in h32[user]:
            np.testing.assert_allclose(np.asarray(h8[user][key]),
                                       np.asarray(h32[user][key]),
                                       atol=0.05)
    assert s8.stats["host_materializations"] == 0
    assert s8.stats["delta_codec"] == "int8"
    assert s32.stats["delta_codec"] == "fp32"
    assert s32.stats["ring_bytes_saved_per_user"] == 0
    # global params track the fp32 server (EF keeps noise a residual)
    for key in s32.params:
        np.testing.assert_allclose(np.asarray(s8.params[key]),
                                   np.asarray(s32.params[key]), atol=5e-3)


def test_quant_serving_residency_ratio():
    s8, _ = _drive("int8")
    st = s8.stats
    assert st["ring_bytes_per_user"] * 3.5 <= st["ring_bytes_per_user_fp32"]
    assert st["ring_bytes_saved_per_user"] == (
        st["ring_bytes_per_user_fp32"] - st["ring_bytes_per_user"])


def test_quant_serving_head_and_stacked_heads():
    s8, h8 = _drive("int8")
    again = s8.head("u0")
    for key in again:
        np.testing.assert_array_equal(np.asarray(again[key]),
                                      np.asarray(h8["u0"][key]))
    stacked = s8.stacked_heads(["u0", "u1"])
    for key in stacked:
        np.testing.assert_array_equal(np.asarray(stacked[key][0]),
                                      np.asarray(h8["u0"][key]))


def test_quant_serving_straggler_window_boundary():
    srv = PersonalizationServer(_params(), _loss, _PCFG, modes=("C",),
                                windows=4, delta_dtype="int8")
    t1 = srv.submit("s1", _batch(1))
    srv.advance_window(flush=False)          # t1 becomes a straggler
    t2 = srv.submit("s2", _batch(2))
    srv.flush()
    assert srv.poll(t1) is not None and srv.poll(t2) is not None
    srv.advance_window()
    assert srv.stats["ring_stragglers"] == 1
    assert srv.stats["host_materializations"] == 0


def test_quant_serving_snapshot_demotion():
    srv, _ = _drive("int8", windows=3)
    snaps = srv.ring._snapshots
    current = srv.ring.current
    assert not isinstance(snaps[current], QuantTree)   # fresh stays fp32
    assert any(isinstance(s, QuantTree) for w, s in snaps.items()
               if w < current)


def test_quant_save_restore_bit_exact(tmp_path):
    srv, heads = _drive("int8")
    path = os.path.join(tmp_path, "ck")
    srv.save(path)
    back = PersonalizationServer.restore(path, _loss, _PCFG, modes=("C",))
    assert back.delta_dtype == "int8"
    for user in heads:
        a, b = srv.head(user), back.head(user)
        for key in a:
            np.testing.assert_array_equal(np.asarray(a[key]),
                                          np.asarray(b[key]))
    # residual codes survive bit-exactly (the EF recurrence continues)
    assert list(srv._residuals) == list(back._residuals)
    for user in srv._residuals:
        b1, r1 = srv._residuals[user]
        b2, r2 = back._residuals[user]
        for qa, qb in zip(
                jax.tree.leaves(jax.tree.map(lambda x: x[r1],
                                             b1.stacked.q)),
                jax.tree.leaves(jax.tree.map(lambda x: x[r2],
                                             b2.stacked.q))):
            np.testing.assert_array_equal(np.asarray(qa), np.asarray(qb))
    # demoted snapshots keep their int8 codes + scales exactly
    for w, snap in srv.ring._snapshots.items():
        snap2 = back.ring._snapshots[w]
        assert isinstance(snap2, QuantTree) == isinstance(snap, QuantTree)
        for la, lb in zip(jax.tree.leaves(snap), jax.tree.leaves(snap2)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # and the restored server keeps serving
    t = back.submit("u0", _batch(999))
    back.flush()
    assert back.poll(t) is not None


def test_delta_dtype_validated():
    with pytest.raises(ValueError):
        PersonalizationServer(_params(), _loss, _PCFG, modes=("C",),
                              delta_dtype="fp16")


# ---------------------------------------------------------------------------
# transport codec negotiation
# ---------------------------------------------------------------------------

def test_transport_codec_negotiation():
    async def run():
        srv = PersonalizationServer(_params(), _loss, _PCFG, modes=("C",),
                                    max_pending=2, delta_dtype="int8")
        ts = await TransportServer(srv, flush_ms=20.0).start()
        cq = await AsyncTransportClient("127.0.0.1", ts.port,
                                        codec="int8").connect()
        cf = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        tq = await cq.submit("uq", _batch(1))
        tf = await cf.submit("uf", _batch(2))
        hq = await cq.poll(tq, wait_ms=30_000)
        hf = await cf.poll(tf, wait_ms=30_000)
        assert hq is not None and hf is not None
        assert cq.last_codec == "int8"      # negotiated
        assert cf.last_codec == "fp32"      # legacy client: fp32 fallback
        np.testing.assert_allclose(hq["w"], hf["w"], atol=0.05)
        await cq.head("uq")
        assert cq.last_codec == "int8"
        await cf.head("uf")
        assert cf.last_codec == "fp32"
        stats = await cq.stats()
        assert stats["delta_codec"] == "int8"
        assert stats["wire_codec"] == "int8"
        assert stats["host_materializations"] == 0
        await cq.close()
        await cf.close()
        await ts.stop()
    asyncio.run(run())


def test_transport_fp32_server_never_sends_int8():
    async def run():
        srv = PersonalizationServer(_params(), _loss, _PCFG, modes=("C",),
                                    max_pending=1)
        ts = await TransportServer(srv, flush_ms=20.0).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port,
                                       codec="int8").connect()
        head = await c.poll(await c.submit("u", _batch(3)),
                            wait_ms=30_000)
        assert head is not None
        assert c.last_codec == "fp32"   # server-side codec wins
        await c.close()
        await ts.stop()
    asyncio.run(run())
