"""Sharding-rule unit tests (pure logic on a 1×1 host mesh — no 512-device
override in the test process; the real meshes are exercised by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduce_for_smoke
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import params_struct, train_batch_specs
from repro.configs import get_shape
from repro.sharding import rules


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def test_param_spec_guard_replicates_indivisible(mesh):
    cfg = get_config("gemma2-2b")
    # on a 1x1 mesh everything divides; spec structure must be valid
    spec = rules.param_spec(cfg, "layers/attn/wq", (26, 2304, 2048), mesh)
    assert len(spec) == 3


def test_param_shardings_cover_tree(mesh):
    cfg = reduce_for_smoke(get_config("granite-moe-1b-a400m"))
    struct = params_struct(cfg)
    shardings = rules.param_shardings(cfg, struct, mesh)
    n1 = len(jax.tree.leaves(struct))
    n2 = len(jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec")))
    assert n1 == n2


def test_moe_expert_dim_rule(mesh):
    cfg = get_config("deepseek-v3-671b")
    spec = rules.param_spec(cfg, "layers/moe/wg", (58, 256, 7168, 2048), mesh)
    # (L, E, d, f): experts on model; d FSDP over data (deepseek is FSDP)
    assert spec[1] == "model"


def test_embed_rules(mesh):
    cfg = get_config("gemma2-2b")
    s_tok = rules.param_spec(cfg, "embed/tok", (256000, 2304), mesh)
    s_un = rules.param_spec(cfg, "embed/unembed", (2304, 256000), mesh)
    assert s_tok[0] == "model" and s_un[-1] == "model"


def test_norms_replicated(mesh):
    cfg = get_config("gemma2-2b")
    assert rules.param_spec(cfg, "layers/ln1", (26, 2304), mesh) == P(None, None)


def test_batch_shardings_batch_dim(mesh):
    cfg = get_config("codeqwen1.5-7b")
    batch = train_batch_specs(cfg, get_shape("train_4k"))
    sh = rules.batch_shardings(batch, mesh)
    assert sh["tokens"].spec[0] == "data"


def test_cache_shardings_head_vs_seq(mesh):
    cfg = get_config("minitron-8b")
    cache = {"layers": {
        "k": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128), jnp.bfloat16)}}
    sh = rules.cache_shardings(cfg, cache, mesh)
    spec = sh["layers"]["k"].spec
    assert spec[1] == "data"           # batch on data
    assert spec[3] == "model"        # 8 kv heads divisible on 1-ax mesh


def test_divisibility_guard():
    mesh = make_host_mesh()
    sizes = {"data": 1, "model": 1}
    assert rules._fits(7, "model", sizes)
    assert rules._fits(7, None, sizes)


def test_activation_rules_shapes(mesh):
    r = rules.default_activation_rules(mesh)
    assert "moe_dispatch" in r and "residual" in r
