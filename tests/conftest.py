import os

# tests see the real single CPU device (the 512-device override is local to
# repro.launch.dryrun, per the assignment)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
