"""PersonalizationServer subsystem: head correctness against the direct
personalization functions, bounded-staleness straggler admission against a
hand-rolled oracle, micro-batcher bucketing/shard layout, ring retention,
and the steady-state zero-host-materialization contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PersAFLConfig
from repro.core.maml import personalize_maml
from repro.core.moreau import personalize_me
from repro.serving import MicroBatcher, PersonalizationServer, Ticket


def loss(p, b):
    logits = b["x"] @ p["w"] + p["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(jax.nn.one_hot(b["y"], 4) * logp, -1))


def user_batch(seed, n=8, d=5):
    rng = np.random.RandomState(seed)
    return {"x": rng.randn(n, d).astype(np.float32),
            "y": rng.randint(0, 4, n).astype(np.int32)}


def _params(seed=0, d=5):
    rng = np.random.RandomState(seed)
    return {"w": jnp.asarray(0.1 * rng.randn(d, 4).astype(np.float32)),
            "b": jnp.zeros((4,))}


def _pcfg(**kw):
    base = dict(option="C", lam=20.0, inner_steps=5, inner_eta=0.05,
                alpha=0.1, beta=0.5)
    base.update(kw)
    return PersAFLConfig(**base)


def _close(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=kw.get("rtol", 1e-5),
                                   atol=kw.get("atol", 1e-6))


# -- head correctness ------------------------------------------------------

@pytest.mark.parametrize("cohort_impl", ["auto", "shard_map"])
def test_mode_c_head_equals_prox_solve(cohort_impl):
    params = _params()
    pcfg = _pcfg()
    srv = PersonalizationServer(params, loss, pcfg,
                                cohort_impl=cohort_impl)
    tickets = [srv.submit(f"u{i}", user_batch(i)) for i in range(5)]
    assert all(srv.poll(t) is None for t in tickets)
    srv.flush()
    for i, t in enumerate(tickets):
        ref = personalize_me(loss, params, user_batch(i), pcfg.lam,
                             pcfg.inner_eta, pcfg.inner_steps)
        _close(srv.poll(t), ref)


def test_mode_b_head_equals_one_step_finetune():
    params = _params()
    pcfg = _pcfg()
    srv = PersonalizationServer(params, loss, pcfg, modes=("B",))
    t = srv.submit("u0", user_batch(3), mode="B")
    srv.flush()
    _close(srv.poll(t), personalize_maml(loss, params, user_batch(3),
                                         pcfg.alpha))


def test_stacked_heads_match_rows():
    srv = PersonalizationServer(_params(), loss, _pcfg())
    tickets = [srv.submit(f"u{i}", user_batch(i)) for i in range(4)]
    srv.flush()
    stacked = srv.stacked_heads([t.user for t in tickets])
    for i, t in enumerate(tickets):
        _close(jax.tree.map(lambda x: x[i], stacked), srv.head(t.user))


# -- straggler admission ---------------------------------------------------

def test_straggler_admission_matches_oracle():
    """A request stamped in window t but drained in window t+1 must be
    computed against w_t and re-weighted into window t+1's apply with the
    staleness discount — pinned against a hand-rolled oracle."""
    damping = 0.7
    pcfg = _pcfg(staleness_damping=damping)
    params0 = _params()
    srv = PersonalizationServer(params0, loss, pcfg, windows=3)

    # window 0: two fresh users, applied at the boundary
    srv.submit("a", user_batch(1))
    srv.submit("b", user_batch(2))
    srv.flush()
    # late request queued BEFORE the boundary fires; drained after it
    srv.submit("late", user_batch(3))
    srv.advance_window(flush=False)
    # window 1: one fresh user joins the straggler
    t_late_check = srv.submit("c", user_batch(4))
    srv.advance_window()   # flushes: c fresh (τ=0), late straggler (τ=1)
    assert srv.stats["ring_stragglers"] == 1
    assert srv.stats["ring_dropped"] == 0

    def prox_delta(w, seed):
        theta = personalize_me(loss, w, user_batch(seed), pcfg.lam,
                               pcfg.inner_eta, pcfg.inner_steps)
        return jax.tree.map(lambda a, b: np.asarray(a) - np.asarray(b),
                            w, theta)

    # oracle: window 0 applies a,b at β/2 each
    d_a, d_b = prox_delta(params0, 1), prox_delta(params0, 2)
    params1 = jax.tree.map(
        lambda w, da, db: np.asarray(w) - pcfg.beta / 2 * (da + db),
        params0, d_a, d_b)
    # window 1: fresh c at β/2, late row (computed at w_0!) at
    # β/2·(1+1)^{-damping}
    d_c = prox_delta(params1, 4)
    d_late = prox_delta(params0, 3)
    w_late = pcfg.beta / 2 * (1.0 + 1.0) ** (-damping)
    params2 = jax.tree.map(
        lambda w, dc, dl: w - pcfg.beta / 2 * dc - w_late * dl,
        params1, d_c, d_late)
    _close(srv.params, params2, rtol=1e-5, atol=1e-5)
    # the straggler was still served a head — computed at its stamped w_0
    _close(srv.poll(t_late_check),
           personalize_me(loss, params1, user_batch(4), pcfg.lam,
                          pcfg.inner_eta, pcfg.inner_steps))
    _close(srv.head("late"),
           personalize_me(loss, params0, user_batch(3), pcfg.lam,
                          pcfg.inner_eta, pcfg.inner_steps))


def test_past_tau_max_is_dropped_not_applied():
    srv = PersonalizationServer(_params(), loss, _pcfg(), windows=2)
    assert srv.ring.tau_max == 1
    t = srv.submit("slow", user_batch(0))
    srv.advance_window(flush=False)
    srv.advance_window(flush=False)      # τ = 2 > τ_max = 1
    before = jax.tree.map(np.asarray, srv.params)
    srv.flush()
    assert t.status == "dropped" and t.tau == 2
    assert srv.stats["batcher_dropped"] == 1   # refused pre-cohort: the
    assert srv.stats["ring_dropped"] == 0      # drop never cost a slot
    with pytest.raises(RuntimeError, match="tau_max"):
        srv.poll(t)
    srv.advance_window()
    _close(srv.params, before)           # dropped row never applied


def test_ring_retention_prunes_old_windows():
    srv = PersonalizationServer(_params(), loss, _pcfg(), windows=2)
    srv.submit("u0", user_batch(0))
    srv.flush()
    assert srv.ring.lookup("u0") is not None
    live0 = srv.ring.live_banks
    assert live0 > 0
    for _ in range(3):
        srv.advance_window()
    assert srv.ring.lookup("u0") is None
    assert srv.ring.live_banks == 0      # old windows' banks released


# -- batching --------------------------------------------------------------

def test_micro_batcher_groups_by_mode_and_buckets_pow2():
    srv = PersonalizationServer(_params(), loss, _pcfg(), modes=("B", "C"))
    for i in range(5):
        srv.submit(f"c{i}", user_batch(i), mode="C")
    for i in range(3):
        srv.submit(f"b{i}", user_batch(10 + i), mode="B")
    srv.flush()
    s = srv.stats
    assert s["batcher_drains"] == 1
    assert s["cohort_calls"] == 2        # one per mode group
    # pow2 buckets: 5 -> 8 (waste 3), 3 -> 4 (waste 1)
    assert s["padding_waste"] == 4
    assert s["max_cohort"] == 5


def test_auto_flush_at_max_pending():
    srv = PersonalizationServer(_params(), loss, _pcfg(), max_pending=4)
    tickets = [srv.submit(f"u{i}", user_batch(i)) for i in range(4)]
    assert all(t.status == "done" for t in tickets)   # flushed on the 4th


def test_shard_major_layout_preserves_row_identity():
    """With a sharded batcher layout every user's head must still be the
    user's own solve — placement moves rows, never mixes them."""
    params = _params()
    pcfg = _pcfg()
    srv = PersonalizationServer(params, loss, pcfg)
    srv.batcher.n_shards = 4             # force the shard-major path
    tickets = [srv.submit(f"u{i}", user_batch(i)) for i in range(5)]
    srv.flush()
    assert srv.stats["batcher_shard_padding"] > 0
    for i, t in enumerate(tickets):
        _close(srv.poll(t), personalize_me(loss, params, user_batch(i),
                                           pcfg.lam, pcfg.inner_eta,
                                           pcfg.inner_steps))
    # stable keying: the same users land in the same shard slots again
    b = MicroBatcher(srv.engines, n_shards=4)
    assert all(b._shard(f"u{i}") == srv.batcher._shard(f"u{i}")
               for i in range(5))


def test_ticket_unknown_mode_rejected():
    srv = PersonalizationServer(_params(), loss, _pcfg(), modes=("C",))
    with pytest.raises(ValueError, match="not enabled"):
        srv.submit("u", user_batch(0), mode="B")
    with pytest.raises(ValueError, match="unknown personalization mode"):
        PersonalizationServer(_params(), loss, _pcfg(), modes=("Z",))


# -- steady-state contract -------------------------------------------------

def test_steady_state_zero_host_materializations():
    """submit → flush → poll/stacked_heads → advance over many windows
    never moves a delta or head to the host."""
    srv = PersonalizationServer(_params(), loss, _pcfg(), windows=3)
    users = [f"u{i}" for i in range(6)]
    for _ in range(5):
        tickets = [srv.submit(u, user_batch(i))
                   for i, u in enumerate(users)]
        srv.flush()
        for t in tickets:
            jax.block_until_ready(jax.tree.leaves(srv.poll(t))[0])
        jax.block_until_ready(
            jax.tree.leaves(srv.stacked_heads(users))[0])
        srv.advance_window()
    assert srv.stats["host_materializations"] == 0
    assert srv.stats["ring_windows"] == 5
    assert int(srv.staleness()["server_rounds"]) == 30
    for leaf in jax.tree.leaves(srv.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_head_cache_lru_eviction():
    srv = PersonalizationServer(_params(), loss, _pcfg(), head_cache=3)
    tickets = [srv.submit(f"u{i}", user_batch(i)) for i in range(5)]
    srv.flush()
    assert srv.stats["cached_heads"] == 3
    with pytest.raises(KeyError):
        srv.head("u0")                    # evicted from the LRU cache
    # but the TICKET still owns its (bank, row) handle: eviction only
    # affects user-keyed lookups, never an open ticket's own result
    _close(srv.poll(tickets[0]),
           personalize_me(loss, srv.ring.snapshot(0), user_batch(0),
                          _pcfg().lam, _pcfg().inner_eta,
                          _pcfg().inner_steps))
    # a handle-less done ticket (pre-restart construction) falls back to
    # the cache and surfaces the eviction explicitly
    orphan = Ticket(user="u0", mode="C", stamp=0, status="done")
    with pytest.raises(RuntimeError, match="evicted"):
        srv.poll(orphan)
    jax.block_until_ready(jax.tree.leaves(srv.head("u4"))[0])


def test_fairness_cap_bounds_per_user_rows_per_window():
    """A heavy user cannot monopolize the window's apply weight vector:
    rows beyond user_cap are refused pre-cohort and the drop is typed."""
    srv = PersonalizationServer(_params(), loss, _pcfg(), user_cap=2)
    tickets = [srv.submit("heavy", user_batch(i)) for i in range(4)]
    t_light = srv.submit("light", user_batch(9))
    srv.flush()
    assert [t.status for t in tickets] == ["done", "done", "capped",
                                           "capped"]
    assert t_light.status == "done"
    assert srv.stats["batcher_fairness_capped"] == 2
    assert srv.stats["ring_admitted"] == 3        # 2 heavy + 1 light
    with pytest.raises(RuntimeError, match="fairness cap"):
        srv.poll(tickets[2])
    # the cap resets at the window boundary: same user serves again
    srv.advance_window()
    t_next = srv.submit("heavy", user_batch(5))
    srv.flush()
    assert t_next.status == "done"


def test_ring_refusal_surfaces_capped_not_dropped():
    """Regression: a ring-level fairness refusal used to mark the ticket
    "dropped", so poll reported a bogus tau_max violation.  The ring (the
    admission authority) must report WHY it refused, and flush must type
    the ticket accordingly."""
    srv = PersonalizationServer(_params(), loss, _pcfg(), user_cap=1)
    # simulate pre-filter drift (multiple front-ends, restarted batcher):
    # the batcher lets everything through, the ring stays the authority
    srv.batcher.user_cap = None
    t1 = srv.submit("u", user_batch(0))
    t2 = srv.submit("u", user_batch(1))
    srv.flush()
    assert t1.status == "done"
    assert t2.status == "capped"        # pre-PR: "dropped"
    assert srv.stats["ring_fairness_capped"] == 1
    assert srv.stats["ring_dropped"] == 0
    with pytest.raises(RuntimeError, match="fairness cap"):
        srv.poll(t2)                    # pre-PR: raised "tau_max"


def test_ring_admit_row_reports_cause():
    from repro.serving import DeltaRing
    srv = PersonalizationServer(_params(), loss, _pcfg())
    srv.submit("u", user_batch(0))
    srv.flush()
    bank = srv.ring._banks[0][0]
    ring = DeltaRing(_params(), windows=3, user_cap=1)
    assert ring.admit_row("a", bank, 0, 0) == "admitted"
    assert ring.admit_row("a", bank, 0, 0) == "capped"
    assert ring.admit_row("b", bank, 0, 3) == "dropped"  # tau_max = 2
    # the boolean wrapper keeps its contract
    assert ring.admit("c", bank, 0, 1) is True
    assert ring.admit("c", bank, 0, 1) is False
    assert ring.stats == {"windows": 0, "admitted": 2, "stragglers": 1,
                          "dropped": 1, "fairness_capped": 2,
                          "robust_clipped": 0, "robust_trimmed": 0,
                          "robust_nonfinite": 0}


def test_fairness_cap_ring_is_admission_authority():
    """The ring enforces the cap cumulatively across drains within one
    window (the batcher's pre-filter is per-drain bookkeeping)."""
    from repro.serving import DeltaRing
    ring = DeltaRing(_params(), windows=2, user_cap=1)
    srv = PersonalizationServer(_params(), loss, _pcfg())
    srv.submit("u", user_batch(0))
    srv.flush()
    bank = srv.ring._banks[0][0]
    assert ring.admit("u", bank, 0, 0) is True
    assert ring.admit("u", bank, 0, 0) is False   # over cap, same window
    assert ring.stats["fairness_capped"] == 1
    state = ring.advance(srv.state, beta=0.5)
    assert ring.admit("u", bank, 0, 1) is True    # new window, cap reset
    assert state is not None


def test_restart_warm_start_roundtrip(tmp_path):
    """save/restore through repro.checkpoint.store: a restarted server
    keeps its global params, ring snapshots + window counter, and the
    head cache — no empty-ring cold start."""
    pcfg = _pcfg()
    srv = PersonalizationServer(_params(), loss, pcfg, windows=3,
                                user_cap=4)
    users = [f"u{i}" for i in range(4)]
    for w in range(2):
        for i, u in enumerate(users):
            srv.submit(u, user_batch(10 * w + i))
        srv.advance_window()
    heads_before = {u: jax.tree.map(np.asarray, srv.head(u))
                    for u in users}
    path = str(tmp_path / "serve_state")
    srv.save(path)

    srv2 = PersonalizationServer.restore(path, loss, pcfg)
    # global model, window counter, staleness accounting all survive
    _close(srv2.params, srv.params)
    assert srv2.window == srv.window == 2
    assert int(srv2.staleness()["server_rounds"]) \
        == int(srv.staleness()["server_rounds"])
    assert srv2.ring.user_cap == 4
    # ring snapshots survive (straggler requests can still drain)
    assert set(srv2.ring._snapshots) == set(srv.ring._snapshots)
    for w in srv.ring._snapshots:
        _close(srv2.ring.snapshot(w), srv.ring.snapshot(w))
    # the head cache is warm: no re-personalization needed after restart
    assert srv2.stats["cached_heads"] == len(users)
    for u in users:
        _close(srv2.head(u), heads_before[u])
    # and the restored server keeps serving + advancing
    t = srv2.submit("fresh", user_batch(99))
    srv2.advance_window()
    assert t.status == "done"
    assert srv2.window == 3


def test_restart_preserves_ring_stats(tmp_path):
    """Regression: DeltaRing.load restored the window counter but left
    stats["windows"] (and every other ring counter) at zero, skewing any
    per-window serve metric computed after a restart."""
    srv = PersonalizationServer(_params(), loss, _pcfg(), windows=3)
    for w in range(2):
        srv.submit(f"u{w}", user_batch(w))
        srv.advance_window()
    before = dict(srv.ring.stats)
    assert before["windows"] == 2 and before["admitted"] == 2
    path = str(tmp_path / "ring_stats")
    srv.save(path)
    srv2 = PersonalizationServer.restore(path, loss, _pcfg())
    assert srv2.ring.stats == before          # pre-PR: all zeros
    # counters keep accumulating from the restored values
    srv2.submit("fresh", user_batch(9))
    srv2.advance_window()
    assert srv2.ring.stats["windows"] == 3
    assert srv2.ring.stats["admitted"] == 3


def test_ring_load_without_stats_falls_back_to_counter():
    """Pre-stats checkpoints: windows falls back to the window counter
    (the one value the counter implies), the rest stay zero."""
    from repro.serving import DeltaRing
    ring = DeltaRing(_params(), windows=3)
    ring.load({4: _params(), 5: _params()}, 5)
    assert ring.stats["windows"] == 5
    assert ring.stats["admitted"] == 0


def test_restart_with_empty_head_cache(tmp_path):
    srv = PersonalizationServer(_params(), loss, _pcfg())
    srv.advance_window()
    path = str(tmp_path / "empty_state")
    srv.save(path)
    srv2 = PersonalizationServer.restore(path, loss, _pcfg())
    assert srv2.stats["cached_heads"] == 0
    assert srv2.window == 1
    _close(srv2.params, srv.params)


# -- admission-weight duplicate accumulation (bugfix regression) -----------

def test_admission_weights_duplicate_rows_accumulate():
    """Regression: a row admitted twice in one window (user_cap >= 2,
    transport re-submits landing in the same bank slot) used to be
    OVERWRITTEN (`w[idx] = wt`), silently under-applying the duplicate
    while the version counter still advanced per admission."""
    from repro.core import admission_weights
    w = admission_weights(4, [(0, 0), (0, 0)], beta=1.0, count=2)
    np.testing.assert_allclose(w, [1.0, 0.0, 0.0, 0.0])   # pre-fix: 0.5
    # damping composes per admission, not per slot
    w = admission_weights(4, [(1, 0), (1, 2)], beta=1.0, count=2,
                          damping=1.0)
    np.testing.assert_allclose(w[1], 0.5 + 0.5 / 3.0, rtol=1e-6)


def test_duplicate_admission_applies_both_rows():
    """End-to-end through the ring: the SAME (bank, row) admitted twice
    into one window contributes 2·β/count to the apply — pre-fix the
    second admission overwrote the first's weight."""
    from repro.core import init_server_state
    from repro.serving import DeltaRing
    pcfg = _pcfg()
    params0 = _params()
    srv = PersonalizationServer(params0, loss, pcfg)
    srv.submit("u", user_batch(1))
    srv.flush()
    bank = srv.ring._banks[0][0]
    ring = DeltaRing(params0, windows=2, user_cap=2)
    assert ring.admit("u", bank, 0, 0)
    assert ring.admit("u", bank, 0, 0)    # transport re-submit, same slot
    state = ring.advance(init_server_state(params0), beta=pcfg.beta)
    delta = jax.tree.map(lambda x: np.asarray(x[0]), bank.stacked)
    expect = jax.tree.map(lambda w, d: np.asarray(w) - pcfg.beta * d,
                          params0, delta)   # 2 · β/2 · d; pre-fix: β/2 · d
    _close(state.params, expect, rtol=1e-5, atol=1e-6)


# -- per-ticket result handles (stale-ticket aliasing bugfix) ---------------

def test_stale_ticket_keeps_its_own_head():
    """Regression: poll resolved "done" tickets BY USER, so polling an
    older ticket after a newer flush for the same user silently returned
    the newest head.  Each ticket owns its (bank, row) handle."""
    pcfg = _pcfg()
    params = _params()
    srv = PersonalizationServer(params, loss, pcfg)
    t1 = srv.submit("u", user_batch(1))
    srv.flush()
    t2 = srv.submit("u", user_batch(2))
    srv.flush()
    ref1 = personalize_me(loss, params, user_batch(1), pcfg.lam,
                          pcfg.inner_eta, pcfg.inner_steps)
    ref2 = personalize_me(loss, params, user_batch(2), pcfg.lam,
                          pcfg.inner_eta, pcfg.inner_steps)
    _close(srv.poll(t1), ref1)            # pre-fix: aliased to ref2
    _close(srv.poll(t2), ref2)
    _close(srv.head("u"), ref2)           # user-keyed lookup IS the newest


def test_superseded_ticket_fails_explicitly_after_retirement():
    """Once a served ticket's ring window rotates out, its head bank is
    gone — poll must raise a typed superseded-and-retired error, never
    return another flush's head."""
    srv = PersonalizationServer(_params(), loss, _pcfg(), windows=2)
    t_old = srv.submit("u", user_batch(1))
    srv.flush()
    assert t_old.status == "done" and t_old.window == 0
    for _ in range(2):                    # horizon moves past window 0
        srv.submit("u", user_batch(2))
        srv.advance_window()
    with pytest.raises(RuntimeError, match="superseded and retired"):
        srv.poll(t_old)
    assert t_old.head is None             # the bank pin is dropped too


# -- tau_max requested-vs-effective round trip (bugfix regression) ----------

def test_tau_max_clamp_warns_and_preserves_requested():
    from repro.serving import DeltaRing
    with pytest.warns(UserWarning, match="clamped"):
        ring = DeltaRing(_params(), windows=2, tau_max=5)
    assert ring.tau_max == 1              # effective: ring depth bound
    assert ring.tau_max_requested == 5    # requested: preserved


def test_tau_max_roundtrips_requested_not_clamped(tmp_path):
    """Regression: the checkpoint used to persist the CLAMPED tau_max, so
    restoring a shallow-ring checkpoint into a deeper ring silently kept
    the accidentally-tightened bound."""
    path = str(tmp_path / "tau_state")
    with pytest.warns(UserWarning, match="clamped"):
        srv = PersonalizationServer(_params(), loss, _pcfg(), windows=2,
                                    tau_max=5)
    assert srv.ring.tau_max == 1
    srv.save(path)
    # restore into a deeper ring: the REQUESTED bound re-clamps against
    # the new depth (min(5, 8-1) = 5), not the old ring's accident
    srv2 = PersonalizationServer.restore(path, loss, _pcfg(), windows=8)
    assert srv2.ring.tau_max_requested == 5
    assert srv2.ring.tau_max == 5         # pre-fix: stayed 1
    # same-depth restore still warns and re-clamps identically
    with pytest.warns(UserWarning, match="clamped"):
        srv3 = PersonalizationServer.restore(path, loss, _pcfg())
    assert srv3.ring.tau_max == 1 and srv3.ring.tau_max_requested == 5


def test_window_apply_advances_global_model():
    srv = PersonalizationServer(_params(), loss, _pcfg())
    before = jax.tree.map(np.asarray, srv.params)
    srv.submit("u", user_batch(0))
    srv.advance_window()
    moved = sum(float(np.sum(np.abs(np.asarray(a) - b)))
                for a, b in zip(jax.tree.leaves(srv.params),
                                jax.tree.leaves(before)))
    assert moved > 0
    assert int(srv.staleness()["server_rounds"]) == 1
