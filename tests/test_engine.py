"""Cohort-engine and scheduler tests: the vmapped cohort path is a
performance transform, not a semantics change — pinned against the
sequential per-client loop for all three options, plus determinism of the
event-driven runs and the DelayModel's §5 statistics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import MNIST_CNN
from repro.core import PersAFLConfig, client_update, split_batches_for_option
from repro.data import make_federated_dataset
from repro.fl import (ApplyPolicy, CohortEngine, DelayModel, FLRun,
                      buffered, immediate, sync_barrier)
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn


def quad_loss(w, batch):
    r = batch["a"] @ w["w"] - batch["y"]
    return 0.5 * jnp.mean(r ** 2)


def _client_batches(seed, q3=6, m=8, d=5):
    rng = np.random.RandomState(seed)
    return {"a": jnp.asarray(rng.randn(q3, m, d).astype(np.float32)),
            "y": jnp.asarray(rng.randn(q3, m).astype(np.float32))}


# ---------------------------------------------------------------------------
# cohort equivalence: vmapped == sequential, options A/B/C
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("option", ["A", "B", "C"])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_cohort_matches_sequential(option, k):
    pcfg = PersAFLConfig(option=option, q_local=2, eta=0.05, alpha=0.05,
                         lam=20.0, inner_steps=5, inner_eta=0.02,
                         maml_mode="full")
    params = {"w": jnp.arange(1.0, 6.0) * 0.1}
    batch_list = [_client_batches(seed) for seed in range(k)]

    engine = CohortEngine(pcfg, quad_loss, vectorized=True)
    got = engine.update_cohort(params, batch_list)

    for b3q, delta in zip(batch_list, got):
        ref, _ = client_update(pcfg, quad_loss, params,
                               split_batches_for_option(option, b3q))
        np.testing.assert_allclose(np.asarray(delta["w"]),
                                   np.asarray(ref["w"]),
                                   rtol=1e-5, atol=1e-6)


def test_cohort_mean_masks_padding():
    """Bucket padding (k=3 -> bucket 4) must not leak into the mean."""
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.05)
    params = {"w": jnp.zeros(5)}
    batch_list = [_client_batches(seed) for seed in range(3)]
    engine = CohortEngine(pcfg, quad_loss, vectorized=True)
    mean = engine.update_cohort_mean(params, batch_list)
    deltas = engine.update_cohort(params, batch_list)
    ref = jax.tree.map(lambda *xs: sum(xs) / len(xs), *deltas)
    np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(ref["w"]),
                               rtol=1e-5, atol=1e-7)


def test_cohort_bucketing_bounds_compiles():
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.05)
    engine = CohortEngine(pcfg, quad_loss)
    assert [engine._bucket(k) for k in (1, 2, 3, 5, 8, 9)] \
        == [1, 2, 4, 8, 8, 16]
    # sharded cohorts round up to a device-count multiple (equal shards)
    engine._ndev = 8
    assert [engine._bucket(k) for k in (1, 8, 9, 17)] == [8, 8, 16, 32]


def test_padding_waste_stat_counts_bucket_overhead():
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.05)
    params = {"w": jnp.zeros(5)}
    engine = CohortEngine(pcfg, quad_loss, vectorized=True)
    engine.update_cohort(params, [_client_batches(s) for s in range(3)])
    assert engine.stats["padding_waste"] == 1     # bucket 4, 3 real rows
    engine.update_cohort(params, [_client_batches(s) for s in range(5)])
    assert engine.stats["padding_waste"] == 1 + 3  # bucket 8, 5 real rows


def test_delta_bank_lazy_materialization():
    """The bank's stacked buffer crosses to the host at most once, and only
    when a row is actually asked for."""
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.05)
    params = {"w": jnp.zeros(5)}
    engine = CohortEngine(pcfg, quad_loss, vectorized=True)
    bank = engine.update_cohort(params, [_client_batches(s) for s in range(3)])
    assert len(bank) == 3 and bank.capacity == 4
    assert engine.stats["host_materializations"] == 0
    rows = list(bank)
    assert engine.stats["host_materializations"] == 1
    bank.row(0)
    assert engine.stats["host_materializations"] == 1  # cached host views
    assert all(isinstance(r["w"], np.ndarray) for r in rows)
    # materialization releases the device buffer (no double residency) …
    assert bank._stacked is None and bank.capacity == 4
    # … and .stacked transparently re-uploads from the host copy
    np.testing.assert_allclose(np.asarray(bank.stacked["w"][0]),
                               np.asarray(rows[0]["w"]))


# ---------------------------------------------------------------------------
# simulators on the real (synthetic-MNIST) federated setup
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_small():
    clients = make_federated_dataset("mnist", n_clients=5,
                                     classes_per_client=3, seed=0)
    params = init_cnn(MNIST_CNN, jax.random.PRNGKey(0))
    loss = lambda p, b: cnn_loss(MNIST_CNN, p, b, train=False)
    return clients, params, loss


def _run_async(fed, *, vectorized, rounds=15, seed=0):
    clients, params, loss = fed
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.02)
    sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(len(clients), seed=1),
                strategy="persafl", schedule=immediate(),
                batch_size=8, seed=seed, vectorized=vectorized)
    hist = sim.run(max_rounds=rounds)
    return sim, hist


def test_async_vectorized_matches_sequential_trace(fed_small):
    """Same seeds => the engine path replays the per-event path's History
    and reaches the same final params (up to vmap fp reassociation)."""
    sim_v, h_v = _run_async(fed_small, vectorized=True)
    sim_s, h_s = _run_async(fed_small, vectorized=False)
    assert h_v.staleness == h_s.staleness
    np.testing.assert_allclose(h_v.active_ratio, h_s.active_ratio)
    np.testing.assert_allclose(h_v.times, h_s.times)
    for a, b in zip(jax.tree.leaves(sim_v.state["params"]),
                    jax.tree.leaves(sim_s.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_async_run_is_deterministic(fed_small):
    """Two runs with the same seed yield an identical History."""
    _, h1 = _run_async(fed_small, vectorized=True)
    _, h2 = _run_async(fed_small, vectorized=True)
    d1, d2 = h1.as_dict(), h2.as_dict()
    assert d1.keys() == d2.keys()
    for key in d1:
        np.testing.assert_array_equal(np.asarray(d1[key]),
                                      np.asarray(d2[key]), err_msg=key)


def test_buffered_async_end_to_end(fed_small):
    clients, params, loss = fed_small
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.02, buffer_size=4)
    sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(len(clients), seed=1),
                strategy="persafl", schedule=buffered(4),
                batch_size=8, seed=0)
    hist = sim.run(max_rounds=16)
    t = int(sim.final_stats["server_rounds"])
    assert t >= 16 and t % 4 == 0           # advances M per flush
    assert len(hist.staleness) == t         # every contributing delta counted
    # the accounting fix: buffered runs report a real mean staleness
    assert float(sim.final_stats["mean_staleness"]) == pytest.approx(
        sum(hist.staleness) / t)
    assert all(s >= 0 for s in hist.staleness)
    for leaf in jax.tree.leaves(sim.state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_buffered_m1_matches_immediate_async(fed_small):
    """M=1 buffered == paper-faithful immediate apply (same trace)."""
    clients, params, loss = fed_small
    kw = dict(clients=clients, loss_fn=loss, init_params=params,
              delays=DelayModel(len(clients), seed=1), batch_size=8, seed=0)
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.02)
    h_a = FLRun(pcfg=pcfg, strategy="persafl", schedule=immediate(),
                **kw).run(max_rounds=10)
    kw["delays"] = DelayModel(len(clients), seed=1)
    h_b = FLRun(pcfg=dataclasses.replace(pcfg, buffer_size=1),
                strategy="persafl", schedule=buffered(1), **kw).run(
                    max_rounds=10)
    assert h_a.staleness == h_b.staleness
    np.testing.assert_allclose(h_a.active_times, h_b.active_times)


def test_buffered_flush_never_transfers_deltas_to_host(fed_small):
    """Acceptance: buffered applies consume the stacked DeltaBank on device
    — zero per-client (or per-bank) device→host delta transfers."""
    clients, params, loss = fed_small
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.02, buffer_size=4)
    sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(len(clients), seed=1),
                strategy="persafl", schedule=buffered(4),
                batch_size=8, seed=0)
    sim.run(max_rounds=16)
    assert sim.engine.stats["cohort_calls"] > 0
    assert sim.engine.stats["host_materializations"] == 0


class _LegacyHostLoopPolicy(ApplyPolicy):
    """The pre-DeltaBank flush as an ApplyPolicy: M host-side damped
    tree.maps + one summed apply.  Kept only as the numerical-equality
    oracle for the fused apply_rows weight-vector path — and as proof any
    apply schedule plugs into FLRun's event loop."""

    def __init__(self, m):
        self.m = m

    def start(self, run):
        self._buffer = []

    def on_upload(self, run, now, rid, version, hist, eval_fn, eval_every):
        from repro.core import apply_buffered
        staleness = run._t - version
        hist.staleness.append(staleness)
        self._buffer.append((rid, staleness))
        if len(self._buffer) < self.m:
            return
        run._flush()
        deltas = []
        for r, _ in self._buffer:
            bank, idx = run._computed.pop(r)
            deltas.append(bank.row(idx))
        stales = [s for _, s in self._buffer]
        damping = run.pcfg.staleness_damping
        if damping:
            deltas = [jax.tree.map(lambda x: x * (1.0 + s) ** (-damping), d)
                      for d, s in zip(deltas, stales)]
        delta_sum = jax.tree.map(lambda *xs: sum(xs), *deltas)
        t_old = run._t
        run.state = apply_buffered(run.state, delta_sum, len(deltas),
                                   run.pcfg.beta,
                                   staleness_max=max(stales),
                                   staleness_sum=float(sum(stales)))
        self._buffer = []
        run._t = t_old + len(deltas)


@pytest.mark.parametrize("damping", [0.0, 1.5])
def test_buffered_apply_rows_matches_legacy_host_loop(fed_small, damping):
    """Regression pin: folding β/M + per-delta damping into the apply_rows
    weight vector reproduces the old host-side loop's numbers."""
    clients, params, loss = fed_small
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.02, buffer_size=4,
                         staleness_damping=damping)
    sims = []
    for schedule in (buffered(4), _LegacyHostLoopPolicy(4)):
        sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                    pcfg=pcfg, delays=DelayModel(len(clients), seed=1),
                    strategy="persafl", schedule=schedule,
                    batch_size=8, seed=0)
        sim.run(max_rounds=12)
        sims.append(sim)
    new, old = sims
    assert int(new.final_stats["server_rounds"]) \
        == int(old.final_stats["server_rounds"])
    assert float(new.final_stats["mean_staleness"]) == pytest.approx(
        float(old.final_stats["mean_staleness"]))
    for a, b in zip(jax.tree.leaves(new.state["params"]),
                    jax.tree.leaves(old.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_buffered_staleness_damping_discounts_stale_deltas(fed_small):
    """staleness_damping must act on the buffered path too (per-delta)."""
    clients, params, loss = fed_small
    kw = dict(clients=clients, loss_fn=loss, init_params=params,
              batch_size=8, seed=0)
    runs = {}
    for a in (0.0, 2.0):
        pcfg = PersAFLConfig(option="A", q_local=2, eta=0.02, buffer_size=4,
                             staleness_damping=a)
        sim = FLRun(pcfg=pcfg, strategy="persafl", schedule=buffered(4),
                    **kw, delays=DelayModel(len(clients), seed=1))
        sim.run(max_rounds=8)
        runs[a] = sim.state["params"]
    p0 = jax.tree.leaves(params)
    moved = lambda p: sum(float(jnp.sum((a - b) ** 2))  # noqa: E731
                          for a, b in zip(jax.tree.leaves(p), p0))
    # damped applies discount stale deltas => strictly smaller server moves
    assert 0 < moved(runs[2.0]) < moved(runs[0.0])


def test_sync_cohort_path_runs(fed_small):
    clients, params, loss = fed_small
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.01)
    sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(len(clients)),
                strategy="fedavg", schedule=sync_barrier(3), batch_size=8,
                seed=0)
    sim.run(max_rounds=3)
    assert sim.engine.stats["cohort_calls"] == 3
    assert sim.engine.stats["max_cohort"] == 3


# ---------------------------------------------------------------------------
# DelayModel (paper §5 statistics)
# ---------------------------------------------------------------------------

def test_delay_upload_mean_4_to_6x_download():
    dm = DelayModel(n_clients=40, seed=7)
    n_draws = 400
    for i in range(0, 40, 13):
        downs = np.array([dm.sample_download(i) for _ in range(n_draws)])
        ups = np.array([dm.sample_upload(i) for _ in range(n_draws)])
        ratio = ups.mean() / downs.mean()
        assert 3.5 < ratio < 6.5, (i, ratio)   # 4-6x up to jitter noise


def test_delay_scale_multiplies_both():
    base = DelayModel(n_clients=6, seed=3)
    scaled = DelayModel(n_clients=6, seed=3, scale=2.5)
    # same seed => identical jitter streams => exact 2.5x, draw by draw
    for i in range(6):
        np.testing.assert_allclose(scaled.sample_download(i),
                                   2.5 * base.sample_download(i), rtol=1e-12)
        np.testing.assert_allclose(scaled.sample_upload(i),
                                   2.5 * base.sample_upload(i), rtol=1e-12)


def test_delay_streams_reproducible():
    a = DelayModel(n_clients=4, seed=11)
    b = DelayModel(n_clients=4, seed=11)
    seq_a = [a.sample_download(i % 4) for i in range(20)] \
        + [a.sample_upload(i % 4) for i in range(20)]
    seq_b = [b.sample_download(i % 4) for i in range(20)] \
        + [b.sample_upload(i % 4) for i in range(20)]
    assert seq_a == seq_b
    c = DelayModel(n_clients=4, seed=12)
    assert [c.sample_download(i % 4) for i in range(20)] != seq_a[:20]
