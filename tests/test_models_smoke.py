"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model<=512, <=4 experts) runs one forward/train
step and one decode step on CPU; shapes and finiteness asserted."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, reduce_for_smoke
from repro.core import PersAFLConfig
from repro.launch.steps import make_train_step
from repro.models import api

ARCHS = list_archs()


def _smoke_cfg(arch):
    return reduce_for_smoke(get_config(arch))


def _train_batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_visual_tokens:
        batch["visual"] = jax.random.normal(
            key, (B, cfg.n_visual_tokens, cfg.d_model))
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_len, cfg.d_model))
    return batch


def test_reduced_limits():
    for arch in ARCHS:
        cfg = _smoke_cfg(arch)
        assert cfg.n_layers == 2
        assert cfg.d_model <= 512
        if cfg.moe is not None:
            assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch):
    cfg = _smoke_cfg(arch)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    loss = api.loss_fn(cfg, params, _train_batch(cfg, key))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_persafl_train_step(arch):
    """One full PersA-FL client round + server apply on the reduced arch."""
    cfg = _smoke_cfg(arch)
    key = jax.random.PRNGKey(1)
    params = api.init_params(cfg, key)
    pcfg = PersAFLConfig(option="A", q_local=2, eta=0.01)
    step = jax.jit(make_train_step(cfg, pcfg, n_microbatches=1))
    batch = _train_batch(cfg, key)
    new_params, metrics = step(params, params, batch)
    # shapes preserved, update applied, everything finite
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail("shape changed"), params, new_params)
    assert bool(jnp.isfinite(metrics["delta_norm"]))
    assert float(metrics["delta_norm"]) > 0
    leaves = jax.tree.leaves(new_params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = _smoke_cfg(arch)
    key = jax.random.PRNGKey(2)
    params = api.init_params(cfg, key)
    B = 2
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_len, cfg.d_model))
    cache = api.init_cache(cfg, params, batch, max_len=8, dtype=jnp.float32)
    logits, cache = api.decode_step(cfg, params, cache, batch["tokens"],
                                    jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits2, _ = api.decode_step(cfg, params, cache, batch["tokens"] + 1,
                                 jnp.int32(1))
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-130m",
                                  "granite-moe-1b-a400m", "zamba2-1.2b",
                                  "whisper-large-v3", "deepseek-v3-671b"])
def test_prefill_decode_equivalence(arch):
    """Teacher-forced logits == step-by-step decode (MoE: no-drop regime)."""
    cfg = _smoke_cfg(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(42)
    params = api.init_params(cfg, key)
    B, S = 1, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    from repro.models import encdec as ed
    from repro.models import lm, ssm_lm
    from repro.models.layers import unembed
    if cfg.family in ("ssm", "hybrid"):
        h = ssm_lm.ssm_lm_hidden(cfg, params, toks, window=cfg.sliding_window)
        full = unembed(params["embed"], h, cfg.final_softcap)
    elif cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_len, cfg.d_model))
        ench = ed.encode(cfg, params, batch["frames"])
        h = ed.decode_full(cfg, params, toks, ench)
        full = unembed(params["embed"], h, cfg.final_softcap)
    else:
        full, _ = lm.lm_logits(cfg, params, toks)
    cache = api.init_cache(cfg, params, batch, max_len=S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = api.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(full - dec)))
    assert err < 5e-4, err


def test_vlm_visual_tokens_required():
    cfg = _smoke_cfg("internvl2-76b")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(AssertionError):
        api.loss_fn(cfg, params, {"tokens": jnp.zeros((1, 8), jnp.int32),
                                  "labels": jnp.zeros((1, 8), jnp.int32)})
