"""Socket transport front-end: wire codec round-trips, the
flush-timer-driven submit→poll→head path (bit-for-bit vs the in-process
server), explicit backpressure (BUSY at server/connection/user scope), a
second-OS-process integration drive, and clean shutdown."""
import asyncio
import os
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PersAFLConfig
from repro.serving import PersonalizationServer
from repro.serving.transport import (AsyncTransportClient, TransportBusy,
                                     TransportError, TransportServer,
                                     decode_pytree, encode_pytree,
                                     pack_frame, split_frame)


def loss(p, b):
    logits = b["x"] @ p["w"] + p["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(jax.nn.one_hot(b["y"], 4) * logp, -1))


def user_batch(seed, n=8, d=5):
    rng = np.random.RandomState(seed)
    return {"x": rng.randn(n, d).astype(np.float32),
            "y": rng.randint(0, 4, n).astype(np.int32)}


def _params(seed=0, d=5):
    rng = np.random.RandomState(seed)
    return {"w": jnp.asarray(0.1 * rng.randn(d, 4).astype(np.float32)),
            "b": jnp.zeros((4,))}


def _pcfg(**kw):
    base = dict(option="C", lam=20.0, inner_steps=5, inner_eta=0.05,
                alpha=0.1, beta=0.5)
    base.update(kw)
    return PersAFLConfig(**base)


def _server(**kw):
    kw.setdefault("max_pending", 64)
    return PersonalizationServer(_params(), loss, _pcfg(), **kw)


def _bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        assert np.array_equal(x, y)


# -- codec -----------------------------------------------------------------

def test_pytree_codec_roundtrip():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"b": np.asarray([1, 2], np.int32),
                       "c": [np.float64(3.5) * np.ones((2,)),
                             np.zeros((1, 2), np.float16)]}}
    out = decode_pytree(encode_pytree(tree))
    _bitwise_equal(tree, out)
    # jax leaves encode identically to their host values (f64 narrows to
    # f32 at jnp.asarray time, before the codec ever sees it)
    jtree = jax.tree.map(jnp.asarray, tree)
    out2 = decode_pytree(encode_pytree(jtree))
    _bitwise_equal(jax.tree.map(np.asarray, jtree), out2)


def test_frame_roundtrip():
    header = {"op": "SUBMIT", "user": "u0", "mode": "C"}
    body = b"\x00\x01binary\xff"
    framed = pack_frame(header, body)
    # strip the outer length prefix, as the stream reader does
    import struct
    (n,) = struct.unpack("!I", framed[:4])
    assert n == len(framed) - 4
    h, b = split_frame(framed[4:])
    assert h == header and b == body


# -- request path over the socket ------------------------------------------

def test_round_trip_flush_timer_head_bitwise():
    """submit → (deadline flush timer) → poll → head, equal bit-for-bit
    to the head the in-process surface serves for the same request."""
    ref = _server()
    t_ref = ref.submit("u0", user_batch(0))
    ref.flush()
    expected = ref.poll(t_ref)

    async def go():
        srv = _server()
        ts = await TransportServer(srv, flush_ms=150.0).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        tid = await c.submit("u0", user_batch(0))
        # below max_pending: nothing has flushed yet — still queued
        assert await c.poll(tid) is None
        head = await c.poll(tid, wait_ms=30_000)   # flush timer fires
        assert head is not None
        assert ts.stats["timer_flushes"] == 1
        again = await c.head("u0")
        stats = await c.stats()
        await c.close()
        await ts.stop()
        return head, again, stats

    head, again, stats = asyncio.run(go())
    _bitwise_equal(head, expected)
    _bitwise_equal(again, expected)
    assert stats["host_materializations"] == 0
    assert stats["cohort_calls"] == 1


def test_full_queue_flushes_synchronously_not_by_timer():
    async def go():
        srv = _server(max_pending=3)
        ts = await TransportServer(srv, flush_ms=60_000.0).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        tids = [await c.submit(f"u{i}", user_batch(i)) for i in range(3)]
        # the 3rd submit filled the queue: served without any timer
        heads = [await c.poll(t, wait_ms=1_000) for t in tids]
        assert all(h is not None for h in heads)
        assert ts.stats["timer_flushes"] == 0
        await c.close()
        await ts.stop()

    asyncio.run(go())


def test_refusals_surface_typed_errors():
    """A request beyond tau_max polls back as code="dropped" over the
    wire (and a fairness refusal as code="capped")."""
    async def go():
        srv = _server(windows=2)                 # tau_max = 1
        ts = await TransportServer(srv, flush_ms=60_000.0).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        tid = await c.submit("slow", user_batch(0))
        await c.advance(flush=False)
        await c.advance(flush=False)             # tau = 2 > tau_max
        await c.flush()
        with pytest.raises(TransportError) as ei:
            await c.poll(tid)
        assert ei.value.code == "dropped"

        srv2 = _server(user_cap=1)
        srv2.batcher.user_cap = None             # ring is the authority
        ts2 = await TransportServer(srv2, flush_ms=60_000.0,
                                    max_inflight=8).start()
        c2 = await AsyncTransportClient("127.0.0.1", ts2.port).connect()
        # the transport's door check spans its own connections, so the
        # over-cap row must come from traffic it cannot see: in-process
        # submits sharing the same server (pre-filter drift)
        t_local = srv2.submit("heavy", user_batch(1))
        t2 = await c2.submit("heavy", user_batch(2))
        await c2.flush()
        assert t_local.status == "done"
        with pytest.raises(TransportError) as ei:
            await c2.poll(t2)
        assert ei.value.code == "capped"
        for cl in (c, c2):
            await cl.close()
        await ts.stop()
        await ts2.stop()

    asyncio.run(go())


# -- backpressure ----------------------------------------------------------

def test_backpressure_busy_server_scope():
    async def go():
        srv = _server()
        ts = await TransportServer(srv, flush_ms=60_000.0,
                                   max_inflight=2).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        t0 = await c.submit("u0", user_batch(0))
        t1 = await c.submit("u1", user_batch(1))
        with pytest.raises(TransportBusy) as ei:
            await c.submit("u2", user_batch(2))
        assert ei.value.scope == "server"
        assert ts.stats["busy"] == 1
        # terminal polls free the slots: the queue drains and refills
        await c.flush()
        assert (await c.poll(t0, wait_ms=1_000)) is not None
        assert (await c.poll(t1, wait_ms=1_000)) is not None
        await c.submit("u2", user_batch(2))      # accepted now
        await c.close()
        await ts.stop()

    asyncio.run(go())


def test_backpressure_busy_connection_and_user_scopes():
    async def go():
        srv = _server(user_cap=1)
        ts = await TransportServer(srv, flush_ms=60_000.0,
                                   conn_inflight=2).start()
        c1 = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        c2 = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        # per-user door check (honors user_cap before burning a slot) —
        # and it spans connections: the same user on ANOTHER socket is
        # refused too
        await c1.submit("shared", user_batch(0))
        with pytest.raises(TransportBusy) as ei:
            await c1.submit("shared", user_batch(1))
        assert ei.value.scope == "user"
        with pytest.raises(TransportBusy) as ei:
            await c2.submit("shared", user_batch(1))
        assert ei.value.scope == "user"
        # ...and counts rows the ring already ADMITTED this window
        await c1.flush()
        with pytest.raises(TransportBusy) as ei:
            await c2.submit("shared", user_batch(2))
        assert ei.value.scope == "user"
        # per-connection bound; the other connection is unaffected
        await c1.submit("other", user_batch(2))
        with pytest.raises(TransportBusy) as ei:
            await c1.submit("third", user_batch(3))
        assert ei.value.scope == "connection"
        await c2.submit("fourth", user_batch(4))
        await c1.close()
        await c2.close()
        await ts.stop()

    asyncio.run(go())


def test_dead_connection_releases_inflight_slots():
    async def go():
        srv = _server()
        ts = await TransportServer(srv, flush_ms=60_000.0,
                                   max_inflight=2).start()
        c1 = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        await c1.submit("u0", user_batch(0))
        await c1.submit("u1", user_batch(1))
        await c1.close()                          # frees both slots
        await asyncio.sleep(0.05)
        c2 = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        await c2.submit("u2", user_batch(2))      # no BUSY
        await c2.close()
        await ts.stop()

    asyncio.run(go())


# -- protocol robustness ---------------------------------------------------

def test_unknown_ops_and_tickets_are_typed_errors():
    async def go():
        srv = _server()
        ts = await TransportServer(srv).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        with pytest.raises(TransportError) as ei:
            await c._rpc({"op": "NOPE"})
        assert ei.value.code == "unknown_op"
        with pytest.raises(TransportError) as ei:
            await c.poll(12345)
        assert ei.value.code == "unknown_ticket"
        with pytest.raises(TransportError) as ei:
            await c.head("nobody")
        assert ei.value.code == "unknown_user"
        with pytest.raises(TransportError) as ei:
            await c.submit("u", user_batch(0), mode="Z")
        assert ei.value.code == "bad_mode"
        # an undecodable npz body is a bad_request for THAT frame only:
        # no flush ran, other queued tickets are untouched and serve once
        t_ok = await c.submit("fine", user_batch(1))
        with pytest.raises(TransportError) as ei:
            await c._rpc({"op": "SUBMIT", "user": "u", "mode": "C"},
                         b"not-an-npz")
        assert ei.value.code == "bad_request"
        assert ts.stats["failed_flushes"] == 0
        await c.flush()
        assert (await c.poll(t_ok, wait_ms=1_000)) is not None
        await c.close()
        await ts.stop()

    asyncio.run(go())


def test_poisoned_batch_fails_typed_and_server_survives():
    """A malformed batch (wrong keys/shapes — remote clients send
    arbitrary pytrees) must not kill the event loop or strand tickets:
    the failed flush group polls back as server_error and the NEXT
    well-formed request is served normally."""
    async def go():
        srv = _server(max_pending=2)
        ts = await TransportServer(srv, flush_ms=60_000.0).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        t_ok = await c.submit("good", user_batch(0))
        # second submit fills the queue -> auto-flush with the poison in
        bad = {"wrong_key": np.zeros((3, 3), np.float32)}
        with pytest.raises(TransportError) as ei:
            await c.submit("evil", bad)
        assert ei.value.code == "server_error"
        # the good ticket was in the poisoned drain: typed failure
        with pytest.raises(TransportError) as ei:
            await c.poll(t_ok, wait_ms=1_000)
        assert ei.value.code == "server_error"
        assert ts.stats["failed_flushes"] == 1
        # the server is still alive and serving
        t2 = await c.submit("good", user_batch(1))
        await c.flush()
        assert (await c.poll(t2, wait_ms=1_000)) is not None
        await c.close()
        await ts.stop()

    asyncio.run(go())


def test_clean_shutdown_closes_connections():
    """stop() must return promptly even with a handler parked in a long
    POLL wait — the task is cancelled, not stranded."""
    async def go():
        srv = _server()
        ts = await TransportServer(srv, flush_ms=60_000.0).start()
        c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        assert (await c.stats())["window"] == 0
        # park a second connection in a 60s POLL wait
        c2 = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        tid = await c2.submit("u", user_batch(0))
        waiter = asyncio.ensure_future(c2.poll(tid, wait_ms=60_000))
        await asyncio.sleep(0.05)
        t0 = asyncio.get_running_loop().time()
        await ts.stop()
        assert asyncio.get_running_loop().time() - t0 < 2.0
        with pytest.raises((ConnectionError, OSError)):
            await c.stats()
        with pytest.raises((ConnectionError, OSError,
                            TransportError)):
            await waiter
        await c.close()
        await c2.close()

    asyncio.run(go())


# -- second OS process -----------------------------------------------------

CLIENT_SCRIPT = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.serving.transport import TransportClient

    port, out = int(sys.argv[1]), sys.argv[2]
    rng = np.random.RandomState(7)
    batch = {"x": rng.randn(8, 5).astype(np.float32),
             "y": rng.randint(0, 4, 8).astype(np.int32)}
    c = TransportClient("127.0.0.1", port, timeout=120.0)
    tid = c.submit("remote-user", batch)
    head = c.poll(tid, wait_ms=60_000)
    assert head is not None, "poll timed out"
    again = c.head("remote-user")
    for a, b in zip(head.values(), again.values()):
        assert np.array_equal(a, b)
    stats = c.stats()
    assert stats["host_materializations"] == 0, stats
    np.savez(out, **head)
    c.close()
""")


def test_second_process_personalizes_over_the_socket(tmp_path):
    """A separate OS process submits a batch and fetches its personalized
    head over the socket; the head equals the in-process result
    bit-for-bit."""
    script = tmp_path / "client.py"
    script.write_text(CLIENT_SCRIPT)
    out = tmp_path / "head.npz"
    rng = np.random.RandomState(7)
    batch = {"x": rng.randn(8, 5).astype(np.float32),
             "y": rng.randint(0, 4, 8).astype(np.int32)}
    ref = _server()
    t_ref = ref.submit("remote-user", batch)
    ref.flush()
    expected = jax.tree.map(np.asarray, ref.poll(t_ref))

    async def go():
        srv = _server()
        ts = await TransportServer(srv, flush_ms=50.0).start()
        src = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (src, env.get("PYTHONPATH", "")) if p)
        proc = await asyncio.create_subprocess_exec(
            sys.executable, str(script), str(ts.port), str(out), env=env,
            stderr=asyncio.subprocess.PIPE)
        try:
            _, err = await asyncio.wait_for(proc.communicate(),
                                            timeout=240)
        finally:
            if proc.returncode is None:
                proc.kill()
        assert proc.returncode == 0, err.decode()[-2000:]
        stats = dict(srv.stats)
        await ts.stop()
        return stats

    stats = asyncio.run(go())
    assert stats["host_materializations"] == 0
    with np.load(out) as z:
        got = {k: z[k] for k in z.files}
    _bitwise_equal(got, expected)
