"""Load generator for the ``serve_transport`` benchmark row.

Runs as its OWN OS process (the point of the transport: a second process
driving personalization over the socket): N concurrent
:class:`repro.serving.transport.AsyncTransportClient` connections each
submit one request per aggregation window and poll the personalized head
back; a coordinator ADVANCE closes each window.  Client-side npz
encode/decode therefore burns this process's core, not the server's event
loop — exactly the deployment shape.

Emits one JSON line to stdout: best-of-``--reps`` wall seconds over
``--rounds`` windows plus per-request submit→head latencies (seconds).

  PYTHONPATH=src python -m benchmarks.transport_loadgen --port P --conns 32
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import numpy as np

from repro.serving.transport import AsyncTransportClient


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--conns", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--rows", type=int, default=32)
    args = ap.parse_args()

    rng = np.random.RandomState(0)
    batches = [{"images": rng.randn(args.rows, args.d).astype(np.float32),
                "labels": rng.randint(0, 10, args.rows).astype(np.int32)}
               for _ in range(args.conns)]

    async def drive():
        clients = []
        for _ in range(args.conns):
            clients.append(await AsyncTransportClient(
                args.host, args.port).connect())

        async def one(u: int, lat) -> None:
            t0 = time.perf_counter()
            tid = await clients[u].submit(f"user{u}", batches[u])
            head = await clients[u].poll(tid, wait_ms=120_000)
            assert head is not None, "poll timed out"
            lat.append(time.perf_counter() - t0)

        async def window(lat) -> None:
            await asyncio.gather(*(one(u, lat)
                                   for u in range(args.conns)))
            await clients[0].advance()

        await window([])                       # warm-up (server compiles)
        best, lat = float("inf"), []
        for _ in range(args.reps):
            lat_rep = []
            t0 = time.time()
            for _ in range(args.rounds):
                await window(lat_rep)
            wall = time.time() - t0
            if wall < best:
                best, lat = wall, lat_rep
        for c in clients:
            await c.close()
        return {"wall_s": best, "latencies_s": lat,
                "conns": args.conns, "rounds": args.rounds}

    print(json.dumps(asyncio.run(drive())), flush=True)


if __name__ == "__main__":
    main()
