"""Benchmark harness — one function per paper table/figure.

  fig2a_concurrency   — active-client ratio, async vs sync (Figure 2a)
  fig2b_mnist         — 8-algorithm personalized accuracy, hetero MNIST-like
                        within a fixed communication-time budget (Figure 2b)
  fig2c_cifar         — same on CIFAR-like data (Figure 2c)
  table1_staleness    — FedAsync convergence vs maximum delay τ (Table 1's
                        O(1/√T)+O(τ²/T) staleness term, empirically)
  engine              — vectorized cohort engine vs per-event dispatch,
                        32-client buffered-async run (wall-clock speedup,
                        plus padding_waste / host_materializations stats)
  engine_sharded      — shard_map cohort split over 8 forced host devices
                        vs single-device vmap, equality at cohort ≥ 32
  serve               — batched personalization through
                        PersonalizationServer vs per-request loop at 32
                        concurrent users (req/s, zero host materializations)
  serve_transport     — N concurrent socket connections driving submit/poll
                        through TransportServer vs the in-process server
                        path (req/s, p50/p99 latency, ≤1.5x gate, zero
                        host materializations)
  partial             — head-only (personal_subset) serving vs full-model:
                        ring_bytes_per_user ≥ 20x smaller (gated), backbone
                        bit-parity across windows, users/GiB residency row,
                        and a fig2-config convergence pin (|Δacc| ≤ 0.1)
  quant               — int8 delta banking + compressed wire: apply_rows_q
                        kernel parity vs the jnp oracle (gated), ring
                        residency ≥ 3.5x smaller than fp32 banking (gated),
                        SUBMIT/HEAD wire bodies ≥ 3.5x smaller (gated),
                        fig2-config convergence pin fp32 vs int8+EF
                        (|Δacc| ≤ 0.1, gated), host_materializations == 0
  scale               — scenario engine at 10^3→10^6 simulated clients:
                        DeviceScheduler window cost (sub-linear in n,
                        gated), robust admission of adversary-corrupted
                        cohort banks, host_materializations == 0 (gated)
  kernels             — Pallas kernels (interpret) vs jnp oracle, µs/call

Prints ``name,us_per_call,derived`` CSV lines (plus per-figure CSV blocks)
AND appends one machine-readable JSON line per bench run to
``experiments/bench/BENCH_<name>.json`` (JSONL: wall_s, gate results,
measured bytes — CI and sweep scripts parse these instead of scraping
stdout).  Env: BENCH_FAST=1 shrinks rounds for smoke runs.

  PYTHONPATH=src python -m benchmarks.run [--only fig2a,kernels]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (ALGOS, FAST, acc_at_time_budget, run_algo,
                               setup)

OUT_DIR = "experiments/bench"


def _save(name, obj):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=2)


def _bench_log(name, row):
    """Append one machine-readable JSON line for this bench run.

    ``<name>.json`` (:func:`_save`) holds the latest run's full result;
    ``BENCH_<name>.json`` accumulates one JSONL row per run so CI gate
    checks and regression sweeps parse records instead of scraping the
    stdout CSV."""
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"BENCH_{name}.json"), "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")


def fig2a_concurrency():
    """Figure 2a: proportion of active users, async vs sync."""
    clients, params, loss, acc, ev = setup("mnist", n_clients=30)
    r_async = run_algo("fedasync", clients, params, loss, None,
                       async_rounds=60 if FAST else 150)
    r_sync = run_algo("fedavg", clients, params, loss, None,
                      sync_rounds=6 if FAST else 15)
    print("fig2a,algo,mean_active_ratio")
    print(f"fig2a,async,{r_async['mean_active_ratio']:.3f}")
    print(f"fig2a,sync,{r_sync['mean_active_ratio']:.3f}")
    derived = r_async["mean_active_ratio"] - r_sync["mean_active_ratio"]
    print(f"fig2a_concurrency,{(r_async['wall_s']+r_sync['wall_s'])*1e6:.0f},"
          f"{derived:.3f}")
    _save("fig2a", {"async": r_async, "sync": r_sync})
    return derived


def _figure2(kind: str):
    clients, params, loss, acc, ev = setup(kind, n_clients=30)
    async_rounds = 60 if FAST else 160
    sync_rounds = 8 if FAST else 24
    results = {}
    for algo in ALGOS:
        r = run_algo(algo, clients, params, loss, ev,
                     async_rounds=async_rounds, sync_rounds=sync_rounds)
        results[algo] = r
        print(f"fig2_{kind},{algo},final_acc={r['acc'][-1]:.3f},"
              f"wall={r['wall_s']:.0f}s", flush=True)
    # equal simulated-communication-time budget (paper: fixed time window)
    budget = min(max(r["times"]) for r in results.values() if r["times"])
    print(f"fig2_{kind},time_budget,{budget:.0f}")
    print(f"fig2_{kind},algo,acc_at_budget")
    for algo, r in results.items():
        print(f"fig2_{kind},{algo},{acc_at_time_budget(r, budget):.3f}")
    _save(f"fig2_{kind}", {k: v for k, v in results.items()})
    return results, budget


def fig2b_mnist():
    results, budget = _figure2("mnist")
    ours = max(acc_at_time_budget(results[a], budget)
               for a in ("persafl-maml", "persafl-me"))
    base = max(acc_at_time_budget(results[a], budget)
               for a in ("fedavg", "fedasync", "fedprox", "scaffold"))
    print(f"fig2b_mnist,0,{ours - base:.3f}")
    return results


def fig2c_cifar():
    results, budget = _figure2("cifar")
    ours = max(acc_at_time_budget(results[a], budget)
               for a in ("persafl-maml", "persafl-me"))
    base = max(acc_at_time_budget(results[a], budget)
               for a in ("fedavg", "fedasync", "fedprox", "scaffold"))
    print(f"fig2c_cifar,0,{ours - base:.3f}")
    return results


def table1_staleness():
    """Empirical staleness tolerance: FedAsync accuracy vs delay scale."""
    from repro.core import PersAFLConfig
    from repro.fl import DelayModel, FLRun, immediate
    clients, params, loss, acc, ev = setup("mnist", n_clients=20)
    rounds = 60 if FAST else 120
    rows = []
    for scale in (1.0, 4.0, 16.0):
        pcfg = PersAFLConfig(option="A", q_local=5, eta=0.01)
        sim = FLRun(clients=clients, loss_fn=loss,
                    init_params=params, pcfg=pcfg,
                    delays=DelayModel(len(clients), seed=1, scale=scale,
                                      jitter=(0.2, 3.0)),
                    strategy="fedasync", schedule=immediate(),
                    batch_size=16, seed=0)
        h = sim.run(max_rounds=rounds, eval_every=rounds, eval_fn=ev)
        tau = max(h.staleness) if h.staleness else 0
        rows.append({"delay_scale": scale, "tau_max": tau,
                     "acc": h.acc[-1] if h.acc else 0.0})
        print(f"table1,scale={scale},tau_max={tau},acc={rows[-1]['acc']:.3f}",
              flush=True)
    _save("table1_staleness", rows)
    # derived: accuracy degradation from smallest to largest tau
    print(f"table1_staleness,0,{rows[0]['acc'] - rows[-1]['acc']:.3f}")
    return rows


def engine():
    """Cohort engine speedup: one vmapped call per inter-apply window vs one
    jitted dispatch per client event, same ``FLRun(schedule=buffered(M))``
    schedule.

    Uses the dispatch-bound regime the engine targets — a per-user
    personalized head (logistic model on feature vectors, the serving-side
    workload): at 32+ concurrent clients the per-event path pays O(cohort)
    device round-trips per window, the engine pays one."""
    from repro.core import PersAFLConfig, init_server_state
    from repro.data.federated import ClientData
    from repro.fl import DelayModel, FLRun, buffered

    d, n_clients = 32, 32
    rng = np.random.RandomState(0)
    clients = []
    for _ in range(n_clients):
        x = rng.randn(256, d).astype(np.float32)
        y = rng.randint(0, 10, 256).astype(np.int32)
        clients.append(ClientData(train_x=x, train_y=y, test_x=x[:32],
                                  test_y=y[:32], classes=tuple(range(10))))

    def loss(p, b):
        logits = b["images"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 10) * logp, -1))

    params = {"w": jnp.zeros((d, 10)), "b": jnp.zeros((10,))}
    rounds = 1536 if FAST else 4096
    walls, calls = {}, {}
    for vectorized in (True, False):
        sim = FLRun(
            clients=clients, loss_fn=loss, init_params=params,
            pcfg=PersAFLConfig(option="A", q_local=1, eta=0.05),
            delays=DelayModel(len(clients), seed=1), batch_size=8, seed=0,
            strategy="persafl", schedule=buffered(32),
            vectorized=vectorized)
        def reset():
            # replay the identical schedule every repetition: fresh batch
            # rng + delay streams + server state, so warm-up compiles every
            # cohort bucket the timed runs will see
            sim.rng = np.random.RandomState(0)
            sim.delays = DelayModel(len(clients), seed=1)
            sim.state = init_server_state(jax.tree.map(jnp.array, params))
            sim.engine.stats.update(cohort_calls=0, clients=0, max_cohort=0,
                                    padding_waste=0, host_materializations=0)

        reset()
        sim.run(max_rounds=rounds)                 # warm-up: compiles
        best = float("inf")
        for _ in range(3):                         # best-of-3: 2-vCPU noise
            reset()
            t0 = time.time()
            sim.run(max_rounds=rounds)
            best = min(best, time.time() - t0)
        walls[vectorized] = best
        stats = dict(sim.engine.stats)             # identical per repetition
        calls[vectorized] = max(stats["cohort_calls"], 1)
        path = "vectorized" if vectorized else "per_event"
        print(f"engine,{path},wall_s={walls[vectorized]:.3f},"
              f"cohort_calls={stats['cohort_calls']},"
              f"max_cohort={stats['max_cohort']},"
              f"padding_waste={stats['padding_waste']},"
              f"host_materializations={stats['host_materializations']}",
              flush=True)
    speedup = walls[False] / walls[True]
    print(f"engine,{walls[True] / calls[True] * 1e6:.0f},"
          f"speedup={speedup:.2f}")
    _save("engine", {"wall_vectorized_s": walls[True],
                     "wall_per_event_s": walls[False], "speedup": speedup})
    return speedup


def engine_sharded():
    """8-virtual-device CPU shard_map cohort vs single-device vmap.

    The acceptance row: the sharded path must complete a cohort ≥ 32 run
    with deltas equal to the vmap path (atol ≤ 1e-5).  Needs the forced
    host-device split BEFORE jax initializes, so when the parent process
    sees < 8 devices it re-execs itself with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and passes the
    child's engine_sharded rows through.
    """
    if jax.device_count() < 8:
        if os.environ.get("_ENGINE_SHARDED_CHILD"):
            raise RuntimeError(
                "forced 8-device split did not take effect "
                f"(device_count={jax.device_count()})")
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8"
                            ).strip()
        env["_ENGINE_SHARDED_CHILD"] = "1"
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only",
             "engine_sharded"],
            env=env, capture_output=True, text=True)
        rows = [line for line in res.stdout.splitlines()
                if line.startswith("engine_sharded,")]
        for line in rows:
            print(line, flush=True)
        if res.returncode != 0 or not rows:
            sys.stderr.write(res.stderr[-4000:])
            raise RuntimeError("engine_sharded 8-device child failed")
        return

    from repro.core import PersAFLConfig
    from repro.fl import CohortEngine

    t_bench0 = time.time()
    d, cohort = 32, 32
    rng = np.random.RandomState(0)

    def loss(p, b):
        logits = b["images"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 10) * logp, -1))

    pcfg = PersAFLConfig(option="A", q_local=4, eta=0.05)
    params = {"w": jnp.zeros((d, 10)), "b": jnp.zeros((10,))}
    batch_list = [{"images": rng.randn(3 * pcfg.q_local, 16, d
                                       ).astype(np.float32),
                   "labels": rng.randint(0, 10, (3 * pcfg.q_local, 16)
                                         ).astype(np.int32)}
                  for _ in range(cohort)]

    engines = {"vmap": CohortEngine(pcfg, loss, cohort_impl="vmap"),
               "shard_map": CohortEngine(pcfg, loss,
                                         cohort_impl="shard_map")}
    walls, stacks = {}, {}
    for name, eng in engines.items():
        bank = eng.update_cohort(params, batch_list)        # warm-up
        jax.block_until_ready(bank.stacked)
        best = float("inf")
        for _ in range(3):
            t0 = time.time()
            bank = eng.update_cohort(params, batch_list)
            jax.block_until_ready(bank.stacked)
            best = min(best, time.time() - t0)
        walls[name] = best
        stacks[name] = jax.device_get(bank.stacked)
        print(f"engine_sharded,{name},wall_s={best:.3f},"
              f"devices={jax.device_count() if name == 'shard_map' else 1},"
              f"cohort={cohort},"
              f"padding_waste={eng.stats['padding_waste']}", flush=True)
    diff = max(float(np.max(np.abs(a - b))) for a, b in
               zip(jax.tree.leaves(stacks["vmap"]),
                   jax.tree.leaves(stacks["shard_map"])))
    equal = diff <= 1e-5
    print(f"engine_sharded,{walls['shard_map'] * 1e6:.0f},"
          f"max_abs_diff={diff:.2e},equal={equal}", flush=True)
    gates = {"equal_atol_1e-5": equal}
    result = {"wall_vmap_s": walls["vmap"],
              "wall_shard_map_s": walls["shard_map"],
              "devices": jax.device_count(),
              "cohort": cohort, "max_abs_diff": diff,
              "equal_atol_1e-5": equal,
              "wall_s": time.time() - t_bench0, "gates": gates}
    _save("engine_sharded", result)
    _bench_log("engine_sharded", result)
    if not equal:   # this row is a gate, not a report — fail the run
        raise RuntimeError(f"shard_map deltas diverge from vmap: {diff:.2e}")
    return diff


def serve():
    """Batched personalization throughput: PersonalizationServer (one
    cohort call per micro-batch) vs the pre-subsystem per-request loop
    (one jitted prox solve dispatch per user), 32 concurrent users.

    This is the serving-side twin of the ``engine`` row: per-user heads
    are tiny, so the work is dispatch-bound and the per-request loop pays
    O(users) device round-trips where the server pays one.  Steady state
    must keep ``host_materializations`` at 0 — heads are served as
    device-side gathers from the stacked head bank."""
    from repro.core import PersAFLConfig
    from repro.core.moreau import personalize_me
    from repro.serving import PersonalizationServer

    t_bench0 = time.time()
    d, users, rounds = 32, 32, 4 if FAST else 8
    rng = np.random.RandomState(0)

    def loss(p, b):
        logits = b["images"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 10) * logp, -1))

    params = {"w": jnp.zeros((d, 10)), "b": jnp.zeros((10,))}
    pcfg = PersAFLConfig(option="C", lam=20.0, inner_steps=5,
                         inner_eta=0.05, beta=0.5)
    # payloads stay host-side numpy, as a network-facing server holds them:
    # the micro-batcher stacks them in one memcpy per leaf, while the
    # per-request loop pays a host→device transfer per dispatch
    batches = [{"images": rng.randn(16, d).astype(np.float32),
                "labels": rng.randint(0, 10, 16).astype(np.int32)}
               for _ in range(users)]

    # baseline: the old launch/serve.py shape — one dispatch per request
    per_req = jax.jit(lambda p, b: personalize_me(
        loss, p, b, pcfg.lam, pcfg.inner_eta, pcfg.inner_steps))
    jax.block_until_ready(per_req(params, batches[0]))      # warm-up
    t_loop = float("inf")
    for _ in range(3):
        t0 = time.time()
        for _ in range(rounds):
            for b in batches:
                jax.block_until_ready(per_req(params, b))
        t_loop = min(t_loop, time.time() - t0)

    server = PersonalizationServer(params, loss, pcfg, modes=("C",),
                                   max_pending=2 * users)
    uids = [f"user{u}" for u in range(users)]

    def window():
        for uid, b in zip(uids, batches):
            server.submit(uid, b, mode="C")
        server.flush()
        jax.block_until_ready(server.stacked_heads(uids))
        server.advance_window()

    window()                                                # warm-up
    warm_windows = server.stats["ring_windows"]
    t_server = float("inf")
    for _ in range(3):
        t0 = time.time()
        for _ in range(rounds):
            window()
        t_server = min(t_server, time.time() - t0)
    stats = server.stats
    host_mat = stats["host_materializations"]
    n_req = users * rounds
    speedup = t_loop / t_server
    print(f"serve,per_request,wall_s={t_loop:.3f},"
          f"req_per_s={n_req / t_loop:.0f}", flush=True)
    print(f"serve,server,wall_s={t_server:.3f},"
          f"req_per_s={n_req / t_server:.0f},"
          f"windows={stats['ring_windows'] - warm_windows},"
          f"cohort_calls={stats['cohort_calls']},"
          f"ring_bytes_per_user={stats['ring_bytes_per_user']},"
          f"host_materializations={host_mat}", flush=True)
    print(f"serve,{t_server / n_req * 1e6:.0f},speedup={speedup:.2f}")
    gates = {"host_materializations_zero": host_mat == 0}
    result = {"users": users, "rounds": rounds,
              "wall_per_request_s": t_loop,
              "wall_server_s": t_server, "speedup": speedup,
              "req_per_s_server": n_req / t_server,
              "req_per_s_per_request": n_req / t_loop,
              "ring_bytes_per_user": int(stats["ring_bytes_per_user"]),
              "host_materializations": int(host_mat),
              "wall_s": time.time() - t_bench0, "gates": gates}
    _save("serve", result)
    _bench_log("serve", result)
    if host_mat != 0:    # steady-state contract, not a report
        raise RuntimeError(f"serving path materialized {host_mat} banks")
    return speedup


def serve_transport():
    """Transport front-end throughput: N concurrent client connections in
    a SECOND OS PROCESS (``benchmarks.transport_loadgen``) driving
    submit/poll over the loopback socket vs the same windowed workload
    through the in-process PersonalizationServer surface.

    The contract under test: the transport must NOT forfeit the
    micro-batching win — all N connections' submits coalesce into the same
    pow2-bucketed cohort calls (the queue fills to ``max_pending`` and
    flushes synchronously; the ``flush_ms`` deadline timer only catches
    stragglers) and served heads are encoded from one stacked gather per
    flush, so batched throughput over the socket stays within 1.5x of the
    in-process path (gated) and steady-state ``host_materializations``
    stays 0 (gated).  Reports req/s plus p50/p99 per-request latency
    (submit → personalized head on the client).  The head is
    personalization-sized (d=256 features, K=200 prox steps) — at toy
    sizes the wire codec, not the serving stack, dominates both paths."""
    import asyncio

    from repro.core import PersAFLConfig
    from repro.serving import PersonalizationServer
    from repro.serving.transport import TransportServer

    d, rows, conns = 256, 32, 32
    rounds, reps = 4 if FAST else 8, 3
    rng = np.random.RandomState(0)

    def loss(p, b):
        logits = b["images"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 10) * logp, -1))

    params = {"w": jnp.zeros((d, 10)), "b": jnp.zeros((10,))}
    pcfg = PersAFLConfig(option="C", lam=20.0, inner_steps=200,
                         inner_eta=0.01, beta=0.5)
    # the loadgen process generates bit-identical batches (same seed)
    batches = [{"images": rng.randn(rows, d).astype(np.float32),
                "labels": rng.randint(0, 10, rows).astype(np.int32)}
               for _ in range(conns)]
    uids = [f"user{u}" for u in range(conns)]

    def make_server():
        return PersonalizationServer(params, loss, pcfg, modes=("C",),
                                     max_pending=conns)

    # in-process baseline: the `serve` row's server path at the same
    # (users, rounds) — submit all, flush, fetch every head, advance
    srv = make_server()

    def window():
        tickets = [srv.submit(u, b) for u, b in zip(uids, batches)]
        srv.flush()
        for t in tickets:
            jax.block_until_ready(jax.tree.leaves(srv.poll(t))[0])
        srv.advance_window()

    window()                                                 # warm-up
    t_inproc = float("inf")
    for _ in range(reps):
        t0 = time.time()
        for _ in range(rounds):
            window()
        t_inproc = min(t_inproc, time.time() - t0)

    # transport: boot the front-end here, drive it from the loadgen
    # process — one connection per user, all submits racing the same
    # queue; the Nth submit triggers the synchronous micro-batch flush
    async def drive():
        psrv = make_server()
        ts = await TransportServer(psrv, flush_ms=100.0,
                                   max_inflight=4 * conns).start()
        proc = await asyncio.create_subprocess_exec(
            sys.executable, "-m", "benchmarks.transport_loadgen",
            "--port", str(ts.port), "--conns", str(conns),
            "--rounds", str(rounds), "--reps", str(reps),
            "--d", str(d), "--rows", str(rows),
            stdout=asyncio.subprocess.PIPE)
        out, _ = await proc.communicate()
        if proc.returncode != 0:
            raise RuntimeError(
                f"transport loadgen exited {proc.returncode}")
        res = json.loads(out.decode().splitlines()[-1])
        stats = dict(psrv.stats)
        await ts.stop()
        return res["wall_s"], res["latencies_s"], stats

    t_transport, lat, stats = asyncio.run(drive())
    n_req = conns * rounds
    p50 = float(np.percentile(lat, 50) * 1e3)
    p99 = float(np.percentile(lat, 99) * 1e3)
    host_mat = int(stats["host_materializations"])
    ratio = t_transport / t_inproc
    print(f"serve_transport,in_process,wall_s={t_inproc:.3f},"
          f"req_per_s={n_req / t_inproc:.0f}", flush=True)
    print(f"serve_transport,transport,wall_s={t_transport:.3f},"
          f"req_per_s={n_req / t_transport:.0f},conns={conns},"
          f"p50_ms={p50:.2f},p99_ms={p99:.2f},"
          f"cohort_calls={stats['cohort_calls']},"
          f"host_materializations={host_mat}", flush=True)
    print(f"serve_transport,{t_transport / n_req * 1e6:.0f},"
          f"ratio_vs_in_process={ratio:.2f}")
    _save("serve_transport", {
        "conns": conns, "rounds": rounds,
        "wall_in_process_s": t_inproc, "wall_transport_s": t_transport,
        "req_per_s_in_process": n_req / t_inproc,
        "req_per_s_transport": n_req / t_transport,
        "p50_ms": p50, "p99_ms": p99,
        "ratio_vs_in_process": ratio,
        "host_materializations": host_mat})
    if host_mat != 0:       # steady-state contract, not a report
        raise RuntimeError(f"transport path materialized {host_mat} banks")
    if ratio > 1.5:         # the micro-batching win must survive the wire
        raise RuntimeError(
            f"transport throughput {ratio:.2f}x slower than in-process "
            f"(bound: 1.5x) — the socket front-end forfeited batching")
    return ratio


def serve_mesh():
    """2-D ("cohort", "model") mesh serving (PR 10 acceptance row).

    Drives the same windowed personalization workload on the 1-D 8-way
    ``("cohort",)`` mesh and the 2-D ``(2, 4)`` mesh with model-axis
    param shardings, and gates the tentpole's contract:

      * bit-parity — final global params AND every served head are
        ``np.array_equal`` between the two layouts (the mesh is a layout
        choice, never a semantics choice);
      * steady state — ``host_materializations`` stays 0 on BOTH layouts
        (gather-not-transfer on both mesh axes);
      * residency — per-device peak delta/snapshot/params residency on
        the 2x4 mesh is ≤ 0.6x the 1-D peak at equal users: the model
        axis splits every stored row 4 ways and the 2-slice cohort axis
        buckets 4 users into 4 rows where the 8-slice 1-D mesh pads to 8.

    Like ``engine_sharded``, needs the forced 8-device split before jax
    initializes — re-execs itself when the parent sees < 8 devices.
    """
    if jax.device_count() < 8:
        if os.environ.get("_SERVE_MESH_CHILD"):
            raise RuntimeError(
                "forced 8-device split did not take effect "
                f"(device_count={jax.device_count()})")
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8"
                            ).strip()
        env["_SERVE_MESH_CHILD"] = "1"
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only",
             "serve_mesh"],
            env=env, capture_output=True, text=True)
        rows = [line for line in res.stdout.splitlines()
                if line.startswith("serve_mesh,")]
        for line in rows:
            print(line, flush=True)
        if res.returncode != 0 or not rows:
            sys.stderr.write(res.stderr[-4000:])
            raise RuntimeError("serve_mesh 8-device child failed")
        return

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import PersAFLConfig
    from repro.serving import PersonalizationServer
    from repro.sharding.ctx import cohort_mesh, cohort_model_mesh

    t_bench0 = time.time()
    rng = np.random.RandomState(0)
    d, classes, windows = 64, 64, 4

    def loss(p, b):
        logits = b["images"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(
            jax.nn.one_hot(b["labels"], classes) * logp, -1))

    params = {"w": jnp.asarray(rng.randn(d, classes) * 0.1, jnp.float32),
              "b": jnp.zeros((classes,), jnp.float32)}
    pcfg = PersAFLConfig(option="C", lam=20.0, inner_steps=2,
                         inner_eta=0.02, beta=0.5, alpha=0.05)
    # crc32-balanced user ids (distinct mod 8, 2/2 mod 2): both layouts
    # bucket them without cross-slice collisions, so the residency
    # comparison measures the mesh, not hash luck
    users = ["user000", "user004", "user003", "user007"]
    batches = {u: {"images": rng.randn(8, d).astype(np.float32),
                   "labels": rng.randint(0, classes, 8).astype(np.int32)}
               for u in users}

    def per_device_bytes(srv):
        dev = {}

        def add(x):
            if not hasattr(x, "addressable_shards"):
                return
            for s in x.addressable_shards:
                dev[s.device.id] = dev.get(s.device.id, 0) + s.data.nbytes
        for banks in srv.ring._banks.values():
            for bank in banks:
                jax.tree.map(add, bank.stacked)
        for snap in srv.ring._snapshots.values():
            jax.tree.map(add, snap)
        jax.tree.map(add, srv.params)
        return dev

    def drive(mesh, shardings):
        srv = PersonalizationServer(params, loss, pcfg, modes=("C",),
                                    cohort_impl="shard_map", mesh=mesh,
                                    windows=windows,
                                    param_shardings=shardings)
        heads = {}
        t0 = time.time()
        for _ in range(windows):            # fill the ring to steady state
            tickets = {u: srv.submit(u, batches[u], mode="C")
                       for u in users}
            srv.flush()
            heads = {u: jax.tree.map(np.asarray, srv.poll(t))
                     for u, t in tickets.items()}
            srv.advance_window()
        return srv, heads, time.time() - t0

    srv1, heads1, wall1 = drive(cohort_mesh(), None)
    m24 = cohort_model_mesh(4)
    shardings = {"w": NamedSharding(m24, P(None, "model")),
                 "b": NamedSharding(m24, P("model"))}
    srv2, heads2, wall2 = drive(m24, shardings)

    p1 = jax.tree.map(np.asarray, srv1.params)
    p2 = jax.tree.map(np.asarray, srv2.params)
    params_equal = all(np.array_equal(p1[k], p2[k]) for k in p1)
    heads_equal = all(
        np.array_equal(heads1[u][k], heads2[u][k])
        for u in users for k in heads1[u])
    host_mat = (int(srv1.stats["host_materializations"]),
                int(srv2.stats["host_materializations"]))
    peak1 = max(per_device_bytes(srv1).values())
    peak2 = max(per_device_bytes(srv2).values())
    ratio = peak2 / peak1
    print(f"serve_mesh,1d,wall_s={wall1:.3f},peak_device_bytes={peak1},"
          f"host_materializations={host_mat[0]}", flush=True)
    print(f"serve_mesh,2x4,wall_s={wall2:.3f},peak_device_bytes={peak2},"
          f"params_bit_equal={params_equal},heads_bit_equal={heads_equal},"
          f"host_materializations={host_mat[1]}", flush=True)
    print(f"serve_mesh,0,residency_ratio={ratio:.3f}")
    gates = {"params_bit_equal": params_equal,
             "heads_bit_equal": heads_equal,
             "host_materializations_zero": host_mat == (0, 0),
             "residency_ratio_le_0p6": ratio <= 0.6}
    result = {"users": len(users), "windows": windows,
              "wall_1d_s": wall1, "wall_2x4_s": wall2,
              "peak_device_bytes_1d": int(peak1),
              "peak_device_bytes_2x4": int(peak2),
              "residency_ratio": ratio,
              "params_bit_equal": params_equal,
              "heads_bit_equal": heads_equal,
              "host_materializations_1d": host_mat[0],
              "host_materializations_2x4": host_mat[1],
              "wall_s": time.time() - t_bench0, "gates": gates}
    _save("serve_mesh", result)
    _bench_log("serve_mesh", result)
    for gate, ok in gates.items():
        if not ok:
            raise RuntimeError(f"serve_mesh gate failed: {gate} ({result})")
    return ratio


def partial():
    """Partial-model personalization: head-only rows end-to-end.

    Two gates plus a convergence pin:

      * residency — a ``personal_subset=("b",)`` server on the serve-row
        config banks 40-byte rows where the full server banks 1320-byte
        ones; ``ring_bytes_per_user`` must shrink ≥ 20x (gated).  That
        ratio is the resident-users-at-fixed-memory lever, reported as a
        users-per-GiB row for both servers.
      * backbone bit-parity — across several advanced windows the subset
        server's backbone leaf stays bit-identical to the initial params
        (``np.array_equal``, not allclose): head-only deltas never touch
        it, so ONE shared backbone serves every retained window exactly.
      * convergence pin — on the fig2 MNIST config, personalized accuracy
        with head-only fine-tune (``fc/#1``, the final FC layer) must land
        within 0.1 of full-model personalized fine-tune after a short
        persafl-me run (gated): the head carries the personalization.
    """
    from repro.core import PersAFLConfig
    from repro.serving import PersonalizationServer

    t_bench0 = time.time()
    d, users, windows = 32, 32, 3
    rng = np.random.RandomState(0)

    def loss(p, b):
        logits = b["images"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(jax.nn.one_hot(b["labels"], 10) * logp, -1))

    params = {"w": jnp.zeros((d, 10)), "b": jnp.zeros((10,))}
    pcfg = PersAFLConfig(option="C", lam=20.0, inner_steps=5,
                         inner_eta=0.05, beta=0.5)
    batches = [{"images": rng.randn(16, d).astype(np.float32),
                "labels": rng.randint(0, 10, 16).astype(np.int32)}
               for _ in range(users)]
    uids = [f"user{u}" for u in range(users)]
    w0 = np.asarray(params["w"])

    bytes_per_user = {}
    for name, subset in (("full", None), ("head_only", ("b",))):
        srv = PersonalizationServer(params, loss, pcfg, modes=("C",),
                                    max_pending=2 * users,
                                    personal_subset=subset)
        for _ in range(windows):
            for uid, b in zip(uids, batches):
                srv.submit(uid, b, mode="C")
            srv.flush()
            jax.block_until_ready(srv.stacked_heads(uids))
            srv.advance_window()
            if subset is not None and not np.array_equal(
                    np.asarray(srv.params["w"]), w0):
                raise RuntimeError(
                    "head-only serving perturbed the backbone — subset "
                    "rows must leave non-subset leaves bit-identical")
        st = srv.stats
        bytes_per_user[name] = int(st["ring_bytes_per_user"])
        print(f"partial,{name},ring_row_bytes={st['ring_row_bytes']},"
              f"ring_bytes_per_user={bytes_per_user[name]},"
              f"users_per_gib={2 ** 30 // bytes_per_user[name]},"
              f"host_materializations={st['host_materializations']}",
              flush=True)
    ratio = bytes_per_user["full"] / bytes_per_user["head_only"]

    # convergence pin: fig2 MNIST config, short persafl-me run, then the
    # same personalized eval with full vs head-only fine-tune masks
    from repro.fl import (DelayModel, FLRun, immediate,
                          make_personalized_eval, strategy)
    clients, cparams, closs, cacc, _ = setup("mnist",
                                             n_clients=10 if FAST else 20)
    pcfg2 = PersAFLConfig(option="C", q_local=5, lam=25.0, inner_steps=5,
                          inner_eta=0.02, beta=1.0, eta=0.002)
    sim = FLRun(clients=clients, loss_fn=closs, init_params=cparams,
                pcfg=pcfg2, delays=DelayModel(len(clients), seed=1),
                strategy=strategy("persafl", option="C"),
                schedule=immediate(), batch_size=16, seed=0)
    sim.run(max_rounds=20 if FAST else 60)
    trained = sim.state.params
    ev_full = make_personalized_eval(closs, cacc, clients,
                                     ft_steps=1, ft_lr=0.01)
    ev_head = make_personalized_eval(closs, cacc, clients,
                                     ft_steps=1, ft_lr=0.01,
                                     personal_subset="fc/#1")
    a_full, a_head = float(ev_full(trained)), float(ev_head(trained))
    gap = abs(a_full - a_head)
    print(f"partial,convergence,acc_full={a_full:.3f},"
          f"acc_head_only={a_head:.3f},gap={gap:.3f}", flush=True)
    print(f"partial,0,bytes_ratio={ratio:.1f}")
    gates = {"bytes_ratio_ge_20": ratio >= 20.0,
             "acc_gap_le_0p1": gap <= 0.1,
             "backbone_bit_parity": True}
    result = {
        "ring_bytes_per_user_full": bytes_per_user["full"],
        "ring_bytes_per_user_head_only": bytes_per_user["head_only"],
        "users_per_gib_full": 2 ** 30 // bytes_per_user["full"],
        "users_per_gib_head_only": 2 ** 30 // bytes_per_user["head_only"],
        "bytes_ratio": ratio, "backbone_bit_parity": True,
        "acc_full": a_full, "acc_head_only": a_head, "acc_gap": gap,
        "wall_s": time.time() - t_bench0, "gates": gates}
    _save("partial", result)
    _bench_log("partial", result)
    if ratio < 20.0:    # the residency win is the point — gate it
        raise RuntimeError(
            f"head-only rows only {ratio:.1f}x smaller than full rows "
            f"(bound: 20x) — subset rows are not subset-shaped")
    if gap > 0.1:       # head must carry the personalization
        raise RuntimeError(
            f"head-only personalization diverged from full by {gap:.3f} "
            f"accuracy (bound: 0.1) on the fig2 MNIST config")
    return ratio


def quant():
    """Quantized delta banking + compressed wire, four gates + a pin.

      * kernel parity — ``apply_rows_q`` (interpret) must match the jnp
        oracle within 1e-5 over pow2 and non-pow2 cohorts (gated);
      * residency — an int8-banking server at the serve-transport config
        (d=256 features, 256 classes, 32 users) banks int8 delta rows +
        int8 EF residual rows and stores NO fp32 head bank (heads are lazy
        ``snapshot − scale·q`` views), so ``ring_bytes_per_user`` must be
        ≥ 3.5x smaller than the fp32 twin's (gated) — i.e. ≥ 3.5x ring
        capacity at equal device memory;
      * wire — SUBMIT (32×256 batch) and HEAD (256×256 head) npz bodies
        under ``codec="int8"`` must each be ≥ 3.5x smaller than fp32
        (gated; measured on full bodies, npz container overhead included);
      * convergence pin — fig2 MNIST config driven THROUGH two
        PersonalizationServers (fp32 banking vs int8+EF banking) for the
        same windows; personalized accuracy must land within 0.1 (gated):
        error feedback keeps banking noise a residual, not a bias;
      * steady state — the int8 server's ``host_materializations`` stays 0
        (gated): quantized rows never materialize fp32 on the host.
    """
    from repro.core import PersAFLConfig
    from repro.core.quant import quantize_stack
    from repro.kernels.fused_update.kernel import apply_rows_q
    from repro.kernels.fused_update.ref import apply_rows_q_ref
    from repro.serving import PersonalizationServer
    from repro.serving.transport import encode_pytree

    t_bench0 = time.time()
    rng = np.random.RandomState(0)

    # -- gate 1: kernel parity vs the jnp oracle ---------------------------
    max_diff = 0.0
    for m, shape in ((3, (512,)), (8, (4096,)), (5, (257,))):
        w = jnp.asarray(rng.randn(*shape).astype(np.float32))
        stack = jnp.asarray(
            0.01 * rng.randn(m, *shape).astype(np.float32))
        qs = quantize_stack(stack)
        q, sc = jax.tree.leaves(qs.q)[0], jax.tree.leaves(qs.scales)[0]
        weights = jnp.asarray(rng.rand(m).astype(np.float32))
        got = apply_rows_q(w, q, sc, weights, interpret=True)
        want = apply_rows_q_ref(w, q, sc, weights)
        max_diff = max(max_diff, float(jnp.max(jnp.abs(got - want))))
    kernel_parity = max_diff <= 1e-5
    print(f"quant,kernel_parity,max_abs_diff={max_diff:.2e},"
          f"ok={kernel_parity}", flush=True)

    # -- gate 2: ring residency, int8 vs fp32 twin -------------------------
    d, classes, users, windows = 256, 256, 32, 3

    def loss(p, b):
        logits = b["images"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(
            jax.nn.one_hot(b["labels"], classes) * logp, -1))

    params = {"w": jnp.zeros((d, classes)), "b": jnp.zeros((classes,))}
    pcfg = PersAFLConfig(option="C", lam=20.0, inner_steps=5,
                         inner_eta=0.05, beta=0.5)
    batches = [{"images": rng.randn(32, d).astype(np.float32),
                "labels": rng.randint(0, classes, 32).astype(np.int32)}
               for _ in range(users)]
    uids = [f"user{u}" for u in range(users)]

    bytes_per_user, host_mat = {}, {}
    for dtype in ("fp32", "int8"):
        srv = PersonalizationServer(params, loss, pcfg, modes=("C",),
                                    max_pending=2 * users,
                                    delta_dtype=dtype)
        for _ in range(windows):
            for uid, b in zip(uids, batches):
                srv.submit(uid, b, mode="C")
            srv.flush()
            jax.block_until_ready(srv.stacked_heads(uids))
            srv.advance_window()
        st = srv.stats
        bytes_per_user[dtype] = int(st["ring_bytes_per_user"])
        host_mat[dtype] = int(st["host_materializations"])
        print(f"quant,{dtype},ring_row_bytes={st['ring_row_bytes']},"
              f"ring_bytes_per_user={bytes_per_user[dtype]},"
              f"users_per_gib={2 ** 30 // bytes_per_user[dtype]},"
              f"host_materializations={host_mat[dtype]}", flush=True)
    ring_ratio = bytes_per_user["fp32"] / bytes_per_user["int8"]

    # -- gate 3: wire bytes, SUBMIT and HEAD bodies ------------------------
    submit_bytes = {c: len(encode_pytree(batches[0], codec=c))
                    for c in ("fp32", "int8")}
    head = {"w": rng.randn(d, classes).astype(np.float32),
            "b": rng.randn(classes).astype(np.float32)}
    head_bytes = {c: len(encode_pytree(head, codec=c))
                  for c in ("fp32", "int8")}
    submit_ratio = submit_bytes["fp32"] / submit_bytes["int8"]
    head_ratio = head_bytes["fp32"] / head_bytes["int8"]
    print(f"quant,wire,submit_fp32={submit_bytes['fp32']},"
          f"submit_int8={submit_bytes['int8']},"
          f"submit_ratio={submit_ratio:.2f},"
          f"head_fp32={head_bytes['fp32']},"
          f"head_int8={head_bytes['int8']},"
          f"head_ratio={head_ratio:.2f}", flush=True)

    # -- gate 4: convergence pin on the fig2 MNIST config ------------------
    from repro.fl import make_personalized_eval
    clients, cparams, closs, cacc, _ = setup("mnist", n_clients=16)
    pcfg2 = PersAFLConfig(option="C", lam=25.0, inner_steps=5,
                          inner_eta=0.02, beta=1.0)
    cbatches = [{"images": c.train_x[:16], "labels": c.train_y[:16]}
                for c in clients]
    cuids = [f"client{u}" for u in range(len(clients))]
    ev = make_personalized_eval(closs, cacc, clients,
                                ft_steps=1, ft_lr=0.01)
    accs = {}
    for dtype in ("fp32", "int8"):
        srv = PersonalizationServer(cparams, closs, pcfg2, modes=("C",),
                                    max_pending=2 * len(clients),
                                    delta_dtype=dtype)
        for _ in range(6 if FAST else 12):
            for uid, b in zip(cuids, cbatches):
                srv.submit(uid, b, mode="C")
            srv.flush()
            srv.advance_window()
        accs[dtype] = float(ev(srv.params))
    gap = abs(accs["fp32"] - accs["int8"])
    print(f"quant,convergence,acc_fp32={accs['fp32']:.3f},"
          f"acc_int8_ef={accs['int8']:.3f},gap={gap:.3f}", flush=True)
    print(f"quant,0,ring_ratio={ring_ratio:.2f}")

    wall_s = time.time() - t_bench0
    gates = {"kernel_parity": kernel_parity,
             "ring_ratio_ge_3p5": ring_ratio >= 3.5,
             "submit_ratio_ge_3p5": submit_ratio >= 3.5,
             "head_ratio_ge_3p5": head_ratio >= 3.5,
             "acc_gap_le_0p1": gap <= 0.1,
             "host_materializations_zero": host_mat["int8"] == 0}
    result = {
        "kernel_max_abs_diff": max_diff,
        "ring_bytes_per_user_fp32": bytes_per_user["fp32"],
        "ring_bytes_per_user_int8": bytes_per_user["int8"],
        "ring_ratio": ring_ratio,
        "submit_bytes_fp32": submit_bytes["fp32"],
        "submit_bytes_int8": submit_bytes["int8"],
        "submit_ratio": submit_ratio,
        "head_bytes_fp32": head_bytes["fp32"],
        "head_bytes_int8": head_bytes["int8"],
        "head_ratio": head_ratio,
        "acc_fp32": accs["fp32"], "acc_int8_ef": accs["int8"],
        "acc_gap": gap,
        "host_materializations": host_mat["int8"],
        "wall_s": wall_s, "gates": gates,
    }
    _save("quant", result)
    _bench_log("quant", result)
    for gate, ok in gates.items():
        if not ok:
            raise RuntimeError(f"quant gate failed: {gate} ({result})")
    return ring_ratio


def scale():
    """Scenario engine at 10^3 → 10^6 simulated clients (FAST caps at
    10^5): per window, the DeviceScheduler forms the cohort on device, a
    synthetic cohort delta bank is corrupted by the scenario's
    adversaries (``scale_rows``), admitted through the robust clip path
    and applied with one fused ``apply_admitted_rows`` pass.  Gates:

      * sub-linear wall-clock — growing n by 1000x (100x FAST) must grow
        s/window by well under that factor (the whole point of the
        device-resident scheduler; the Python heap is O(n log n) pops
        per simulated second);
      * ``host_materializations == 0`` — no per-client or per-delta
        array ever crosses to the host (cohort ids/times and row norms
        are the only device→host traffic, all [cohort_cap]-sized).
    """
    from repro.core import (apply_admitted_rows, bank_row_norms,
                            init_server_state, mask_rows,
                            robust_admission_weights, scale_rows)
    from repro.fl.engine import DeltaBank
    from repro.fl.scenario import (Adversarial, DeviceScheduler, Diurnal,
                                   ScenarioSpec, Tier)
    ns = [1_000, 10_000, 100_000] if FAST \
        else [1_000, 10_000, 100_000, 1_000_000]
    windows = 3 if FAST else 5
    d = 1 << 16                    # synthetic per-client delta width
    key = jax.random.PRNGKey(0)
    rows_out = []
    print("scale,n,s_per_window,arrivals,dropouts,corrupted,clipped")
    for n in ns:
        spec = ScenarioSpec(
            n_clients=n, seed=0,
            tiers=(Tier("fast", 0.5, 0.7), Tier("slow", 0.5, 1.6)),
            diurnal=Diurnal(period=400.0, floor=0.25), dropout=0.02,
            adversarial=Adversarial(frac=0.05, kinds=("scale",
                                                      "sign_flip")))
        model = spec.build()
        sched = DeviceScheduler(model, window_len=30.0, cohort_cap=256,
                                cycles_per_window=8)
        cap = sched.cohort_cap
        state = init_server_state({"w": jnp.zeros(d, jnp.float32)})
        base = {"w": 0.01 * jax.random.normal(key, (cap, d), jnp.float32)}
        bank_stats = {}
        corrupted = clipped = 0
        sched.next_window()                  # compile/warm-up window
        t0 = time.time()
        for _ in range(windows):
            ids, _times = sched.next_window()
            fill = len(ids)
            if fill == 0:
                continue
            bank = DeltaBank(stacked=base, k=fill, stats=bank_stats)
            fac = model.corruption_factors(ids)
            vec = np.ones(cap, np.float32)
            vec[:fill] = fac
            stacked = scale_rows(bank.stacked, vec)
            corrupted += int(np.sum(fac != 1.0))
            norms = bank_row_norms(stacked)
            weights, keep, info = robust_admission_weights(
                cap, [(j, 0) for j in range(fill)], norms, beta=0.1,
                count=fill, method="clip")
            clipped += info["clipped"]
            if not bool(keep.all()):
                stacked = mask_rows(stacked, keep)
            state = apply_admitted_rows(state, stacked, weights, fill,
                                        staleness_max=0,
                                        staleness_sum=0.0)
        jax.block_until_ready(state.params["w"])
        wall = time.time() - t0
        host_mat = bank_stats.get("host_materializations", 0)
        row = {"n": n, "s_per_window": wall / windows,
               "arrivals": sched.stats["arrivals"],
               "dropouts": sched.stats["dropouts"],
               "overflow_arrivals": sched.stats["overflow_arrivals"],
               "corrupted_rows": corrupted, "clipped": clipped,
               "host_materializations": host_mat}
        rows_out.append(row)
        print(f"scale,{n},{row['s_per_window']:.4f},"
              f"{row['arrivals']},{row['dropouts']},{corrupted},{clipped}")
    n_ratio = rows_out[-1]["n"] / rows_out[0]["n"]
    t_ratio = (rows_out[-1]["s_per_window"]
               / max(rows_out[0]["s_per_window"], 1e-9))
    gates = {
        # "sub-linear": 1000x clients may cost at most 0.2x that in time
        "sublinear_time": t_ratio <= 0.2 * n_ratio,
        "zero_host_materializations":
            all(r["host_materializations"] == 0 for r in rows_out),
        "cohorts_formed": all(r["arrivals"] > 0 for r in rows_out),
    }
    result = {"rows": rows_out, "n_ratio": n_ratio, "t_ratio": t_ratio,
              "windows": windows, "cohort_cap": 256, "fast": FAST,
              "gates": gates}
    _save("scale", result)
    _bench_log("scale", result)
    print(f"scale_sublinear,{t_ratio:.1f},n_ratio={n_ratio:.0f}")
    for gate, ok in gates.items():
        if not ok:
            raise RuntimeError(f"scale gate failed: {gate} ({result})")
    return t_ratio


def kernels():
    """µs/call for each Pallas kernel (interpret) and its jnp oracle."""
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.ssd.kernel import ssd_fwd
    from repro.kernels.ssd.ref import ssd_ref
    from repro.kernels.fused_update import kernel as FK, ref as FR

    def timeit(fn, n=3):
        jax.block_until_ready(fn())  # warm
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn())
        return (time.time() - t0) / n * 1e6

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    t_kern = timeit(lambda: flash_attention_fwd(q, k, v, interpret=True))
    t_ref = timeit(lambda: attention_ref(q, k, v))
    print(f"kernel_flash_attention,{t_kern:.0f},ref_us={t_ref:.0f}")

    x = jax.random.normal(ks[0], (1, 256, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 8)))
    a_log = jnp.log(jnp.linspace(1, 8, 8))
    Bm = jax.random.normal(ks[2], (1, 256, 1, 32))
    Cm = jax.random.normal(ks[3], (1, 256, 1, 32))
    t_kern = timeit(lambda: ssd_fwd(x, dt, a_log, Bm, Cm, chunk=64,
                                    interpret=True))
    t_ref = timeit(lambda: ssd_ref(x, dt, a_log, Bm, Cm))
    print(f"kernel_ssd,{t_kern:.0f},ref_us={t_ref:.0f}")

    w = jax.random.normal(ks[0], (1 << 20,))
    g = jax.random.normal(ks[1], (1 << 20,))
    t_kern = timeit(lambda: FK.sgd_step(w, g, 0.01))
    t_ref = timeit(lambda: FR.sgd_step_ref(w, g, 0.01))
    print(f"kernel_fused_update,{t_kern:.0f},ref_us={t_ref:.0f}")

    from repro.core.quant import quantize_stack
    stack = 0.01 * jax.random.normal(ks[2], (8, 1 << 18))
    qs = quantize_stack(stack)
    q = jax.tree.leaves(qs.q)[0]
    sc = jax.tree.leaves(qs.scales)[0]
    wq = jax.random.normal(ks[3], (1 << 18,))
    wts = jnp.full((8,), 0.1, jnp.float32)
    t_kern = timeit(lambda: FK.apply_rows_q(wq, q, sc, wts,
                                            interpret=True))
    t_ref = timeit(lambda: FR.apply_rows_q_ref(wq, q, sc, wts))
    print(f"kernel_apply_rows_q,{t_kern:.0f},ref_us={t_ref:.0f}")


BENCHES = {
    "fig2a": fig2a_concurrency,
    "fig2b": fig2b_mnist,
    "fig2c": fig2c_cifar,
    "table1": table1_staleness,
    "engine": engine,
    "engine_sharded": engine_sharded,
    "serve": serve,
    "serve_transport": serve_transport,
    "serve_mesh": serve_mesh,
    "partial": partial,
    "quant": quant,
    "scale": scale,
    "kernels": kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args, _ = ap.parse_known_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        BENCHES[name]()
        wall_s = time.time() - t0
        print(f"bench_{name}_total,{wall_s*1e6:.0f},ok", flush=True)
        _bench_log(name, {"bench": name, "wall_s": wall_s, "ok": True})


if __name__ == "__main__":
    main()
