"""Shared benchmark plumbing: builds the paper's §5 setup once per dataset
and runs each algorithm under an equal simulated-communication-time budget."""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.paper_models import CIFAR_CNN, MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import (DelayModel, FLRun, immediate, make_personalized_eval,
                      strategy, sync_barrier)
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn

FAST = bool(int(os.environ.get("BENCH_FAST", "0")))

# the 8 algorithms of paper Figure 2 (4 sync, 1 async baseline, +FedAsync
# and the two PersA-FL variants = this work)
ALGOS = ["fedavg", "fedprox", "scaffold", "perfedavg", "pfedme",
         "fedasync", "persafl-maml", "persafl-me"]


def setup(kind: str, n_clients: int = 30, seed: int = 0):
    cpc = 5 if kind == "mnist" else 3   # paper §5: c=5 MNIST, c=3 CIFAR
    ccfg = MNIST_CNN if kind == "mnist" else CIFAR_CNN
    clients = make_federated_dataset(kind, n_clients=n_clients,
                                     classes_per_client=cpc, seed=seed)
    params = init_cnn(ccfg, jax.random.PRNGKey(seed))
    loss = lambda p, b: cnn_loss(ccfg, p, b, train=False)
    acc = lambda p, b: cnn_accuracy(ccfg, p, b)
    ev = make_personalized_eval(loss, acc, clients, ft_steps=1, ft_lr=0.01)
    return clients, params, loss, acc, ev


def run_algo(algo: str, clients, params, loss, ev, *, seed: int = 0,
             async_rounds: int = 150, sync_rounds: int = 20,
             batch: int = 16) -> Dict:
    """Returns {algo, times, acc, rounds, wall_s, mean_active_ratio}."""
    # hyper-params per paper Appendix D protocol: Q=10, beta=1, lambda from
    # {20,25,30}, alpha from {0.002,0.005,0.01}; stepsize selected per
    # method (paper reports the best configuration per algorithm).  Async
    # single-delta applies need the theory-scaled eta ~ 1/(Q sqrt(L_c T))
    # ~= 2e-3 for stability; sync rounds average 10 clients and tolerate
    # the larger 1e-2.
    q = 5 if FAST else 10
    common = dict(q_local=q, beta=1.0, alpha=0.01, lam=25.0,
                  inner_steps=5 if FAST else 10, inner_eta=0.02,
                  maml_mode="full")
    delays = DelayModel(len(clients), seed=seed)
    t0 = time.time()
    if algo in ("fedasync", "persafl-maml", "persafl-me"):
        option = {"fedasync": "A", "persafl-maml": "B", "persafl-me": "C"}[algo]
        pcfg = PersAFLConfig(option=option, eta=0.002, **common)
        rounds = async_rounds if option == "A" else max(async_rounds // 2, 40)
        sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                    pcfg=pcfg, delays=delays,
                    strategy=strategy("persafl", option=option),
                    schedule=immediate(), batch_size=batch, seed=seed)
        hist = sim.run(max_rounds=rounds,
                       eval_every=max(rounds // 10, 5), eval_fn=ev)
    else:
        pcfg = PersAFLConfig(option="A", eta=0.01, **common)
        sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                    pcfg=pcfg, delays=delays, strategy=strategy(algo),
                    schedule=sync_barrier(10), batch_size=batch, seed=seed)
        hist = sim.run(max_rounds=sync_rounds, eval_every=1, eval_fn=ev)
    return {"algo": algo, "times": hist.times, "acc": hist.acc,
            "wall_s": time.time() - t0,
            "mean_active_ratio": float(np.mean(hist.active_ratio))
            if hist.active_ratio else 0.0,
            "staleness_max": int(max(hist.staleness)) if hist.staleness else 0}


def acc_at_time_budget(result: Dict, budget: float) -> float:
    """Test accuracy reached within a fixed simulated communication time."""
    best = 0.0
    for t, a in zip(result["times"], result["acc"]):
        if t <= budget:
            best = max(best, a)
    return best
