"""qwen1.5-110b [dense] — QKV bias (Qwen1.5 family).

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
Source: [hf:Qwen/Qwen1.5-0.5B] (family card; 110B scaling per assignment).
Pure full attention -> skips long_500k (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1.5-110b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    train_microbatches=16,
    skip_shapes=("long_500k",),
    persafl_option="C",
    maml_mode="fo",
)
