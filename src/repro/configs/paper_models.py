"""The paper's own experimental models (§5 / Appendix D).

MNIST: 2-layer CNN + 2 FC layers; CIFAR-10: 3-layer CNN + 3 FC layers,
pooling + dropout + cross-entropy [39].  These are the models PersA-FL's
experimental claims are made on; we reproduce them (as pure-JAX functional
models in ``repro.models.cnn``) alongside the assigned LLM architectures.

Channel/width counts are scaled to this container's single CPU core (the
paper does not pin them; the ell-conv + ell-fc structure, pooling, dropout
and CE loss are preserved) — recorded in DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    image_size: int
    channels: int
    n_classes: int
    conv_channels: Tuple[int, ...]
    fc_sizes: Tuple[int, ...]
    dropout: float = 0.25


MNIST_CNN = CNNConfig(
    name="paper-mnist-cnn",
    image_size=28,
    channels=1,
    n_classes=10,
    conv_channels=(8, 16),      # ell = 2 conv layers
    fc_sizes=(64, 10),          # 2 fully connected layers
)

CIFAR_CNN = CNNConfig(
    name="paper-cifar-cnn",
    image_size=32,
    channels=3,
    n_classes=10,
    conv_channels=(16, 32, 32),  # ell = 3 conv layers
    fc_sizes=(128, 64, 10),      # 3 fully connected layers
)
