"""Config registry: ``get_config(arch_id)`` / ``list_archs()``.

Selectable via ``--arch <id>`` in the launchers (repro.launch.*).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ArchConfig, InputShape, INPUT_SHAPES,
                                get_shape, reduce_for_smoke)

from repro.configs.zamba2_1p2b import CONFIG as _zamba2
from repro.configs.codeqwen1p5_7b import CONFIG as _codeqwen
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek
from repro.configs.minitron_8b import CONFIG as _minitron
from repro.configs.internvl2_76b import CONFIG as _internvl
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.granite_moe_1b import CONFIG as _granite
from repro.configs.qwen1p5_110b import CONFIG as _qwen110
from repro.configs.mamba2_130m import CONFIG as _mamba2

_REGISTRY: Dict[str, ArchConfig] = {
    c.arch_id: c
    for c in (_zamba2, _codeqwen, _gemma2, _deepseek, _minitron,
              _internvl, _whisper, _granite, _qwen110, _mamba2)
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "get_config",
           "get_shape", "list_archs", "reduce_for_smoke"]
