"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
Source: [arXiv:2411.15242] (Zamba2 technical report).

Hybrid: Mamba2 layers, with a single *shared* transformer (attn+MLP) block
applied every ``attn_every`` layers on concat(hidden, original embedding)
(see DESIGN.md §4).  Sub-quadratic: runs ``long_500k`` (shared attention uses
a sliding window at that shape).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    head_dim=64,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=128),
    attn_every=6,
    sliding_window=4096,       # used by the shared attn block for long_500k
    train_microbatches=2,
    persafl_option="C",
    maml_mode="hf",            # HVP-through-scan avoided (paper Eq. D1)
)
