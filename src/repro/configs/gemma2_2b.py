"""gemma2-2b [dense] — local/global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Source: [arXiv:2408.00118] (Gemma 2).

Alternates sliding-window (4096) and global layers; attention logit softcap
50.0, final logit softcap 30.0; post-block RMSNorms.  Runs ``long_500k``
(native sliding-window local layers; global layers use a sequence-sharded
KV cache — DESIGN.md §4/§5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma2-2b",
    family="dense",
    source="arXiv:2408.00118",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab=256000,
    head_dim=256,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_period=2,
    post_block_norm=True,
    train_microbatches=2,
    persafl_option="C",
    maml_mode="full",
)
