"""codeqwen1.5-7b [dense] — Qwen1.5 architecture.

32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416.
Source: [hf:Qwen/CodeQwen1.5-7B].  QKV bias per Qwen1.5 family.
Pure full attention -> skips long_500k (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="codeqwen1.5-7b",
    family="dense",
    source="hf:Qwen/CodeQwen1.5-7B",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    train_microbatches=4,
    skip_shapes=("long_500k",),
    persafl_option="C",
    maml_mode="full",
)
