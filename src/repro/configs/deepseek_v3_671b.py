"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 experts + MTP.

61L d_model=7168 128H (GQA kv=128) d_ff=2048 (expert) vocab=129280.
Source: [arXiv:2412.19437] (DeepSeek-V3).

MLA (multi-head latent attention): q_lora 1536, kv_lora 512, nope 128 /
rope 64 head dims, v 128.  First 3 layers dense (d_ff 18432).  MTP: one
extra multi-token-prediction block at train time.
Pure full attention -> skips long_500k (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, expert_d_ff=2048,
                  n_shared_experts=1, shared_d_ff=2048,
                  first_k_dense=3, dense_d_ff=18432),
    use_mtp=True,
    train_microbatches=16,
    skip_shapes=("long_500k",),
    persafl_option="C",       # ME: first-order only; MoE top-k non-smoothness noted
    maml_mode="fo",
)
