"""granite-moe-1b-a400m [moe] — 32 experts, top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 (expert) vocab=49155.
Source: [hf:ibm-granite/granite-3.0-1b-a400m-base].
Pure full attention -> skips long_500k (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=32, top_k=8, expert_d_ff=512),
    train_microbatches=1,
    skip_shapes=("long_500k",),
    persafl_option="C",
    maml_mode="fo",
)
