"""internvl2-76b [vlm] — InternViT (stub) + InternLM2-style 70B+ language model.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Source: [arXiv:2404.16821] (InternVL 1.5/2 series).

Per the assignment carve-out, the vision encoder + projector are a STUB:
``input_specs()`` supplies precomputed patch embeddings (n_visual_tokens
positions) which are prepended to the text embeddings; we implement the
language/decoder backbone.  Pure full attention -> skips long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    n_visual_tokens=1024,
    train_microbatches=16,
    skip_shapes=("long_500k",),
    persafl_option="C",
    maml_mode="fo",
)
