"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 d_ff=0 vocab=50280, ssm_state=128.
Source: [arXiv:2405.21060] (Mamba2 / SSD).
Sub-quadratic (linear recurrence) -> runs long_500k.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=128),
    train_microbatches=1,
    persafl_option="C",
    maml_mode="hf",  # HVP through the scan avoided (paper Eq. D1)
)
