"""whisper-large-v3 [audio] — encoder-decoder with stubbed conv frontend.

32L (decoder) d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
Source: [arXiv:2212.04356] (Whisper).

Per the assignment carve-out the mel-spectrogram + conv feature extractor is
a STUB: ``input_specs()`` provides precomputed frame embeddings
(enc_len=1500, i.e. 30 s of audio) consumed by a 32-layer bidirectional
encoder; the 32-layer decoder cross-attends to it.  Decode shapes lower the
decoder ``serve_step`` with a self-attn KV cache of the shape's seq_len plus
the fixed cross-attn cache.  long_500k skipped (full attention).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    is_encdec=True,
    enc_layers=32,
    enc_len=1500,
    train_microbatches=2,
    skip_shapes=("long_500k",),
    persafl_option="C",
    maml_mode="full",
)
