"""Architecture & run configuration system.

Every assigned architecture is described by an :class:`ArchConfig` — a frozen
dataclass consumed by the model builders in ``repro.models`` and the launch
layer.  Configs are selectable by id via :func:`repro.configs.get_config`
(``--arch <id>`` in the launchers).

Input shapes (assigned, public pool):

===========  ==========  ============  ================
name         seq_len     global_batch  kind
===========  ==========  ============  ================
train_4k     4,096       256           training
prefill_32k  32,768      32            inference-prefill
decode_32k   32,768      128           inference-decode
long_500k    524,288     1             long-context-decode
===========  ==========  ============  ================
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> InputShape:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; have {[s.name for s in INPUT_SHAPES]}")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001  # load-balance loss weight
    first_k_dense: int = 0            # leading dense layers (deepseek-v3)
    dense_d_ff: int = 0               # ffn width of those dense layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD (arXiv:2405.21060)."""
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    n_groups: int = 1  # B/C groups


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    source: str                  # citation for the config
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # attention flavour
    qkv_bias: bool = False
    attn_softcap: float = 0.0    # 0 disables (gemma2: 50.0)
    final_softcap: float = 0.0   # gemma2: 30.0
    sliding_window: int = 0      # 0 disables
    local_global_period: int = 0 # gemma2: 2 -> alternate local/global layers
    rope_theta: float = 10_000.0
    post_block_norm: bool = False  # gemma2 post-norms

    # family extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0          # hybrid (zamba2): shared attn block period
    use_mtp: bool = False        # deepseek multi-token prediction head

    # encoder-decoder (whisper)
    is_encdec: bool = False
    enc_layers: int = 0
    enc_len: int = 0             # fixed encoder length (1500 = 30s audio)

    # multimodal stub frontend
    n_visual_tokens: int = 0     # vlm: stubbed patch-embedding count

    # runtime
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs)
    # train-time microbatching (gradient accumulation); per-shape override
    # chosen so activations fit v5e HBM — see DESIGN.md §5.
    train_microbatches: int = 1
    # which shapes this arch supports (skips recorded in DESIGN.md)
    skip_shapes: Tuple[str, ...] = ()

    # PersA-FL defaults for this arch (see repro.core)
    persafl_option: str = "C"          # A | B | C
    maml_mode: str = "hf"              # full | fo | hf (Option B HVP estimator)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_params(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d * 2  # in + out embedding (untied)
        per_layer = 0
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            # in_proj (z,x,B,C,dt) + out_proj + conv
            per_layer += d * (2 * d_in + 2 * s.n_groups * s.state_dim + nh)
            per_layer += d_in * d
            per_layer += (d_in + 2 * s.n_groups * s.state_dim) * s.conv_width
        if self.family not in ("ssm",):  # attention present
            if self.mla is not None:
                m = self.mla
                per_layer += d * m.q_lora_rank + m.q_lora_rank * n_q * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                per_layer += m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                per_layer += n_q * m.v_head_dim * d
            elif self.attn_every:
                pass  # hybrid: shared attn counted once below
            else:
                per_layer += d * hd * (n_q + 2 * n_kv) + n_q * hd * d
        if self.moe is not None:
            mo = self.moe
            moe_layers = L - mo.first_k_dense
            per_layer_moe = mo.n_experts * 3 * d * mo.expert_d_ff + d * mo.n_experts
            per_layer_moe += mo.n_shared_experts * 3 * d * mo.shared_d_ff
            dense = mo.first_k_dense * 3 * d * mo.dense_d_ff
            total = emb + L * per_layer + moe_layers * per_layer_moe + dense
        elif self.family == "ssm":
            total = emb + L * per_layer
        elif self.attn_every:
            # zamba2: shared attn+mlp block, params counted once
            shared = 2 * d * hd * (n_q + 2 * n_kv) + n_q * hd * d + 3 * d * self.d_ff
            total = emb + L * per_layer + shared
        else:
            per_layer += 3 * d * self.d_ff  # gate/up/down
            total = emb + L * per_layer
        if self.is_encdec:
            # encoder self-attn + ffn, decoder cross-attn
            enc = self.enc_layers * (d * hd * (n_q + 2 * n_kv) + n_q * hd * d + 2 * d * self.d_ff)
            cross = L * (d * hd * (n_q + 2 * n_kv) + n_q * hd * d)
            total += enc + cross
        return int(total)

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params
        mo = self.moe
        inactive = (mo.n_experts - mo.top_k) * 3 * self.d_model * mo.expert_d_ff
        return int(self.n_params - (self.n_layers - mo.first_k_dense) * inactive)

    def supports(self, shape_name: str) -> bool:
        return shape_name not in self.skip_shapes


# ---------------------------------------------------------------------------
# reduced variants for CPU smoke tests (2 layers, d_model<=512, <=4 experts)
# ---------------------------------------------------------------------------

def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Shrink a full config to a CPU-runnable variant of the same family."""
    d = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    n_kv = max(1, n_heads // ratio)
    hd = 32
    repl = dict(
        n_layers=2,
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 4 * d) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        dtype="float32",
        remat=False,
        train_microbatches=1,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.moe is not None:
        repl["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=4,
            top_k=2,
            expert_d_ff=2 * d,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            shared_d_ff=2 * d if cfg.moe.n_shared_experts else 0,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            dense_d_ff=2 * d if cfg.moe.first_k_dense else 0,
        )
    if cfg.mla is not None:
        repl["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                qk_nope_head_dim=hd, qk_rope_head_dim=16,
                                v_head_dim=hd)
    if cfg.ssm is not None:
        repl["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=32, chunk=16)
    if cfg.attn_every:
        repl["attn_every"] = 2
    if cfg.is_encdec:
        repl["enc_layers"] = 2
        repl["enc_len"] = 16
    if cfg.n_visual_tokens:
        repl["n_visual_tokens"] = 8
    return dataclasses.replace(cfg, **repl)
