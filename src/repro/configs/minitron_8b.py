"""minitron-8b [dense] — pruned Nemotron.

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Source: [arXiv:2407.14679] (Minitron: compact LMs via pruning+distillation).
Pure full attention -> skips long_500k (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="minitron-8b",
    family="dense",
    source="arXiv:2407.14679",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    train_microbatches=4,
    skip_shapes=("long_500k",),
    persafl_option="C",
    maml_mode="full",
)
