"""Functional optimizers: each is (init_fn, update_fn) over pytrees.

update_fn(grads, opt_state, params) -> (updates, new_opt_state); apply with
``apply_updates`` (updates are *subtracted*, SGD convention).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) - u.astype(jnp.float32))
        .astype(p.dtype), params, updates)


def constant_lr(lr: float):
    return lambda step: lr


def cosine_lr(lr: float, total_steps: int, warmup: int = 0):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        return lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return sched


def sgd(lr) -> Optimizer:
    sched = lr if callable(lr) else constant_lr(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        lr_t = sched(state["step"])
        upd = jax.tree.map(lambda g: lr_t * g.astype(jnp.float32), grads)
        return upd, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9) -> Optimizer:
    sched = lr if callable(lr) else constant_lr(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)}

    def update(grads, state, params=None):
        m = jax.tree.map(lambda mm, g: beta * mm + g.astype(jnp.float32),
                         state["m"], grads)
        lr_t = sched(state["step"])
        upd = jax.tree.map(lambda mm: lr_t * mm, m)
        return upd, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    sched = lr if callable(lr) else constant_lr(lr)

    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
        return {"step": jnp.zeros((), jnp.int32), "m": z(), "v": z()}

    def update(grads, state, params=None):
        step = state["step"] + 1
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = sched(state["step"])
        upd = jax.tree.map(
            lambda mm, vv: lr_t * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), m, v)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
