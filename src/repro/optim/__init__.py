"""Minimal functional optimizers (paper's clients use plain SGD; Adam and
momentum are provided for the beyond-paper server-update variants)."""
from repro.optim.optimizers import (adam, momentum, sgd,            # noqa: F401
                                    apply_updates, constant_lr,
                                    cosine_lr)
