"""Per-architecture PartitionSpec rules (DESIGN.md §5).

Mesh axes: ``("data","model")`` single-pod; ``("pod","data","model")``
multi-pod.  Tensor parallelism on ``model`` (attention heads / FFN hidden /
MoE experts / vocab), client-cohort data parallelism on ``data``/``pod``,
optional FSDP (2-D weight sharding) over the data axes for the ≥70B archs.

Rules are *name-based* on the flattened parameter paths and right-aligned
against the trailing dims, so stacked (scan-over-layers) leaves pick up
leading ``None``s automatically.  Every sharded dim is divisibility-checked
against the mesh; indivisible dims fall back to replication.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# archs whose weights additionally FSDP-shard over the data axes
FSDP_ARCHS = {"deepseek-v3-671b", "qwen1.5-110b", "internvl2-76b"}

# trailing-dim rules: suffix -> (spec for trailing dims, fsdp variant)
_COL = ("wq", "wk", "wv", "wg", "wu", "w1", "w_uq", "w_uk", "w_uv",
        "in_proj", "vis_proj", "proj")          # (d_in, big) -> shard dim -1
_ROW = ("wo", "wd", "w2", "out_proj")           # (big, d_out) -> shard dim -2
_BIAS = ("bq", "bk", "bv")
_REPL = ("w_dq", "w_dkv", "w_krope", "router", "conv_b", "a_log", "dt_bias",
         "d_skip", "gate_norm", "ln", "ln1", "ln2", "ln_in", "ln_mlp",
         "ln_x", "ln1_post", "ln2_post", "final_norm", "enc_norm", "norm")


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fits(dim_size: int, axes, sizes) -> bool:
    if axes is None:
        return True
    total = int(np.prod([sizes[a] for a in (axes if isinstance(axes, tuple)
                                            else (axes,))]))
    return dim_size % total == 0


def _guard(spec_parts, shape, sizes) -> P:
    """Replace indivisible entries with None."""
    out = []
    for dim, axes in zip(shape, spec_parts):
        if axes is None or not _fits(dim, axes, sizes):
            out.append(None)
            continue
        # collapse 1-tuples to the bare axis name so specs read "data",
        # not ("data",) — identical sharding, friendlier introspection
        if isinstance(axes, tuple) and len(axes) == 1:
            axes = axes[0]
        out.append(axes)
    return P(*out)


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_spec(cfg: ArchConfig, path: str, shape: Tuple[int, ...],
               mesh: Mesh, *, expert_both_axes: bool = False,
               fsdp: Optional[bool] = None) -> P:
    sizes = _axis_sizes(mesh)
    if fsdp is None:
        fsdp = cfg.arch_id in FSDP_ARCHS
    d_ax = data_axes(mesh)
    nd = len(shape)
    name = path.rsplit("/", 1)[-1]
    is_moe_expert = "/moe/" in path and name in ("wg", "wu", "wd")

    def right(parts):
        full = [None] * (nd - len(parts)) + list(parts)
        return _guard(full, shape, sizes)

    if is_moe_expert:
        # (..., E, d, f): experts on model (expert parallel); optionally the
        # big matrix dim FSDP-shards over data axes.  expert_both_axes
        # spreads experts over the WHOLE mesh (serving layout: deepseek's
        # 256 experts -> 1/device on 256 chips, zero weight gathers).
        e_ax = tuple(d_ax) + ("model",) if expert_both_axes else "model"
        f2 = fsdp and not expert_both_axes
        if name in ("wg", "wu"):
            return right([e_ax, d_ax if f2 else None, None])
        return right([e_ax, None, d_ax if f2 else None])
    if path.endswith("embed/tok"):
        return right(["model", d_ax if fsdp else None])      # vocab-sharded
    if path.endswith("embed/unembed"):
        return right([d_ax if fsdp else None, "model"])
    if name == "conv_w":
        return right([None, "model"])
    if name in _BIAS:
        return right(["model"])
    if name in _REPL or nd <= 1:
        return P(*([None] * nd))
    if name in _COL:
        return right([d_ax if fsdp else None, "model"])
    if name in _ROW:
        return right(["model", d_ax if fsdp else None])
    return P(*([None] * nd))


def _paths_and_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    def pstr(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(f"#{k.idx}")
            else:
                parts.append(str(k))
        return "/".join(parts)
    return [(pstr(kp), leaf) for kp, leaf in flat], treedef


def param_shardings(cfg: ArchConfig, params_shape, mesh: Mesh,
                    model_parallel: bool = True, mode: str = "default"):
    """NamedSharding pytree matching a params (or ShapeDtypeStruct) tree.

    ``model_parallel=False`` replicates every parameter (pure data/cohort
    parallelism — the §Perf "dp" variant for small archs whose per-layer
    tensor-parallel all-reduces dominate their tiny compute).
    ``mode="ep"``: full-mesh expert parallelism + no FSDP (serving layout —
    kills per-step weight all-gathers at decode)."""
    flat, treedef = _paths_and_leaves(params_shape)
    if not model_parallel:
        specs = [NamedSharding(mesh, P(*([None] * len(leaf.shape))))
                 for _, leaf in flat]
    elif mode == "ep":
        specs = [NamedSharding(mesh, param_spec(cfg, path, leaf.shape, mesh,
                                                expert_both_axes=True,
                                                fsdp=False))
                 for path, leaf in flat]
    else:
        specs = [NamedSharding(mesh, param_spec(cfg, path, leaf.shape, mesh))
                 for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_shardings(batch_shape, mesh: Mesh, axes=None):
    """tokens/labels (B,S) etc: batch dim over (pod,data) — or an explicit
    axis tuple (the §Perf "dp2d" variant shards batch over every axis)."""
    sizes = _axis_sizes(mesh)
    d_ax = tuple(axes) if axes is not None else data_axes(mesh)

    def one(leaf):
        parts = [d_ax] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, _guard(parts, leaf.shape, sizes))

    return jax.tree.map(one, batch_shape)


def cache_shardings(cfg: ArchConfig, cache_shape, mesh: Mesh,
                    seq_on_model: bool = True):
    """KV/SSM caches: batch on data; heads on model when divisible, else
    the sequence dim on model (long_500k B=1 sequence-sharded caches).
    ``seq_on_model=False`` disables the sequence fallback (the §Perf
    "cache=batch" decode variant: replicated-over-model caches avoid the
    per-step gather at the cost of cache memory)."""
    sizes = _axis_sizes(mesh)
    d_ax = data_axes(mesh)
    m = sizes["model"]
    flat, treedef = _paths_and_leaves(cache_shape)
    out = []
    for path, leaf in flat:
        shape = leaf.shape
        nd = len(shape)
        name = path.rsplit("/", 1)[-1]
        parts = [None] * nd
        # layout conventions (leading L = stacked layers):
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            # (L,B,T,Hkv,hd) or (B,T,Hkv,hd)
            b_i, t_i, h_i = nd - 4, nd - 3, nd - 2
            parts[b_i] = d_ax
            if shape[h_i] % m == 0:
                parts[h_i] = "model"
            elif seq_on_model and shape[t_i] % m == 0:
                parts[t_i] = "model"
        elif name in ("ckv", "krope"):
            # (L,B,T,r)
            parts[nd - 3] = d_ax
            if seq_on_model and shape[nd - 2] % m == 0:
                parts[nd - 2] = "model"
        elif name == "state":
            # (L,B,H,P,N)
            parts[nd - 4] = d_ax
            if shape[nd - 3] % m == 0:
                parts[nd - 3] = "model"
        elif name == "conv":
            # (L,B,W-1,conv_dim)
            parts[nd - 3] = d_ax
            if shape[nd - 1] % m == 0:
                parts[nd - 1] = "model"
        out.append(NamedSharding(mesh, _guard(parts, shape, sizes)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def strip_axes(rules_dict: Dict[str, P], axes) -> Dict[str, P]:
    """Remove the given mesh axes from every rule (None them out) — used
    inside shard_map regions where those axes are Manual."""
    axes = set(axes)

    def strip(spec: P) -> P:
        out = []
        for part in spec:
            if part is None:
                out.append(None)
            elif isinstance(part, tuple):
                kept = tuple(a for a in part if a not in axes)
                out.append(kept if kept else None)
            else:
                out.append(None if part in axes else part)
        return P(*out)

    return {k: strip(v) for k, v in rules_dict.items()}


def default_activation_rules(mesh: Mesh) -> Dict[str, P]:
    """Logical activation-name -> PartitionSpec (see repro.sharding.ctx)."""
    d_ax = data_axes(mesh)
    return {
        "residual": P(d_ax, None, None),
        "ffn": P(d_ax, None, "model"),
        "attn_out": P(d_ax, None, "model"),
        "ssm_out": P(d_ax, None, "model"),
        "moe_dispatch": P(d_ax, None, "model", None),
        "moe_expert_in": P("model", d_ax, None, None),
    }
