"""Mesh context + activation sharding + version-portable shard_map.

This module owns how every tensor in the system is *placed*:

  * **The cohort/model mesh.**  :func:`cohort_mesh` is the 1-D
    ``("cohort",)`` layout; :func:`cohort_model_mesh` generalizes it to the
    2-D ``("cohort", "model")`` mesh that unifies the FL path with
    ``launch/mesh.py``'s ``("data", "model")`` production mesh and the
    ``rules.py`` param specs.  Which axis shards what:

      - the **"cohort" axis** carries everything with a leading per-client
        /per-user row dim: stacked batch buffers, DeltaBank/DeltaRing delta
        stacks, head banks, QuantStack codes + scales, stacked client
        state.  Row ``i`` of a ``[bucket, ...]`` buffer lands on cohort
        slice ``i // (bucket // cohort_axis_size)``, which is the layout
        contract behind the serving batcher's user→cohort-slice keying.
      - the **"model" axis** shards *storage*, not cohort compute: params
        at rest, retained window snapshots, and the model dims of every
        bank row, placed by ``rules.py``-style ``PartitionSpec``s (or any
        caller-provided ``param_shardings``).  ``CohortEngine`` shard_map
        bodies are Manual over ALL mesh axes with params replicated inside
        the region (a ``with_sharding_constraint`` gather right before the
        call), and the engine re-shards the delta stack to
        ``P("cohort", *param_spec)`` per leaf right after — a pure
        placement move, bits unchanged.  Two reasons compute stays
        model-replicated: (a) ``lax.scan``/``lax.map`` inside a
        partially-Auto shard_map hard-crashes XLA on the pinned jax 0.4.x
        (``IsManualSubgroup`` check), and real archs scan internally;
        (b) model-sharded grads reassociate cross-class reductions
        (softmax) and break the bit-parity contract between mesh layouts.
        The masked cohort mean stays a single ``psum("cohort")`` per leaf
        that never crosses "model" (a cross-model reduction would
        re-reduce *within* each row — wrong math, not just wrong layout).

    Meshes are **memoized per (device set, shape)** — constructing a fresh
    ``jax.sharding.Mesh`` per call defeated jit caches keyed on sharding
    identity and leaked one mesh object per engine/batcher call.
    :func:`reset_mesh_cache` (owned by the mesh context) is the one
    invalidation point, for tests that fake out the device set.

  * **The mesh context.**  :func:`use_mesh` installs a mesh thread-locally;
    :func:`active_mesh` reads it back.  Engines and the serving stack
    consume the context when no explicit ``mesh=`` is passed, so
    ``launch/serve.py --model-axis 4`` re-homes the whole pipeline onto the
    2-D mesh without threading a mesh argument through every layer.

  * **Activation sharding.**  Models are mesh-agnostic; the launch layer
    may install a mapping from *logical* activation names ("ffn",
    "attn_out", "moe_dispatch", ...) to ``PartitionSpec``s.  When no
    context is installed (unit tests, CPU smoke), ``shard_activation`` is
    a no-op, keeping the model code pure.

:func:`shard_map_compat` is the single jax-version shim for manual-axes
shard_map, shared by ``launch/steps.py`` (cohort train step) and
``fl/engine.py`` (``cohort_impl="shard_map"``) — keep exactly one copy.
The engine passes ``manual_axes=mesh.axis_names`` (full-Manual; see
above); partial-Manual callers leave the remaining axes to the Auto
partitioner on both jax spellings.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax

_state = threading.local()


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes):
    """Version-portable shard_map, Manual only over ``manual_axes``.

    Newer jax exposes ``jax.shard_map(axis_names=..., check_vma=...)``;
    0.4.x spells it ``jax.experimental.shard_map.shard_map(auto=...,
    check_rep=...)`` with the complement axis set.  Mesh axes NOT in
    ``manual_axes`` (the 2-D mesh's "model" axis) stay Auto: in/out specs
    only describe the manual axes and XLA SPMD carries the rest, which is
    how a bare ``P("cohort")`` prefix keeps working unchanged on the
    ``("cohort", "model")`` mesh.
    """
    manual = frozenset(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - manual
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


# -- memoized mesh construction ---------------------------------------------

# (device ids, axis names, axis sizes) -> Mesh.  One mesh object per
# layout: jit caches and NamedSharding equality key on mesh identity, and
# the pre-memoization behavior (a fresh Mesh per cohort_mesh() call) both
# leaked and defeated those caches.
_MESH_CACHE: Dict[Tuple, "jax.sharding.Mesh"] = {}


def reset_mesh_cache() -> None:
    """Drop every memoized mesh.  The mesh context owns invalidation: call
    this when the device set changes under you (tests faking
    ``--xla_force_host_platform_device_count``, distributed re-init)."""
    _MESH_CACHE.clear()


def cohort_mesh(devices=None) -> "jax.sharding.Mesh":
    """The 1-D ``("cohort",)`` mesh over every addressable device
    (memoized — repeated engine/batcher calls share ONE mesh object).

    This is the layout contract shared by ``fl/engine.py``'s
    ``cohort_impl="shard_map"`` and the serving batcher's
    user→cohort-slice keying (``repro.serving.batcher``): row ``i`` of a
    ``[bucket, ...]`` cohort buffer lands on cohort slice
    ``i // (bucket // cohort_axis_size)``, so a batcher that places a user
    at a stable per-slice slot pins that user's delta rows to one cohort
    slice across windows.
    """
    return cohort_model_mesh(model_axis=None, devices=devices)


def cohort_model_mesh(model_axis: Optional[int] = None,
                      devices=None) -> "jax.sharding.Mesh":
    """The ``("cohort", "model")`` mesh: cohort-parallel × model-parallel.

    ``model_axis=None`` returns the 1-D ``("cohort",)`` mesh (the two
    spellings share one cache, so ``cohort_mesh()`` and
    ``cohort_model_mesh(None)`` are the same object).  With ``model_axis=m``
    the device grid is ``(n_devices // m, m)``: delta/head bank rows split
    over "cohort", each row's model dims split over "model" via the
    params' shardings (``rules.py`` specs or explicit ``param_shardings``).
    ``model_axis=1`` is the 2-D mesh with a degenerate model axis — same
    cohort split as the 1-D mesh, useful for parity checks.
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = tuple(jax.devices()) if devices is None else tuple(devices)
    n = len(devs)
    if model_axis is None:
        key = (tuple(d.id for d in devs), ("cohort",), (n,))
        if key not in _MESH_CACHE:
            _MESH_CACHE[key] = Mesh(np.asarray(devs), ("cohort",))
        return _MESH_CACHE[key]
    m = int(model_axis)
    if m < 1 or n % m:
        raise ValueError(f"model_axis={m} must divide the device count "
                         f"({n})")
    key = (tuple(d.id for d in devs), ("cohort", "model"), (n // m, m))
    if key not in _MESH_CACHE:
        _MESH_CACHE[key] = Mesh(np.asarray(devs).reshape(n // m, m),
                                ("cohort", "model"))
    return _MESH_CACHE[key]


def cohort_axis_size(mesh: "jax.sharding.Mesh") -> int:
    """Number of cohort slices of a mesh — the row-dim shard count bank
    buffers and the batcher's user keying are laid out for.  A mesh
    without a "cohort" axis (the production ``("data", "model")`` mesh)
    has one cohort slice: every row lives on the model-parallel group."""
    return int(dict(zip(mesh.axis_names, mesh.devices.shape))
               .get("cohort", 1))


@contextlib.contextmanager
def use_mesh(mesh: "jax.sharding.Mesh"):
    """Install ``mesh`` as the ambient cohort/model mesh.  Engines and the
    serving stack pick it up when constructed without an explicit
    ``mesh=`` — the ``launch/serve.py --model-axis`` path wraps server
    construction in this context instead of threading a mesh through
    every constructor."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def active_mesh() -> Optional["jax.sharding.Mesh"]:
    """The mesh installed by :func:`use_mesh`, or None."""
    return getattr(_state, "mesh", None)


# -- activation sharding ------------------------------------------------------


def _rules() -> Optional[Dict[str, "jax.sharding.PartitionSpec"]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activation_sharding(rules: Dict[str, "jax.sharding.PartitionSpec"]):
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shard_activation(x, name: str):
    rules = _rules()
    if rules is None or name not in rules:
        return x
    spec = rules[name]
    # pad/trim the spec to the array rank
    parts = list(spec)
    if len(parts) < x.ndim:
        parts = parts + [None] * (x.ndim - len(parts))
    elif len(parts) > x.ndim:
        parts = parts[: x.ndim]
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*parts))
