"""Activation-sharding context + version-portable shard_map.

Models are mesh-agnostic; the launch layer may install a mapping from
*logical* activation names ("ffn", "attn_out", "moe_dispatch", ...) to
``PartitionSpec``s.  When no context is installed (unit tests, CPU smoke),
``shard_activation`` is a no-op, keeping the model code pure.

This is the hook the §Perf hillclimb uses to steer XLA SPMD without
touching model code.

:func:`shard_map_compat` is the single jax-version shim for manual-axes
shard_map, shared by ``launch/steps.py`` (cohort train step) and
``fl/engine.py`` (``cohort_impl="shard_map"``) — keep exactly one copy.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes):
    """Version-portable shard_map, Manual only over ``manual_axes``.

    Newer jax exposes ``jax.shard_map(axis_names=..., check_vma=...)``;
    0.4.x spells it ``jax.experimental.shard_map.shard_map(auto=...,
    check_rep=...)`` with the complement axis set.
    """
    manual = frozenset(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - manual
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def cohort_mesh() -> "jax.sharding.Mesh":
    """The 1-D ``("cohort",)`` mesh over every addressable device.

    This is the layout contract shared by ``fl/engine.py``'s
    ``cohort_impl="shard_map"`` and the serving batcher's user→shard keying
    (``repro.serving.batcher``): row ``i`` of a ``[bucket, ...]`` cohort
    buffer lands on device ``i // (bucket // n_devices)``, so a batcher
    that places a user at a stable per-shard slot pins that user's delta
    rows to one device across windows.
    """
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()), ("cohort",))


def _rules() -> Optional[Dict[str, "jax.sharding.PartitionSpec"]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activation_sharding(rules: Dict[str, "jax.sharding.PartitionSpec"]):
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shard_activation(x, name: str):
    rules = _rules()
    if rules is None or name not in rules:
        return x
    spec = rules[name]
    # pad/trim the spec to the array rank
    parts = list(spec)
    if len(parts) < x.ndim:
        parts = parts + [None] * (x.ndim - len(parts))
    elif len(parts) > x.ndim:
        parts = parts[: x.ndim]
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*parts))
