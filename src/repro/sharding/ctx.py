"""Activation-sharding context.

Models are mesh-agnostic; the launch layer may install a mapping from
*logical* activation names ("ffn", "attn_out", "moe_dispatch", ...) to
``PartitionSpec``s.  When no context is installed (unit tests, CPU smoke),
``shard_activation`` is a no-op, keeping the model code pure.

This is the hook the §Perf hillclimb uses to steer XLA SPMD without
touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def _rules() -> Optional[Dict[str, "jax.sharding.PartitionSpec"]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activation_sharding(rules: Dict[str, "jax.sharding.PartitionSpec"]):
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shard_activation(x, name: str):
    rules = _rules()
    if rules is None or name not in rules:
        return x
    spec = rules[name]
    # pad/trim the spec to the array rank
    parts = list(spec)
    if len(parts) < x.ndim:
        parts = parts + [None] * (x.ndim - len(parts))
    elif len(parts) > x.ndim:
        parts = parts[: x.ndim]
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*parts))
