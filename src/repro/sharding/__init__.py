from repro.sharding.ctx import activation_sharding, shard_activation  # noqa: F401
