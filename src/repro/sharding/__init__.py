from repro.sharding.ctx import (activation_sharding,   # noqa: F401
                                shard_activation, shard_map_compat)
