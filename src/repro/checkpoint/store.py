"""npz-based pytree checkpointing (no orbax dependency).

Pytrees are flattened to ``path/to/leaf``-keyed arrays; structure (dicts,
lists) round-trips from the key paths.  Typed containers
(:class:`repro.core.types.ServerState`) are stored as their field dicts and
re-typed on load, so the server-state checkpoint format is unchanged from
the raw-dict era — old checkpoints load into the new dataclass.

List rebuild is GAP-PRESERVING: ``#i`` indices keep their positions and
missing ones become ``None`` (an empty pytree node), so the *pruned*
personal-subset trees of ``repro.core.subset`` — whose lists legitimately
skip backbone slots — round-trip with their exact treedef.  Dense
checkpoints have no gaps and rebuild exactly as before.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if dataclasses.is_dataclass(tree) and not isinstance(tree, type):
        out.update(_flatten({f.name: getattr(tree, f.name)
                             for f in dataclasses.fields(tree)}, prefix))
    elif isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(k.startswith("#") for k in keys):
            # gap-preserving: position i stays at index i, absent indices
            # rebuild as None (pruned-subset lists skip backbone slots)
            by_idx = {int(k[1:]): node[k] for k in keys}
            return [rebuild(by_idx[i]) if i in by_idx else None
                    for i in range(max(by_idx) + 1)]
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


# public aliases: the flat ``path/to/leaf`` layout doubles as the wire
# encoding of :mod:`repro.serving.transport` (npz frames over the socket
# use exactly the checkpoint layout, so a captured frame IS a checkpoint)
flatten_pytree = _flatten
unflatten_pytree = _unflatten

# ``np.savez``/``np.load`` silently degrade non-native dtypes: ml_dtypes
# leaves (bfloat16, float8_*) have numpy kind 'V' and come back as raw void
# records — dtype ``|V2`` instead of bfloat16.  Such leaves are stored as
# same-width unsigned-int bit patterns plus a ``__dt__:<key>`` marker
# naming the true dtype, and re-viewed on load — bit-exact both ways.
# Native dtypes (f32/f16/int8/uint8/...) round-trip untouched.  The marker
# prefix contains ``:``, which no ``path/to/leaf`` key produced by
# ``_flatten`` starts with, so markers can never collide with data keys.
DTYPE_KEY_PREFIX = "__dt__:"


def _true_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def pack_dtypes(flat: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Rewrite non-npz-native leaves as uint bit patterns + dtype markers."""
    out: Dict[str, np.ndarray] = {}
    for key, val in flat.items():
        arr = np.asarray(val)
        if arr.dtype.kind == "V":
            out[key] = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            out[DTYPE_KEY_PREFIX + key] = np.asarray(arr.dtype.name)
        else:
            out[key] = arr
    return out


def unpack_dtypes(flat: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Inverse of :func:`pack_dtypes`: re-view marked leaves, drop markers."""
    markers = {k[len(DTYPE_KEY_PREFIX):]: str(flat[k]) for k in flat
               if k.startswith(DTYPE_KEY_PREFIX)}
    out = {k: v for k, v in flat.items()
           if not k.startswith(DTYPE_KEY_PREFIX)}
    for key, name in markers.items():
        out[key] = out[key].view(_true_dtype(name))
    return out


def save_pytree(path: str, tree, meta: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = pack_dtypes(_flatten(jax.tree.map(np.asarray, tree)))
    np.savez(path, **flat)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def load_pytree(path: str):
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(unpack_dtypes(flat))


def load_meta(path: str) -> Dict | None:
    """The sidecar ``.meta.json`` written by :func:`save_pytree`, or None."""
    if path.endswith(".npz"):
        path = path[:-len(".npz")]
    meta_path = path + ".meta.json"
    if not os.path.exists(meta_path):
        return None
    with open(meta_path) as f:
        return json.load(f)


def save_server_state(path: str, state, meta: Dict | None = None):
    save_pytree(path, state, meta)


def load_server_state(path: str):
    """Load a server-state checkpoint, re-typed as :class:`ServerState`.

    Pre-PR-4 checkpoints (raw dicts with the same four keys) load
    identically — the on-disk layout never changed.
    """
    from repro.core.types import ServerState
    tree = load_pytree(path)
    if isinstance(tree, dict) and set(tree) == {
            f.name for f in dataclasses.fields(ServerState)}:
        return ServerState.from_dict(tree)
    return tree
