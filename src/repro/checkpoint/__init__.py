from repro.checkpoint.store import (save_pytree, load_pytree,      # noqa: F401
                                    load_meta, save_server_state,
                                    load_server_state)
