from repro.checkpoint.store import (save_pytree, load_pytree,      # noqa: F401
                                    save_server_state, load_server_state)
