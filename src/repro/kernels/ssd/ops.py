"""jit'd wrapper for the SSD kernel (pallas on TPU / interpret for
validation / chunked-jnp reference otherwise)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.kernel import ssd_fwd
from repro.kernels.ssd.ref import ssd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "mode"))
def ssd(x, dt, a_log, B_mat, C_mat, *, chunk: int = 128, mode: str = "auto"):
    """mode: "auto" (tpu->kernel else sequential ref), "kernel" (interpret),
    "ref" (sequential-recurrence oracle)."""
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ssd_ref(x, dt, a_log, B_mat, C_mat)
    return ssd_fwd(x, dt, a_log, B_mat, C_mat, chunk=chunk,
                   interpret=not _on_tpu())
