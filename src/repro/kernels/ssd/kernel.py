"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation (DESIGN.md §2): the GPU Mamba2 kernel leans on warp-level
parallel prefix scans; on TPU we instead exploit the *sequential* grid —
the grid's innermost dimension iterates chunks in order, so the inter-chunk
recurrent state lives in a VMEM scratch accumulator that persists across
grid steps (reset at chunk 0).  Intra-chunk work is the quadratic
attention-like form, which maps onto the MXU as (chunk × chunk) matmuls.

Grid: (batch, n_chunks) — chunks innermost/sequential per batch row.
Blocks: one chunk of x/dt/B/C per step; all heads resident (head_dim ≤ 64,
state ≤ 128 keeps VMEM ≈ a few MB for the assigned configs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int, n_heads: int, head_dim: int, n_state: int,
                n_groups: int):
    ic = pl.program_id(1)
    f32 = jnp.float32

    @pl.when(ic == 0)
    def _():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(f32)                      # (l, H, P)
    dt = dt_ref[0].astype(f32)                    # (l, H)
    a_log = alog_ref[...].astype(f32)             # (H,)
    bmat = b_ref[0].astype(f32)                   # (l, G, N)
    cmat = c_ref[0].astype(f32)                   # (l, G, N)

    rep = n_heads // n_groups
    bm = jnp.repeat(bmat, rep, axis=1)            # (l, H, N)
    cm = jnp.repeat(cmat, rep, axis=1)

    A = -jnp.exp(a_log)                           # (H,)
    a = dt * A[None, :]                           # (l, H)
    a_cum = jnp.cumsum(a, axis=0)                 # (l, H)
    x_dt = x * dt[..., None]                      # (l, H, P)

    # intra-chunk: L[l,s] = exp(acum_l - acum_s) for l >= s
    seg = a_cum[:, None, :] - a_cum[None, :, :]   # (l, s, H)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    seg = jnp.where(tri[:, :, None], seg, -jnp.inf)
    L = jnp.exp(seg)                              # (l, s, H)
    # scores: (l,s,H) = sum_n C[l,h,n] B[s,h,n]
    scores = jnp.einsum("lhn,shn->lsh", cm, bm) * L
    y = jnp.einsum("lsh,shp->lhp", scores, x_dt)

    # inter-chunk: contribution of the carried state
    decay_in = jnp.exp(a_cum)                     # (l, H)
    state = state_ref[...].astype(f32)            # (H, P, N)
    y += jnp.einsum("lhn,hpn,lh->lhp", cm, state, decay_in)

    # update carried state for the next chunk
    decay_out = jnp.exp(a_cum[-1:, :] - a_cum)    # (l, H)
    chunk_state = jnp.einsum("lhn,lh,lhp->hpn", bm, decay_out, x_dt)
    total_decay = jnp.exp(jnp.sum(a, axis=0))     # (H,)
    state_ref[...] = (state * total_decay[:, None, None]
                      + chunk_state).astype(state_ref.dtype)

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_fwd(x, dt, a_log, B_mat, C_mat, *, chunk: int = 128,
            interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); B/C: (B,S,G,N) -> (B,S,H,P) f32."""
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (Bb, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_heads=H,
                               head_dim=P, n_state=N, n_groups=G)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, G, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, G, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, H, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, B_mat, C_mat)
