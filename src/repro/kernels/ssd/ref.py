"""Pure-jnp oracle for the SSD (Mamba2) kernel: sequential-recurrence
semantics, the ground truth both the chunked reference and the Pallas
kernel must match.

y_t = C_t · h_t + 0 (D-skip handled by the caller),
h_t = exp(dt_t A) h_{t-1} + dt_t B_t ⊗ x_t.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a_log, B_mat, C_mat):
    """x: (B,S,H,P); dt: (B,S,H) post-softplus; a_log: (H,);
    B_mat/C_mat: (B,S,G,N) -> y (B,S,H,P) in f32."""
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    f32 = jnp.float32
    x = x.astype(f32)
    dt = dt.astype(f32)
    Bm = jnp.repeat(B_mat.astype(f32), rep, axis=2)
    Cm = jnp.repeat(C_mat.astype(f32), rep, axis=2)
    A = -jnp.exp(a_log.astype(f32))

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # (B,H,P),(B,H),(B,H,N)×2
        da = jnp.exp(dtt * A)                     # (B,H)
        h = h * da[:, :, None, None] + jnp.einsum("bh,bhn,bhp->bhpn",
                                                  dtt, bt, xt)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bb, H, P, N), f32)
    _, ys = jax.lax.scan(step, h0,
                         (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
                          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)                 # (B,S,H,P)
