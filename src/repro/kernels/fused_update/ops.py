"""jit'd wrappers for the fused-update kernel, pytree-aware."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_update import kernel as K
from repro.kernels.fused_update import ref as R


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dispatch(kernel_fn, ref_fn, mode):
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref_fn
    return functools.partial(kernel_fn, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("eta", "mode"))
def sgd_step_tree(w_tree, g_tree, eta: float, mode: str = "auto"):
    fn = _dispatch(K.sgd_step, R.sgd_step_ref, mode)
    return jax.tree.map(lambda w, g: fn(w, g, eta), w_tree, g_tree)


@functools.partial(jax.jit, static_argnames=("eta_in", "lam", "mode"))
def prox_inner_tree(theta_tree, g_tree, w_tree, eta_in: float, lam: float,
                    mode: str = "auto"):
    fn = _dispatch(K.prox_inner, R.prox_inner_ref, mode)
    return jax.tree.map(lambda t, g, w: fn(t, g, w, eta_in, lam),
                        theta_tree, g_tree, w_tree)


@functools.partial(jax.jit, static_argnames=("eta", "lam", "mode"))
def prox_outer_tree(w_tree, theta_tree, eta: float, lam: float,
                    mode: str = "auto"):
    fn = _dispatch(K.prox_outer, R.prox_outer_ref, mode)
    return jax.tree.map(lambda w, t: fn(w, t, eta, lam), w_tree, theta_tree)


def donate_argnums(*argnums):
    """Donation is a no-op (plus a warning) off-TPU — only request it where
    it buys the in-place apply.  Single policy point, resolved lazily so
    merely importing the callers never initializes the JAX backend."""
    return argnums if jax.default_backend() == "tpu" else ()


@functools.lru_cache(maxsize=None)
def _apply_delta_jit():
    @functools.partial(jax.jit, static_argnames=("mode",),
                       donate_argnums=donate_argnums(0))
    def apply(w_tree, d_tree, scale, mode: str = "auto"):
        fn = _dispatch(K.apply_scaled, R.apply_scaled_ref, mode)
        s = jnp.asarray(scale, jnp.float32)
        return jax.tree.map(lambda w, d: fn(w, d, s), w_tree, d_tree)
    return apply


def apply_delta_tree(w_tree, d_tree, scale, mode: str = "auto"):
    """Server apply w ← w − s·Δ over a pytree in one fused pass per leaf.

    ``scale`` is traced (β, β/M, or staleness-damped β/(1+τ)^a), so one
    compile serves every staleness value and buffer count; the params tree
    is donated so on TPU the apply is an in-place read-modify-write.
    """
    return _apply_delta_jit()(w_tree, d_tree, scale, mode=mode)


@functools.lru_cache(maxsize=None)
def _apply_rows_jit():
    @functools.partial(jax.jit, static_argnames=("mode",),
                       donate_argnums=donate_argnums(0))
    def apply(w_tree, stack_tree, weights, mode: str = "auto"):
        fn = _dispatch(K.apply_rows, R.apply_rows_ref, mode)
        s = jnp.asarray(weights, jnp.float32)
        return jax.tree.map(lambda w, d: fn(w, d, s), w_tree, stack_tree)
    return apply


def spans_devices(tree) -> bool:
    """True when any leaf is a committed array sharded over >1 device.
    Tracers (inside jit) report False — callers that jit the apply must
    resolve the dispatch mode on concrete arrays first."""
    for leaf in jax.tree.leaves(tree):
        try:
            sharding = getattr(leaf, "sharding", None)
        except Exception:
            continue
        if sharding is not None and len(sharding.device_set) > 1:
            return True
    return False


@functools.lru_cache(maxsize=None)
def _apply_rows_seq_jit():
    # order-invariant sequential twin of _apply_rows_jit: the dispatch for
    # device-spanning stacks, where a per-shard partial-sum reduction
    # would make the flush result depend on the mesh layout
    @functools.partial(jax.jit, donate_argnums=donate_argnums(0))
    def apply(w_tree, stack_tree, weights, order):
        s = jnp.asarray(weights, jnp.float32)
        return jax.tree.map(
            lambda w, d: R.apply_rows_seq_ref(w, d, s, order),
            w_tree, stack_tree)
    return apply


@functools.lru_cache(maxsize=None)
def _apply_rows_q_seq_jit():
    @functools.partial(jax.jit, donate_argnums=donate_argnums(0))
    def apply(w_tree, q_tree, scales_tree, weights, order):
        s = jnp.asarray(weights, jnp.float32)
        return jax.tree.map(
            lambda w, q, sc: R.apply_rows_q_seq_ref(w, q, sc, s, order),
            w_tree, q_tree, scales_tree)
    return apply


def _default_order(stack_tree):
    import numpy as np
    return np.arange(jax.tree.leaves(stack_tree)[0].shape[0],
                     dtype=np.int32)


@functools.lru_cache(maxsize=None)
def _apply_rows_q_jit():
    @functools.partial(jax.jit, static_argnames=("mode",),
                       donate_argnums=donate_argnums(0))
    def apply(w_tree, q_tree, scales_tree, weights, mode: str = "auto"):
        fn = _dispatch(K.apply_rows_q, R.apply_rows_q_ref, mode)
        s = jnp.asarray(weights, jnp.float32)
        return jax.tree.map(lambda w, q, sc: fn(w, q, sc, s),
                            w_tree, q_tree, scales_tree)
    return apply


def apply_rows_q_tree(w_tree, q_tree, scales_tree, weights,
                      mode: str = "auto", order=None):
    """Quantized twin of :func:`apply_rows_tree`: the stack arrives as an
    int8 ``q_tree`` (leaves ``[M, ...]``) + f32 ``scales_tree`` (leaves
    ``[M]``, per row per leaf — the :class:`repro.core.quant.QuantStack`
    components) and each leaf's apply folds dequant × admission weight ×
    accumulate into one fused pass — no fp32 copy of the bank is ever
    materialized.  Sharded stacks force the sequential oracle path for
    the same reason as :func:`apply_rows_tree` (mesh-invariant reduction
    order).
    """
    if mode == "auto" and spans_devices(q_tree):
        mode = "seq"
    if mode == "seq":
        if order is None:
            order = _default_order(q_tree)
        return _apply_rows_q_seq_jit()(w_tree, q_tree, scales_tree,
                                       weights, order)
    return _apply_rows_q_jit()(w_tree, q_tree, scales_tree, weights,
                               mode=mode)


def apply_rows_tree(w_tree, stack_tree, weights, mode: str = "auto",
                    order=None):
    """Stacked server apply w ← w − Σ_i weights[i]·Δ_i per leaf, fused.

    ``stack_tree`` is a DeltaBank buffer: params-shaped pytree whose leaves
    carry a leading ``[M]`` cohort axis and never leave the device;
    ``weights`` is the traced ``[M]`` f32 row-weight vector (β/M, staleness
    damping, padding masks).  One compile per (bucket, leaf-shape) serves
    every flush.

    A device-spanning stack (``cohort_impl="shard_map"`` banks, on the 1-D
    or 2-D mesh alike) forces ``mode="seq"``: the sequential oracle
    (:func:`repro.kernels.fused_update.ref.apply_rows_seq_ref`)
    accumulates rows one at a time — in ``order`` when given, row order
    otherwise — so the flush result is bit-identical across mesh layouts.
    The Pallas kernel has no partitioning rule (it would gather the whole
    multi-GB buffer onto every device), and a ``jnp.sum`` reduction would
    reassociate per cohort split.
    """
    if mode == "auto" and spans_devices(stack_tree):
        mode = "seq"
    if mode == "seq":
        if order is None:
            order = _default_order(stack_tree)
        return _apply_rows_seq_jit()(w_tree, stack_tree, weights, order)
    return _apply_rows_jit()(w_tree, stack_tree, weights, mode=mode)
