"""Pure-jnp oracle for the fused local-update kernel.

Option A step:   w ← w − η g
Option C inner:  θ ← θ − η_in (g + λ(θ − w))
Option C outer:  w ← w − η λ (w − θ)
Server apply:    w ← w − s Δ   (s a *traced* scalar: β, β/M, or the
                 staleness-damped β/(1+τ)^a — no recompile per staleness)
Stacked apply:   w ← w − Σ_i s_i Δ_i          (fp32 bank rows)
Quantized apply: w ← w − Σ_i s_i·scale_i·q_i  (int8 bank rows + per-row
                 f32 scales: dequant folded into the reduction coefficient)

All of these are memory-bound elementwise chains over multi-GB parameter
tensors on the assigned architectures; the kernel fuses each into a single
HBM round-trip (DESIGN.md §6).
The stacked applies come in two reduction orders: the free-association
``jnp.sum`` forms below (fastest single-device lowering), and sequential
``*_seq_ref`` twins that accumulate rows one at a time in an explicit
order — the order-invariant oracle the sharded path uses so a flush's
result is bit-identical on the 1-D ``("cohort",)`` and 2-D
``("cohort", "model")`` meshes (a per-shard partial-sum reduction would
reassociate differently per cohort split).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_step_ref(w, g, eta: float):
    return (w.astype(jnp.float32) - eta * g.astype(jnp.float32)).astype(w.dtype)


def prox_inner_ref(theta, g, w, eta_in: float, lam: float):
    t32 = theta.astype(jnp.float32)
    return (t32 - eta_in * (g.astype(jnp.float32)
                            + lam * (t32 - w.astype(jnp.float32)))
            ).astype(theta.dtype)


def prox_outer_ref(w, theta, eta: float, lam: float):
    w32 = w.astype(jnp.float32)
    return (w32 - eta * lam * (w32 - theta.astype(jnp.float32))).astype(w.dtype)


def apply_scaled_ref(w, d, scale):
    """Server apply w ← w − s·Δ; ``scale`` may be a traced jnp scalar."""
    s = jnp.asarray(scale, jnp.float32)
    return (w.astype(jnp.float32) - s * d.astype(jnp.float32)).astype(w.dtype)


def apply_rows_ref(w, d_stack, weights):
    """Stacked server apply w ← w − Σ_i s_i·Δ_i in one reduction.

    ``d_stack`` is the on-device DeltaBank buffer ``[M, *w.shape]``;
    ``weights`` a traced ``[M]`` f32 vector carrying β/M, per-row staleness
    damping, and padding masks (zero rows contribute nothing).
    """
    s = jnp.asarray(weights, jnp.float32).reshape((-1,) + (1,) * w.ndim)
    acc = jnp.sum(s * d_stack.astype(jnp.float32), axis=0)
    return (w.astype(jnp.float32) - acc).astype(w.dtype)


def apply_rows_q_ref(w, q_stack, scales, weights):
    """Quantized stacked apply w ← w − Σ_i s_i·scale_i·q_i, one reduction.

    ``q_stack`` is the int8 ``[M, *w.shape]`` bank buffer and ``scales``
    its ``[M]`` f32 per-row dequant scales (``repro.core.quant``);
    ``weights`` the same traced admission-weight vector as
    :func:`apply_rows_ref`.  The dequant is folded into the per-row
    coefficient, so the oracle matches the kernel's arithmetic exactly
    (never dequantize-then-apply as two passes).
    """
    coeff = (jnp.asarray(weights, jnp.float32)
             * jnp.asarray(scales, jnp.float32)
             ).reshape((-1,) + (1,) * w.ndim)
    acc = jnp.sum(coeff * q_stack.astype(jnp.float32), axis=0)
    return (w.astype(jnp.float32) - acc).astype(w.dtype)


def apply_rows_seq_ref(w, d_stack, weights, order):
    """Order-invariant stacked apply: rows accumulate SEQUENTIALLY.

    ``order`` is an int32 ``[M]`` row permutation; the accumulation chain
    is ``((w − s_{o0}Δ_{o0}) − s_{o1}Δ_{o1}) − ...`` regardless of how the
    stack is sharded — every step is elementwise, so XLA SPMD partitions
    it spatially without reassociating the row chain.  This is what makes
    a serving-window flush bit-identical across mesh layouts: callers pass
    the *admission order* (a mesh-independent total order on the window's
    rows) and the result no longer depends on which cohort slice a row
    landed on.  Zero-weight padding rows contribute an exact ``+0``.
    """
    s = jnp.asarray(weights, jnp.float32)
    order = jnp.asarray(order, jnp.int32)

    def body(i, acc):
        j = order[i]
        return acc + s[j] * d_stack[j].astype(jnp.float32)

    acc = jax.lax.fori_loop(0, d_stack.shape[0], body,
                            jnp.zeros(w.shape, jnp.float32))
    return (w.astype(jnp.float32) - acc).astype(w.dtype)


def apply_rows_q_seq_ref(w, q_stack, scales, weights, order):
    """Quantized twin of :func:`apply_rows_seq_ref`: dequant folded into
    the per-row coefficient, rows accumulated sequentially in ``order``."""
    coeff = jnp.asarray(weights, jnp.float32) \
        * jnp.asarray(scales, jnp.float32)
    order = jnp.asarray(order, jnp.int32)

    def body(i, acc):
        j = order[i]
        return acc + coeff[j] * q_stack[j].astype(jnp.float32)

    acc = jax.lax.fori_loop(0, q_stack.shape[0], body,
                            jnp.zeros(w.shape, jnp.float32))
    return (w.astype(jnp.float32) - acc).astype(w.dtype)
