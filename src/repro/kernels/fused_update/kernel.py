"""Pallas TPU kernels: fused PersA-FL local updates and bank applies.

The paper's client loop applies η/λ-scaled parameter updates every local
step; at multi-billion-parameter scale each unfused update costs 3–4 HBM
round-trips (read w, read g, write w, plus the λ(θ−w) temporary for
Option C).  This module fuses each chain into one read-modify-write pass,
tiled as flat (block,) VMEM rows.  Math in f32, storage dtype preserved.

Two stacked-bank apply kernels close every aggregation window:

  * ``apply_rows``   — fp32 banking: ``w ← w − Σ_i weights[i]·Δ_i`` over a
    ``[M, n]`` delta stack, the weight vector folding β/M, per-row FedAsync
    staleness damping ``(1+τ)^{-a}`` and bucket-padding masks.
  * ``apply_rows_q`` — **int8 banking**: the stack arrives quantized
    (symmetric absmax, ``repro.core.quant``) as int8 rows + per-row f32
    scales, and the kernel folds dequantization × admission weight ×
    accumulate into the SAME one-pass read-modify-write: the coefficient
    ``weights[i]·scales[i]`` multiplies ``int8→f32`` rows in VMEM, so a
    straggler re-admission never materializes an fp32 delta row anywhere.
    Scales ride alongside the traced weight vector as a second ``[rows,1]``
    operand block — identical padding-mask and pow2-row-bucket discipline,
    so one compile per bucket serves every window composition.

Both have jnp oracles in ``ref.py`` (bit-comparable in interpret mode) and
pytree-aware jitted fronts in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 64 * 1024  # 256 KiB f32 per operand per step — comfortably VMEM


def _sgd_kernel(w_ref, g_ref, o_ref, *, eta):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = (w - eta * g).astype(o_ref.dtype)


def _prox_inner_kernel(t_ref, g_ref, w_ref, o_ref, *, eta_in, lam):
    t = t_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (t - eta_in * (g + lam * (t - w))).astype(o_ref.dtype)


def _prox_outer_kernel(w_ref, t_ref, o_ref, *, eta, lam):
    w = w_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    o_ref[...] = (w - eta * lam * (w - t)).astype(o_ref.dtype)


def _run_flat(kernel, out_dtype, *arrays, interpret=True):
    """Pad to a BLOCK multiple, run the 1-D grid, unpad."""
    flat = [a.reshape(-1) for a in arrays]
    n = flat[0].shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = [jnp.pad(a, (0, pad)) for a in flat]
    total = n + pad
    grid = (total // BLOCK,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)) for _ in flat],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), out_dtype),
        interpret=interpret,
    )(*flat)
    return out[:n].reshape(arrays[0].shape)


def sgd_step(w, g, eta: float, *, interpret: bool = True):
    return _run_flat(functools.partial(_sgd_kernel, eta=eta), w.dtype, w, g,
                     interpret=interpret)


def prox_inner(theta, g, w, eta_in: float, lam: float, *,
               interpret: bool = True):
    return _run_flat(functools.partial(_prox_inner_kernel, eta_in=eta_in,
                                       lam=lam),
                     theta.dtype, theta, g, w, interpret=interpret)


def prox_outer(w, theta, eta: float, lam: float, *, interpret: bool = True):
    return _run_flat(functools.partial(_prox_outer_kernel, eta=eta, lam=lam),
                     w.dtype, w, theta, interpret=interpret)


def _apply_scaled_kernel(w_ref, d_ref, s_ref, o_ref):
    # s lives in SMEM as a (1, 1) scalar so the scale (β, β/M, or the
    # staleness-damped β/(1+τ)^a) stays a traced value — one compile
    # serves every staleness/buffer-count the scheduler produces.
    s = s_ref[0, 0]
    w = w_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    o_ref[...] = (w - s * d).astype(o_ref.dtype)


# apply_rows tiles: ROW_BLOCK×COL_BLOCK f32 delta tiles must fit VMEM next
# to the w/o blocks — 128×8192×4 = 4 MiB.  Cohort buckets are pow2, so for
# M ≤ 128 (every realistic cohort) the whole reduction is ONE grid pass per
# column block and the f32 accumulator never round-trips through the output
# dtype; beyond that the row-chunk grid dim revisits the output block.
ROW_BLOCK = 128
COL_BLOCK = 8192


def _apply_rows_kernel(w_ref, d_ref, s_ref, o_ref):
    # partial reduction over this row chunk: s is [rows, 1] f32 in VMEM so
    # the weight vector (β/M · damping · padding mask per row) stays traced
    r = pl.program_id(1)
    part = jnp.sum(s_ref[...] * d_ref[...].astype(jnp.float32), axis=0)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = (w_ref[...].astype(jnp.float32) - part).astype(o_ref.dtype)

    @pl.when(r > 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) - part).astype(o_ref.dtype)


def apply_rows(w, d_stack, weights, *, interpret: bool = True):
    """Stacked server apply w ← w − Σ_i weights[i]·Δ_i, one fused pass.

    ``d_stack``: ``[M, *w.shape]`` stacked delta buffer (a DeltaBank's
    device buffer); ``weights``: traced ``[M]`` f32 — β/M, per-row FedAsync
    staleness damping and padding masks are all just rows of this vector,
    so one compile serves every buffer composition.  The column grid axis
    is major and the row-chunk axis minor, so each output block is visited
    on consecutive iterations (the Pallas revisiting contract).
    """
    m = d_stack.shape[0]
    flat_w = w.reshape(-1)
    flat_d = d_stack.reshape(m, -1)
    n = flat_w.shape[0]
    pad = (-n) % COL_BLOCK
    if pad:
        flat_w = jnp.pad(flat_w, (0, pad))
        flat_d = jnp.pad(flat_d, ((0, 0), (0, pad)))
    row_blk = min(1 << max(m - 1, 0).bit_length(), ROW_BLOCK)
    rpad = (-m) % row_blk
    s = jnp.asarray(weights, jnp.float32).reshape(m, 1)
    if rpad:  # zero-weight, zero-delta padding rows: contribute nothing
        flat_d = jnp.pad(flat_d, ((0, rpad), (0, 0)))
        s = jnp.pad(s, ((0, rpad), (0, 0)))
    total = n + pad
    grid = (total // COL_BLOCK, (m + rpad) // row_blk)
    out = pl.pallas_call(
        _apply_rows_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((COL_BLOCK,), lambda c, r: (c,)),
                  pl.BlockSpec((row_blk, COL_BLOCK), lambda c, r: (r, c)),
                  pl.BlockSpec((row_blk, 1), lambda c, r: (r, 0))],
        out_specs=pl.BlockSpec((COL_BLOCK,), lambda c, r: (c,)),
        out_shape=jax.ShapeDtypeStruct((total,), w.dtype),
        interpret=interpret,
    )(flat_w, flat_d, s)
    return out[:n].reshape(w.shape)


def _apply_rows_q_kernel(w_ref, q_ref, s_ref, sc_ref, o_ref):
    # fused dequant × admission-weight × accumulate: the per-row coefficient
    # weights[i]·scales[i] (both [rows, 1] f32, traced) multiplies the
    # int8→f32 rows in VMEM, so the fp32 delta row never exists in memory —
    # only the partial sums do
    r = pl.program_id(1)
    coeff = s_ref[...] * sc_ref[...]
    part = jnp.sum(coeff * q_ref[...].astype(jnp.float32), axis=0)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = (w_ref[...].astype(jnp.float32) - part).astype(o_ref.dtype)

    @pl.when(r > 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) - part).astype(o_ref.dtype)


def apply_rows_q(w, q_stack, scales, weights, *, interpret: bool = True):
    """Quantized stacked apply ``w ← w − Σ_i weights[i]·scales[i]·q_i``.

    ``q_stack``: ``[M, *w.shape]`` int8 rows (symmetric absmax quantized);
    ``scales``: ``[M]`` f32 per-row dequantization scales; ``weights``: the
    same traced ``[M]`` f32 admission-weight vector as :func:`apply_rows`.
    Same grid, padding and pow2-row-bucket discipline — zero-weight
    zero-scale padding rows contribute nothing — with the dequant folded
    into the reduction coefficient, so the bank's int8 rows are read once
    and no fp32 copy of the stack is ever materialized.
    """
    m = q_stack.shape[0]
    flat_w = w.reshape(-1)
    flat_q = q_stack.reshape(m, -1)
    n = flat_w.shape[0]
    pad = (-n) % COL_BLOCK
    if pad:
        flat_w = jnp.pad(flat_w, (0, pad))
        flat_q = jnp.pad(flat_q, ((0, 0), (0, pad)))
    row_blk = min(1 << max(m - 1, 0).bit_length(), ROW_BLOCK)
    rpad = (-m) % row_blk
    s = jnp.asarray(weights, jnp.float32).reshape(m, 1)
    sc = jnp.asarray(scales, jnp.float32).reshape(m, 1)
    if rpad:  # zero-weight, zero-scale padding rows: contribute nothing
        flat_q = jnp.pad(flat_q, ((0, rpad), (0, 0)))
        s = jnp.pad(s, ((0, rpad), (0, 0)))
        sc = jnp.pad(sc, ((0, rpad), (0, 0)))
    total = n + pad
    grid = (total // COL_BLOCK, (m + rpad) // row_blk)
    out = pl.pallas_call(
        _apply_rows_q_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((COL_BLOCK,), lambda c, r: (c,)),
                  pl.BlockSpec((row_blk, COL_BLOCK), lambda c, r: (r, c)),
                  pl.BlockSpec((row_blk, 1), lambda c, r: (r, 0)),
                  pl.BlockSpec((row_blk, 1), lambda c, r: (r, 0))],
        out_specs=pl.BlockSpec((COL_BLOCK,), lambda c, r: (c,)),
        out_shape=jax.ShapeDtypeStruct((total,), w.dtype),
        interpret=interpret,
    )(flat_w, flat_q, s, sc)
    return out[:n].reshape(w.shape)


def apply_scaled(w, d, scale, *, interpret: bool = True):
    """Server apply w ← w − s·Δ in one read-modify-write pass."""
    flat_w, flat_d = w.reshape(-1), d.reshape(-1)
    n = flat_w.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat_w = jnp.pad(flat_w, (0, pad))
        flat_d = jnp.pad(flat_d, (0, pad))
    total = n + pad
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _apply_scaled_kernel,
        grid=(total // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)),
                  pl.BlockSpec((BLOCK,), lambda i: (i,)),
                  pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), w.dtype),
        interpret=interpret,
    )(flat_w, flat_d, s)
    return out[:n].reshape(w.shape)
