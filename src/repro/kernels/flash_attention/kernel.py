"""Pallas TPU flash-attention (forward) kernel.

TPU adaptation (DESIGN.md §2): q is tiled into ``block_q``-row VMEM blocks
on a (batch, q-head, q-block) grid; K/V stream through VMEM in ``block_k``
chunks inside a ``fori_loop`` with the online-softmax running (m, l, acc)
state kept in VMEM scratch.  MXU alignment: block sizes are multiples of
128 and the contraction runs in f32.  GQA is expressed in the K/V
BlockSpec index maps (q-head h reads kv-head h // group), so no k/v
repetition ever hits HBM.  Supports causal masking, sliding windows
(gemma2/zamba2) and logit softcap (gemma2).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_q, block_k,
                  seq_k, causal, window, softcap):
    iq = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale       # (bq, hd)
    q_start = iq * block_q

    nk = seq_k // block_k
    if causal:
        # only stream k-blocks that intersect the causal cone
        nk_live = (q_start + block_q + block_k - 1) // block_k
        nk = min(nk, nk_live) if isinstance(nk_live, int) else nk

    def body(ik, carry):
        m_prev, l_prev, acc = carry
        k_start = ik * block_k
        k = k_ref[0, pl.ds(k_start, block_k), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.ds(k_start, block_k), 0, :].astype(jnp.float32)
        logits = q @ k.T                                     # (bq, bk)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = jnp.ones_like(logits, dtype=jnp.bool_)
        if causal:
            mask = mask & (kj <= qi)
        if window:
            mask = mask & (kj > qi - window)
        logits = jnp.where(mask, logits, NEG_INF)
        m_cur = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    hd = q_ref.shape[-1]
    init = (jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, hd), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, nk, body, init)
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, block_q: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd) -> (B,S,Hq,hd).

    ``interpret=True`` executes the kernel body in Python on CPU (our
    validation mode); on TPU pass ``interpret=False``.
    """
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    grid = (B, Hq, S // block_q)

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, block_q=block_q, block_k=block_k,
        seq_k=T, causal=causal, window=window, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, iq: (b, iq, h, 0)),
            pl.BlockSpec((1, T, 1, hd),
                         lambda b, h, iq, g=group: (b, 0, h // g, 0)),
            pl.BlockSpec((1, T, 1, hd),
                         lambda b, h, iq, g=group: (b, 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, iq: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
