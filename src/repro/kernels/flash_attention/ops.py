"""jit'd public wrapper for the flash-attention kernel.

Dispatch: Pallas on TPU, interpret-mode Pallas for explicit kernel
validation, jnp reference otherwise (CPU dry-runs lower the reference —
kernels are a TPU-target artifact, DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k", "mode"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, mode: str = "auto"):
    """mode: "auto" (tpu->kernel else ref), "kernel" (interpret on CPU),
    "ref" (pure jnp)."""
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    interpret = not _on_tpu()
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=interpret)
