"""Pure-jnp oracle for the flash-attention kernel.

Plain materialized-logits attention with causal / sliding-window masking,
GQA head grouping and optional logit softcap — the semantics the Pallas
kernel must reproduce blockwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd); Hq % Hkv == 0 -> (B,S,Hq,hd)."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, S, Hkv, group, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (kj <= qi)
    if window:
        mask = mask & (kj > qi - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, Hq, hd)
