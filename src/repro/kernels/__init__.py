"""Pallas TPU kernels for the perf-critical compute hot-spots (DESIGN.md §6).

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch wrapper) and ref.py (pure-jnp oracle); validated in interpret mode
on CPU, targeted at TPU v5e.
"""
