"""Partial-model personalization: the ``personal_subset`` param-tree spec.

"Sharper Convergence Guarantees for Federated Learning with Partial Model
Personalization" (arXiv 2309.17409) splits the model into a shared backbone
and a small per-user *personal subset* (head / last-k blocks / LoRA-style
factors): only the subset is personalized per user, so only the subset
needs per-user banking — the single biggest lever toward millions of
resident users (ROADMAP).  This module is the one spelling of that split
used by every layer: strategy (``repro.fl.api``), window apply
(``repro.core.server``), serving ring/cache (``repro.serving``),
checkpoints and the wire (``subset`` descriptor in transport headers).

A :class:`SubsetSpec` is a frozen tuple of *path prefixes* in the
checkpoint store's flat layout (``repro.checkpoint.store``): dict keys
joined by ``/``, list/tuple indices spelled ``#i`` — e.g. ``("fc/#1",)``
selects the last fully-connected layer of the fig2 CNN.  A prefix selects
every leaf at or below it.  Specs also build from a *pytree bool mask*
(True leaves are personal).

Subset pytrees use the **pruned form**: dict keys with no selected leaf
are dropped and unselected list slots become ``None`` (an empty pytree
node, skipped by ``jax.tree.map``), trailing ``None`` slots trimmed.  The
pruned form is closed under the npz codec — ``decode(encode(extract(t)))``
has the same treedef as ``extract(t)`` — so bank rows, ring snapshots,
checkpoints and wire frames all share one structure and every
``tree.map`` between them lines up.

All helpers are pure structural walks (no shape/value access beyond
leaves), so they are trace-safe inside jit/vmap and work on tracers.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

import jax
import numpy as np

_MISSING = object()


def _is_leaf(node) -> bool:
    return not isinstance(node, (dict, list, tuple)) and node is not None


def leaf_paths(tree) -> Tuple[str, ...]:
    """Every leaf path of ``tree`` in the checkpoint store's flat spelling
    (sorted dict keys irrelevant — paths are order-free)."""
    out = []

    def walk(node, prefix):
        if isinstance(node, dict):
            for k in node:
                walk(node[k], f"{prefix}{k}/")
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{prefix}#{i}/")
        elif node is None:
            pass
        else:
            out.append(prefix[:-1])

    walk(tree, "")
    return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class SubsetSpec:
    """The personal subset of a param pytree, as flat path prefixes.

    Hashable (usable as a jit static argument / dict key); equality is on
    the normalized prefix tuple.  Matching is prefix-wise: leaf path ``p``
    is personal iff some prefix ``q`` satisfies ``p == q`` or
    ``p.startswith(q + "/")``.
    """

    prefixes: Tuple[str, ...]

    # -- construction ------------------------------------------------------

    @staticmethod
    def resolve(spec, tree=None) -> Optional["SubsetSpec"]:
        """Normalize any accepted spelling to a SubsetSpec (or None).

        Accepted: None, a SubsetSpec, one path-prefix string, an iterable
        of path-prefix strings, or a pytree bool mask (True leaves are
        personal).  With ``tree`` given, the spec is validated against it
        (:meth:`validate`).
        """
        if spec is None:
            return None
        if isinstance(spec, SubsetSpec):
            out = spec
        elif isinstance(spec, str):
            out = SubsetSpec(tuple(p for p in spec.split(",") if p))
        elif isinstance(spec, (list, tuple)) and spec \
                and all(isinstance(p, str) for p in spec):
            # a list/tuple of path prefixes (the descriptor spelling)
            out = SubsetSpec(tuple(spec))
        elif isinstance(spec, (dict, list, tuple)):
            # pytree bool mask: collect the True leaf paths
            paths = []

            def walk(node, prefix):
                if isinstance(node, dict):
                    for k in node:
                        walk(node[k], f"{prefix}{k}/")
                elif isinstance(node, (list, tuple)):
                    for i, v in enumerate(node):
                        walk(v, f"{prefix}#{i}/")
                elif node:
                    paths.append(prefix[:-1])

            walk(spec, "")
            out = SubsetSpec(tuple(sorted(paths)))
        elif isinstance(spec, Iterable):
            out = SubsetSpec(tuple(str(p) for p in spec))
        else:
            raise TypeError(f"cannot build a SubsetSpec from {type(spec)}")
        if not isinstance(out.prefixes, tuple) \
                or not all(isinstance(p, str) for p in out.prefixes):
            raise TypeError("SubsetSpec prefixes must be a tuple of paths")
        if not out.prefixes:
            raise ValueError("personal_subset selects no leaves")
        if tree is not None:
            out.validate(tree)
        return out

    @staticmethod
    def from_descriptor(paths) -> "SubsetSpec":
        """Rebuild from a wire/checkpoint descriptor (a list of paths)."""
        return SubsetSpec(tuple(str(p) for p in paths))

    # -- matching ----------------------------------------------------------

    def _match(self, path: str) -> bool:
        return any(path == q or path.startswith(q + "/")
                   for q in self.prefixes)

    def validate(self, tree) -> Tuple[str, ...]:
        """Concrete personal leaf paths of ``tree``; raises if any prefix
        matches nothing (a typo'd subset must fail loudly, not silently
        personalize nothing)."""
        paths = leaf_paths(tree)
        for q in self.prefixes:
            if not any(p == q or p.startswith(q + "/") for p in paths):
                raise ValueError(
                    f"personal_subset prefix {q!r} matches no param leaf; "
                    f"leaves are {list(paths)[:8]}...")
        return tuple(p for p in paths if self._match(p))

    def descriptor(self, tree=None) -> list:
        """JSON-able wire/checkpoint descriptor.  With ``tree`` given,
        the resolved concrete leaf paths (what a client needs to merge a
        subset head into its own backbone); otherwise the raw prefixes."""
        return list(self.validate(tree)) if tree is not None \
            else list(self.prefixes)

    # -- structural transforms --------------------------------------------

    def extract(self, tree):
        """``tree`` restricted to the personal subset, in pruned form."""

        def walk(node, prefix):
            if isinstance(node, dict):
                out = {}
                for k in node:
                    sub = walk(node[k], f"{prefix}{k}/")
                    if sub is not _MISSING:
                        out[k] = sub
                return out if out else _MISSING
            if isinstance(node, (list, tuple)):
                subs = [walk(v, f"{prefix}#{i}/")
                        for i, v in enumerate(node)]
                if all(s is _MISSING for s in subs):
                    return _MISSING
                last = max(i for i, s in enumerate(subs)
                           if s is not _MISSING)
                return [None if s is _MISSING else s
                        for s in subs[:last + 1]]
            if node is None:
                return _MISSING
            return node if self._match(prefix[:-1]) else _MISSING

        sub = walk(tree, "")
        return {} if sub is _MISSING else sub

    def mask(self, tree):
        """``tree``-structured pytree of Python bools (True = personal).
        Feed to ``jax.tree.map`` for masked updates, or map to ``0``/None
        for vmap ``in_axes`` over mixed stacked-subset/shared-backbone
        trees."""

        def walk(node, prefix):
            if isinstance(node, dict):
                return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
            if isinstance(node, (list, tuple)):
                out = [walk(v, f"{prefix}#{i}/") for i, v in enumerate(node)]
                return type(node)(out) if isinstance(node, tuple) else out
            if node is None:
                return None
            return self._match(prefix[:-1])

        return walk(tree, "")


def merge_subset(full, sub):
    """``full`` with every leaf present in ``sub`` replaced by ``sub``'s.

    Drives off ``full``'s structure and tolerates every pruned spelling of
    ``sub`` — extract()'s form, the npz round-trip's form (missing keys,
    gap lists), or None (nothing personal).  Trace-safe; the merge is how
    a subset snapshot/head recombines with the shared backbone.
    """
    if sub is None:
        return full
    if isinstance(full, dict):
        get = sub.get if isinstance(sub, dict) else (lambda k: None)
        return {k: merge_subset(v, get(k)) for k, v in full.items()}
    if isinstance(full, (list, tuple)):
        n = len(sub) if isinstance(sub, (list, tuple)) else 0
        out = [merge_subset(v, sub[i] if i < n else None)
               for i, v in enumerate(full)]
        return type(full)(out) if isinstance(full, tuple) else out
    return sub


def subset_like(full, sub):
    """``full``'s leaves re-arranged into ``sub``'s pruned structure — the
    params-side operand of a subset-shaped ``apply_rows`` (same treedef as
    the subset delta stack)."""
    if sub is None:
        return None
    if isinstance(sub, dict):
        return {k: subset_like(full[k], v) for k, v in sub.items()}
    if isinstance(sub, (list, tuple)):
        return [subset_like(full[i], v) for i, v in enumerate(sub)]
    return full


def tree_nbytes(tree) -> int:
    """Total leaf bytes of a pytree (host or device arrays)."""
    return int(sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
                   for x in jax.tree.leaves(tree)))


def row_nbytes(stacked_tree) -> int:
    """Bytes of ONE row of a stacked ``[capacity, ...]`` bank buffer — the
    per-user unit the ``ring_bytes_per_user`` stat and bench gate count."""
    return int(sum(int(np.prod(x.shape[1:])) * np.dtype(x.dtype).itemsize
                   for x in jax.tree.leaves(stacked_tree)))
