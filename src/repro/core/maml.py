"""Option B — MAML personalized gradient estimators (paper Eq. 5 & 9).

∇F_i(w) = [I − α ∇²f_i(w; D″)] ∇f_i(w − α ∇f_i(w; D′); D)

Three estimators (paper §2.2 & Appendix D):
  * ``full`` — exact Hessian-vector product via forward-over-reverse
    (jvp of grad).  The paper computes ∇²f̃·v with a stochastic Hessian; the
    JAX HVP is the same quantity without materializing the Hessian.
  * ``fo``   — FO-MAML: drop the Hessian term.
  * ``hf``   — HF-MAML (paper Eq. D1): central finite difference
    ∇²f(w)u ≈ [∇f(w+δu) − ∇f(w−δu)] / (2δ), direction-normalised.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Loss = Callable  # loss_fn(params, batch) -> scalar


def _axpy(a: float, x, y):
    """y + a*x over pytrees (computed in the params' dtype)."""
    return jax.tree.map(lambda xx, yy: yy + a * xx, x, y)


def tree_dot(x, y):
    return sum(jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32))
               for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)))


def tree_norm(x):
    return jnp.sqrt(tree_dot(x, x))


def maml_grad(loss_fn: Loss, params, batch, batch_prime, batch_dprime,
              alpha: float, mode: str = "full", hf_delta: float = 1e-2):
    """Stochastic MAML gradient (Eq. 9). Returns a pytree like ``params``."""
    g_inner = jax.grad(loss_fn)(params, batch_prime)
    adapted = _axpy(-alpha, g_inner, params)
    g_outer = jax.grad(loss_fn)(adapted, batch)
    if mode == "fo" or alpha == 0.0:
        return g_outer
    if mode == "full":
        # HVP at w on batch D'': ∇²f(w; D'') @ g_outer
        hvp = jax.jvp(lambda p: jax.grad(loss_fn)(p, batch_dprime),
                      (params,), (g_outer,))[1]
        return _axpy(-alpha, hvp, g_outer)
    if mode == "hf":
        # normalize the direction for numerical stability, rescale after
        nrm = tree_norm(g_outer)
        safe = jnp.maximum(nrm, 1e-12)
        u = jax.tree.map(lambda g: (g / safe).astype(g.dtype), g_outer)
        gp = jax.grad(loss_fn)(_axpy(hf_delta, u, params), batch_dprime)
        gm = jax.grad(loss_fn)(_axpy(-hf_delta, u, params), batch_dprime)
        fd = jax.tree.map(
            lambda a, b: ((a - b) * (nrm / (2.0 * hf_delta))).astype(a.dtype),
            gp, gm)
        return _axpy(-alpha, fd, g_outer)
    raise ValueError(f"unknown maml mode {mode!r}")


def personalize_maml(loss_fn: Loss, params, batch, alpha: float):
    """Client-side fine-tuning: one SGD step (the paper's evaluation budget)."""
    g = jax.grad(loss_fn)(params, batch)
    return _axpy(-alpha, g, params)
