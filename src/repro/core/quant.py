"""Symmetric int8 absmax quantization for delta banking + error feedback.

The serving ring's residency and the wire's SUBMIT/HEAD bodies both scale
linearly with delta precision.  This module provides the one codec both
reuse: **symmetric absmax int8** — per ROW per LEAF for stacked bank
buffers (:class:`QuantStack`), per LEAF for retained snapshots
(:class:`QuantTree`) —

    scale = absmax / 127          (0 for an all-zero row: dequant is exact)
    q     = clip(round(x / scale), -127, 127)  int8
    deq   = scale * q                          f32

plus **error feedback** (:func:`ef_quantize_stack`): the quantization
error of a user's banked delta is carried on device and added to that
user's *next* delta before re-quantizing, so banking noise stays a bounded
residual instead of a bias that accumulates across aggregation windows.

Handle types consumed by the serving stack:

  * :class:`QuantStack` — the quantized twin of a DeltaBank's ``stacked``
    buffer: int8 ``q`` leaves ``[capacity, ...]`` + f32 ``scales`` leaves
    ``[capacity]``.  A NamedTuple, hence a pytree: ``jax.tree`` utilities,
    shard_map and ``row_nbytes`` accounting all see both components.
  * :class:`QuantTree` — a quantized params(-subset) snapshot: int8
    leaves + one f32 scalar scale per leaf.
  * :class:`QuantizedBank` — duck-types the DeltaBank surface the
    :class:`repro.serving.bank.DeltaRing` needs (``stacked`` /
    ``capacity`` / ``k`` / ``__len__``) while never holding fp32 rows;
    ``rows()`` is a fused dequantizing gather.
  * :class:`QuantizedHeads` — a *lazy* head bank: ``head = snapshot −
    scale·q`` computed per gather, so quantized serving stores NO separate
    head bank at all (the residency win the ``quant`` bench gates).

The global window apply never materializes fp32 rows either:
``repro.core.apply_admitted_rows`` dispatches a :class:`QuantStack` to the
fused dequant-×-weight-×-accumulate kernel
``repro.kernels.fused_update.apply_rows_q``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class QuantStack(NamedTuple):
    """Quantized stacked bank buffer: int8 rows + per-row-per-leaf scales."""
    q: Any        # int8 pytree, leaves [capacity, ...]
    scales: Any   # f32 pytree, leaves [capacity]


class QuantTree(NamedTuple):
    """Quantized params(-subset) tree: int8 leaves + per-leaf scalar scale."""
    q: Any        # int8 pytree, param-shaped
    scales: Any   # f32 pytree, scalar per leaf


def _row_scale(x):
    """Per-row absmax/127 over all trailing axes; shape ``[capacity]``."""
    x32 = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x32), axis=tuple(range(1, x.ndim))) \
        if x.ndim > 1 else jnp.abs(x32)
    return absmax / 127.0


def _bcast(scale, ndim):
    return scale.reshape(scale.shape + (1,) * (ndim - scale.ndim))


def _q(x32, scale):
    safe = jnp.where(scale > 0, scale, 1.0)
    return jnp.clip(jnp.round(x32 / safe), -127, 127).astype(jnp.int8)


def _quantize_stack(tree) -> QuantStack:
    leaves, treedef = jax.tree.flatten(tree)
    qs, scs = [], []
    for x in leaves:
        sc = _row_scale(x)
        qs.append(_q(x.astype(jnp.float32), _bcast(sc, x.ndim)))
        scs.append(sc)
    return QuantStack(jax.tree.unflatten(treedef, qs),
                      jax.tree.unflatten(treedef, scs))


def _dequantize_stack(qstack: QuantStack):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * _bcast(s, q.ndim),
        qstack.q, qstack.scales)


@functools.lru_cache(maxsize=None)
def _quantize_stack_jit():
    return jax.jit(_quantize_stack)


@functools.lru_cache(maxsize=None)
def _dequantize_stack_jit():
    return jax.jit(_dequantize_stack)


def quantize_stack(tree) -> QuantStack:
    """``[capacity, ...]`` fp stacked pytree → :class:`QuantStack`."""
    return _quantize_stack_jit()(tree)


def dequantize_stack(qstack: QuantStack):
    """:class:`QuantStack` → fp32 stacked pytree (scale·q per row)."""
    return _dequantize_stack_jit()(qstack)


@functools.lru_cache(maxsize=None)
def _quantize_tree_jit():
    @jax.jit
    def f(tree):
        leaves, treedef = jax.tree.flatten(tree)
        qs, scs = [], []
        for x in leaves:
            sc = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
            qs.append(_q(x.astype(jnp.float32), sc))
            scs.append(sc)
        return QuantTree(jax.tree.unflatten(treedef, qs),
                         jax.tree.unflatten(treedef, scs))
    return f


@functools.lru_cache(maxsize=None)
def _dequantize_tree_jit():
    @jax.jit
    def f(qtree):
        return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                            qtree.q, qtree.scales)
    return f


def quantize_tree(tree) -> QuantTree:
    """Params(-subset) pytree → :class:`QuantTree` (per-leaf scalar scale).
    Used for retained ring snapshots of already-closed windows."""
    return _quantize_tree_jit()(tree)


def dequantize_tree(qtree: QuantTree):
    return _dequantize_tree_jit()(qtree)


# -- error feedback ---------------------------------------------------------

def _ef_body(adj):
    qstack = _quantize_stack(adj)
    err = jax.tree.map(lambda a, d: a.astype(jnp.float32) - d,
                       adj, _dequantize_stack(qstack))
    return qstack, _quantize_stack(err)


@functools.lru_cache(maxsize=None)
def _ef_jit():
    return jax.jit(_ef_body)


@functools.lru_cache(maxsize=None)
def _ef_res_jit():
    @jax.jit
    def f(raw, res):
        return _ef_body(jax.tree.map(
            lambda x, r: x.astype(jnp.float32) + r, raw, res))
    return f


def ef_quantize_stack(raw, residual=None):
    """One fused error-feedback quantization step over a stacked buffer.

    ``adj = raw + residual`` (per row; ``residual`` is the carried
    quantization error of each row's user, zeros where absent), then
    ``adj`` is quantized and the NEW error ``adj − dequant`` is itself
    quantized for storage.  Returns ``(delta QuantStack, residual
    QuantStack)`` — the second is what the caller banks per user and feeds
    back on that user's next submission.  Quantizing the stored residual
    adds only a second-order error (≤ scale/254 of an already-small
    tensor), which the EF property test bounds.
    """
    if residual is None:
        return _ef_jit()(raw)
    return _ef_res_jit()(raw, residual)


# -- gathers ----------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _gather_rows_jit():
    @jax.jit
    def f(qstack, rows):
        return jax.tree.map(
            lambda q, s: jnp.take(q, rows, axis=0).astype(jnp.float32)
            * _bcast(jnp.take(s, rows, axis=0), q.ndim),
            qstack.q, qstack.scales)
    return f


@functools.lru_cache(maxsize=None)
def _head_rows_jit():
    @jax.jit
    def f(snap, qstack, rows):
        def one(p, q, s):
            d = jnp.take(q, rows, axis=0).astype(jnp.float32) \
                * _bcast(jnp.take(s, rows, axis=0), q.ndim)
            return (p[None].astype(jnp.float32) - d).astype(p.dtype)
        return jax.tree.map(one, snap, qstack.q, qstack.scales)
    return f


class QuantizedBank:
    """DeltaBank-shaped handle over a :class:`QuantStack`.

    Presents exactly the surface :class:`repro.serving.bank.DeltaRing`
    and ``apply_admitted_rows`` touch (``stacked``/``capacity``/``k``);
    there is deliberately no host-materializing ``row()`` — quantized
    banking never leaves the device, so ``host_materializations`` cannot
    move.
    """

    def __init__(self, qstack: QuantStack, k: int,
                 stats: Optional[Dict] = None):
        self.stacked = qstack
        self.k = k
        self._stats = stats if stats is not None else {}

    @property
    def capacity(self) -> int:
        return jax.tree.leaves(self.stacked.q)[0].shape[0]

    def __len__(self) -> int:
        return self.k

    def rows(self, rows):
        """Dequantized fp32 ``[len(rows), ...]`` gather (device-side)."""
        return _gather_rows_jit()(self.stacked,
                                  jnp.asarray(rows, jnp.int32))

    def row(self, i: int):
        return jax.tree.map(lambda x: x[0], self.rows([int(i)]))


class QuantizedHeads:
    """Lazy quantized head bank: ``head_row = snapshot − scale·q``.

    Nothing is stored beyond a reference to the flush's snapshot tree and
    its delta :class:`QuantizedBank` — the fp32 head bank of the fp32
    serving path simply does not exist here, which is where quantized
    serving's ≥ 3.5x per-user residency win comes from.  ``rows``/``row``
    fuse the dequant and the subtraction into one jitted device gather
    (same output dtype discipline as the eager head bank: compute f32,
    store the param dtype).
    """

    def __init__(self, snapshot, qbank: QuantizedBank):
        self.snapshot = snapshot
        self.qbank = qbank

    @property
    def k(self) -> int:
        return self.qbank.k

    def rows(self, rows):
        return _head_rows_jit()(self.snapshot, self.qbank.stacked,
                                jnp.asarray(rows, jnp.int32))

    def row(self, i: int):
        return jax.tree.map(lambda x: x[0], self.rows([int(i)]))


def fp32_row_nbytes(qstack: QuantStack) -> int:
    """Bytes ONE row of this stack would occupy as fp32 — the baseline the
    ``ring_bytes_saved_per_user`` stat and quant bench gate compare
    against (scales excluded: fp32 banking has none)."""
    return int(sum(int(np.prod(x.shape[1:])) * 4
                   for x in jax.tree.leaves(qstack.q)))
