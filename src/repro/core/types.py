"""PersA-FL typed containers: the hyper-parameter config (Algorithms 1 & 2)
and the server-state pytree (Algorithm 1's (w, t) + Assumption 1's staleness
accounting)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


@dataclasses.dataclass(frozen=True)
class PersAFLConfig:
    """Hyper-parameters of Algorithms 1 & 2.

    option: "A" (FedAsync), "B" (PersA-FL-MAML), "C" (PersA-FL-ME).
    """
    option: str = "A"
    q_local: int = 10          # Q local steps (paper §5 uses Q=10)
    eta: float = 0.01          # local stepsize η (paper Appendix D)
    beta: float = 1.0          # server stepsize β (Theorems use β=1)

    # Option B (MAML)
    alpha: float = 0.01        # personalization stepsize α
    maml_mode: str = "full"    # full | fo | hf
    hf_delta: float = 1e-2     # finite-difference δ (paper Eq. D1)

    # Option C (Moreau envelope)
    lam: float = 30.0          # λ regularization (paper picks from {20,25,30})
    inner_steps: int = 10      # K inner SGD steps for θ̃ (paper Appendix D)
    inner_eta: float = 0.03    # inner solver stepsize
    nu_target: float = 1e-3    # ν accuracy target (reported, not enforced)

    # beyond-paper: buffered server aggregation (FedBuff [51,63]) — M deltas
    # are summed and applied as one w ← w − β/M ΣΔ server round
    # (FLRun schedule=buffered(M)); 1 = paper-faithful immediate apply
    buffer_size: int = 1
    # beyond-paper: FedAsync-style polynomial staleness damping a in
    # β/(1+τ)^a on async applies; 0 = paper-faithful constant β
    staleness_damping: float = 0.0
    # delta accumulator dtype ("float32" faithful; "bfloat16" halves the
    # client-delta memory/traffic on multi-B-param archs — §Perf knob)
    delta_dtype: str = "float32"

    def personalize_budget(self) -> str:
        return {"A": "none", "B": f"1 SGD step @ alpha={self.alpha}",
                "C": f"{self.inner_steps} prox steps @ lambda={self.lam}"}[
                    self.option]


@dataclasses.dataclass
class ServerState:
    """Algorithm 1's server state as a typed, pytree-registered dataclass.

    Fields: the global model ``params`` (w), the version counter ``t``, and
    Assumption 1's staleness accounting (Σ τ, max τ) over applied updates.
    Registered as a jax pytree, so instances flow through jit/donation/
    ``jax.tree.map`` exactly like the raw dict they replace — one typed
    state object end-to-end (engine applies, serving DeltaRing snapshots,
    checkpoint store).

    Dict-style reads (``state["params"]``) are kept as a thin compatibility
    affordance for pre-PR-4 call sites; new code should use attributes.
    """
    params: Any
    t: Any
    staleness_sum: Any
    staleness_max: Any

    # -- legacy dict-style access (the raw-dict era's spelling) -----------
    def __getitem__(self, key: str):
        return getattr(self, key)

    def keys(self):
        return (f.name for f in dataclasses.fields(self))

    def as_dict(self) -> dict:
        """Shallow field dict (leaves NOT copied) — checkpoint layout."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}

    @classmethod
    def from_dict(cls, d) -> "ServerState":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})

    def replace(self, **kw) -> "ServerState":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_pytree_node(
    ServerState,
    lambda s: ((s.params, s.t, s.staleness_sum, s.staleness_max), None),
    lambda _, children: ServerState(*children),
)
