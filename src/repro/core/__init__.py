"""PersA-FL core: the paper's contribution (Algorithms 1 & 2)."""
from repro.core.types import PersAFLConfig, ServerState          # noqa: F401
from repro.core.client import client_update, split_batches_for_option  # noqa: F401
from repro.core.server import (init_server_state, apply_update,  # noqa: F401
                               apply_buffered, apply_buffered_rows,
                               apply_admitted_rows, admission_weights,
                               robust_admission_weights,
                               robust_flush_weights, bank_row_norms,
                               mask_rows, scale_rows, staleness_stats)
from repro.core.maml import maml_grad, personalize_maml          # noqa: F401
from repro.core.moreau import me_grad, personalize_me, solve_prox  # noqa: F401
from repro.core.subset import (SubsetSpec, leaf_paths,           # noqa: F401
                               merge_subset, subset_like,
                               row_nbytes, tree_nbytes)
from repro.core.quant import (QuantStack, QuantTree,             # noqa: F401
                              QuantizedBank, QuantizedHeads,
                              quantize_stack, dequantize_stack,
                              quantize_tree, dequantize_tree,
                              ef_quantize_stack, fp32_row_nbytes)
