"""Algorithm 2 — client local update (Q steps, Options A/B/C).

The Q-step loop is a ``lax.scan`` carrying the *accumulated delta* rather
than a second parameter copy: w_q = w₀ − Δ_q and Δ_{q+1} = Δ_q + η ∇̃ — the
exact telescoping of Algorithm 2 (Δ = w_{i,0} − w_{i,Q} = η Σ_q ∇̃), but
with peak memory 2× params instead of 3× (DESIGN.md §2).  Δ accumulates in
f32 even when params are bf16.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import maml as maml_mod
from repro.core import moreau as me_mod
from repro.core.maml import tree_norm
from repro.core.types import PersAFLConfig

Loss = Callable


def _zeros_f32(params, dtype=jnp.float32):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype), params)


def _current_w(params, delta):
    return jax.tree.map(lambda p, d: (p.astype(jnp.float32)
                                      - d.astype(jnp.float32)).astype(p.dtype),
                        params, delta)


def client_update(pcfg: PersAFLConfig, loss_fn: Loss, params,
                  batches) -> Tuple:
    """Run Q local steps; return (delta pytree [f32], metrics dict).

    ``batches``: pytree whose leaves have leading dim Q (Options A/C) or a
    dict {"d","dp","dpp"} of three such pytrees (Option B, paper's three
    independent batches D, D′, D″).
    """
    option = pcfg.option

    def step(delta, batch_q):
        w = _current_w(params, delta)
        nu = jnp.zeros((), jnp.float32)
        if option == "A":
            g = jax.grad(loss_fn)(w, batch_q)
        elif option == "B":
            g = maml_mod.maml_grad(loss_fn, w, batch_q["d"], batch_q["dp"],
                                   batch_q["dpp"], pcfg.alpha,
                                   mode=pcfg.maml_mode,
                                   hf_delta=pcfg.hf_delta)
        elif option == "C":
            g, nu = me_mod.me_grad(loss_fn, w, batch_q, pcfg.lam,
                                   pcfg.inner_eta, pcfg.inner_steps)
        else:
            raise ValueError(f"unknown option {option!r}")
        delta = jax.tree.map(
            lambda d, gg: (d.astype(jnp.float32)
                           + pcfg.eta * gg.astype(jnp.float32))
            .astype(d.dtype), delta, g)
        return delta, (tree_norm(g), nu)

    acc_dtype = jnp.dtype(pcfg.delta_dtype)
    delta, (gnorms, nus) = jax.lax.scan(step, _zeros_f32(params, acc_dtype),
                                        batches)
    metrics = {"grad_norm_mean": jnp.mean(gnorms),
               "delta_norm": tree_norm(delta),
               "nu_mean": jnp.mean(nus)}
    return delta, metrics


def split_batches_for_option(option: str, batches_3q):
    """Adapt a 3Q-leading-dim batch pytree to the option's layout.

    Data pipeline always yields 3Q batches so all options consume the same
    stream; A/C use the first Q, B uses the (D, D′, D″) triple split.
    """
    q3 = jax.tree.leaves(batches_3q)[0].shape[0]
    q = q3 // 3
    first = jax.tree.map(lambda x: x[:q], batches_3q)
    if option in ("A", "C"):
        return first
    second = jax.tree.map(lambda x: x[q:2 * q], batches_3q)
    third = jax.tree.map(lambda x: x[2 * q:], batches_3q)
    return {"d": first, "dp": second, "dpp": third}
