"""Algorithm 1 — asynchronous server.

Paper-faithful mode: on receiving Δ from any client, immediately
``w ← w − β Δ`` and bump the version counter t.  Staleness bookkeeping
(Assumption 1) tracks τ = t − Ω(t) per applied update.

Beyond-paper (FedBuff [51]; unbounded-gradient analysis [63]): a buffered
variant aggregates M deltas then applies their mean once — on the TPU mesh
this is one psum over the cohort axes per round (DESIGN.md §2/§5).  The
event-driven counterpart is ``FLRun(schedule=buffered(M))``
(:mod:`repro.fl.api`), which feeds :func:`apply_buffered_rows` one stacked
bank + weight vector per flush.

Every apply routes through ``kernels/fused_update.apply_delta_tree`` — a
single read-modify-write pass per leaf with a *traced* scale, so one compile
serves every staleness value, buffer count, and the optional FedAsync-style
polynomial staleness damping β/(1+τ)^a (``PersAFLConfig.staleness_damping``).

Server state is the typed :class:`repro.core.types.ServerState` pytree
(params, t, Σ τ, max τ) — every apply takes one and returns one; the raw
dict spelling survives only as ``state["..."]`` read compatibility.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PersAFLConfig, ServerState
from repro.core.quant import QuantStack
from repro.core.subset import merge_subset, subset_like
from repro.kernels.fused_update.ops import (apply_delta_tree,
                                            apply_rows_q_tree,
                                            apply_rows_tree, donate_argnums,
                                            spans_devices)


def init_server_state(params) -> ServerState:
    return ServerState(
        params=params,
        t=jnp.zeros((), jnp.int32),
        staleness_sum=jnp.zeros((), jnp.float32),
        staleness_max=jnp.zeros((), jnp.int32),
    )


# the whole apply — fused param update AND the counter/staleness
# bookkeeping — is one jitted call: the schedulers invoke it once per
# server round, and a handful of eager scalar ops per round used to cost
# as much as the update itself.  beta/staleness/damping stay traced, so
# one compile serves the entire run.  The jits are built lazily (cached)
# so importing repro.core never initializes the JAX backend.

@functools.lru_cache(maxsize=None)
def _apply_update_jit():
    @functools.partial(jax.jit, donate_argnums=donate_argnums(0))
    def apply(state, delta, beta, staleness, damping):
        staleness = jnp.asarray(staleness, jnp.int32)
        scale = jnp.asarray(beta, jnp.float32) \
            * (1.0 + staleness.astype(jnp.float32)) ** (-damping)
        return ServerState(
            params=apply_delta_tree(state.params, delta, scale),
            t=state.t + 1,
            staleness_sum=state.staleness_sum
            + staleness.astype(jnp.float32),
            staleness_max=jnp.maximum(state.staleness_max, staleness),
        )
    return apply


def apply_update(state: ServerState, delta, beta: float, staleness,
                 damping: float = 0.0) -> ServerState:
    """Paper-faithful single-delta apply (Algorithm 1 step 4).

    ``damping`` > 0 enables the FedAsync-style polynomial staleness
    discount s(τ) = (1+τ)^(-damping) on the server stepsize (beyond-paper;
    0 keeps the theorems' constant β).
    """
    return _apply_update_jit()(state, delta, beta, staleness,
                               jnp.float32(damping))


@functools.lru_cache(maxsize=None)
def _apply_buffered_jit():
    @functools.partial(jax.jit, donate_argnums=donate_argnums(0))
    def apply(state, delta_sum, count, beta, staleness_max, staleness_sum):
        count = jnp.asarray(count)
        scale = beta / jnp.maximum(count.astype(jnp.float32), 1.0)
        return ServerState(
            params=apply_delta_tree(state.params, delta_sum, scale),
            t=state.t + count.astype(jnp.int32),
            staleness_sum=state.staleness_sum
            + jnp.asarray(staleness_sum, jnp.float32),
            staleness_max=jnp.maximum(state.staleness_max,
                                      jnp.asarray(staleness_max,
                                                  jnp.int32)),
        )
    return apply


def apply_buffered(state: ServerState, delta_sum, count, beta: float,
                   staleness_max, staleness_sum=0.0) -> ServerState:
    """FedBuff-style buffered apply: w ← w − β/M Σ Δ (one server round).

    ``delta_sum`` is typically the result of a psum over the cohort mesh
    axes; ``count`` the number of contributing clients M.  ``staleness_sum``
    is the Σ τ over the buffer's M contributing deltas — the version counter
    advances by M per flush, so omitting it under-reports ``mean_staleness``
    in :func:`staleness_stats` (each buffered delta is one applied update of
    Assumption 1's bookkeeping).
    """
    return _apply_buffered_jit()(state, delta_sum, count, beta,
                                 staleness_max, staleness_sum)


@functools.lru_cache(maxsize=None)
def _apply_rows_state_jit(donate: bool):
    # one body serves both stacked-apply overloads; only donation differs
    # (the serving ring must keep the pre-apply params alive as a window
    # snapshot, the simulators need not)
    @functools.partial(jax.jit, static_argnames=("mode",),
                       donate_argnums=donate_argnums(0) if donate else ())
    def apply(state, delta_stack, weights, order, count, staleness_max,
              staleness_sum, mode: str = "auto"):
        params = state.params
        if (jax.tree_util.tree_structure(delta_stack)
                == jax.tree_util.tree_structure(params)):
            # full-model stack: the original path, bit-for-bit
            new_params = apply_rows_tree(params, delta_stack, weights,
                                         mode=mode, order=order)
        else:
            # personal_subset stack (pruned structure, core.subset): apply
            # only the subset leaves and pass the backbone through
            # untouched.  The structure comparison is a trace-time Python
            # branch — jit already caches per treedef, so no static args.
            new_sub = apply_rows_tree(subset_like(params, delta_stack),
                                      delta_stack, weights, mode=mode,
                                      order=order)
            new_params = merge_subset(params, new_sub)
        return ServerState(
            params=new_params,
            t=state.t + jnp.asarray(count, jnp.int32),
            staleness_sum=state.staleness_sum
            + jnp.asarray(staleness_sum, jnp.float32),
            staleness_max=jnp.maximum(state.staleness_max,
                                      jnp.asarray(staleness_max,
                                                  jnp.int32)),
        )
    return apply


@functools.lru_cache(maxsize=None)
def _apply_rows_q_state_jit(donate: bool):
    # quantized twin of _apply_rows_state_jit: the stack arrives as a
    # QuantStack (int8 rows + per-row-per-leaf f32 scales) and the apply
    # routes through the fused dequant×weight×accumulate kernel — an fp32
    # copy of the bank never exists, not even transiently inside the jit
    @functools.partial(jax.jit, static_argnames=("mode",),
                       donate_argnums=donate_argnums(0) if donate else ())
    def apply(state, q_stack, weights, order, count, staleness_max,
              staleness_sum, mode: str = "auto"):
        params = state.params
        if (jax.tree_util.tree_structure(q_stack.q)
                == jax.tree_util.tree_structure(params)):
            new_params = apply_rows_q_tree(params, q_stack.q,
                                           q_stack.scales, weights,
                                           mode=mode, order=order)
        else:
            # personal_subset stack: apply the subset leaves only, pass
            # the backbone through untouched (same trace-time branch as
            # the fp32 overload)
            new_sub = apply_rows_q_tree(subset_like(params, q_stack.q),
                                        q_stack.q, q_stack.scales,
                                        weights, mode=mode, order=order)
            new_params = merge_subset(params, new_sub)
        return ServerState(
            params=new_params,
            t=state.t + jnp.asarray(count, jnp.int32),
            staleness_sum=state.staleness_sum
            + jnp.asarray(staleness_sum, jnp.float32),
            staleness_max=jnp.maximum(state.staleness_max,
                                      jnp.asarray(staleness_max,
                                                  jnp.int32)),
        )
    return apply


@functools.lru_cache(maxsize=None)
def _row_norms_jit():
    @jax.jit
    def norms(stack):
        tot = None
        for leaf in jax.tree_util.tree_leaves(stack):
            s = jnp.sum(jnp.square(leaf.astype(jnp.float32))
                        .reshape(leaf.shape[0], -1), axis=1)
            tot = s if tot is None else tot + s
        return jnp.sqrt(tot)
    return norms


@functools.lru_cache(maxsize=None)
def _row_norms_q_jit():
    @jax.jit
    def norms(q_tree, scales_tree):
        tot = None
        for q, sc in zip(jax.tree_util.tree_leaves(q_tree),
                         jax.tree_util.tree_leaves(scales_tree)):
            s = jnp.square(sc.astype(jnp.float32)) \
                * jnp.sum(jnp.square(q.astype(jnp.float32))
                          .reshape(q.shape[0], -1), axis=1)
            tot = s if tot is None else tot + s
        return jnp.sqrt(tot)
    return norms


def bank_row_norms(delta_stack) -> np.ndarray:
    """Per-row L2 norms of a stacked bank, computed ON DEVICE.

    One fused reduction over the whole stack per call; the only host
    transfer is the ``[capacity]`` f32 norm vector — never a delta row
    (the robust-admission path preserves ``host_materializations == 0``).
    QuantStacks reduce in the quantized domain (per-leaf
    ``|scale| · ‖q‖₂``, exact for the symmetric codec) without ever
    materializing an fp32 row.  Rows holding NaN/Inf report non-finite
    norms, which is how :func:`robust_admission_weights` detects poisoned
    deltas.
    """
    if isinstance(delta_stack, QuantStack):
        return np.asarray(_row_norms_q_jit()(delta_stack.q,
                                             delta_stack.scales))
    return np.asarray(_row_norms_jit()(delta_stack))


@functools.lru_cache(maxsize=None)
def _mask_rows_jit():
    @jax.jit
    def mask(stack, keep):
        def one(x):
            k = keep.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.where(k, x, jnp.zeros((), x.dtype))
        return jax.tree_util.tree_map(one, stack)
    return mask


def mask_rows(delta_stack, keep):
    """Zero out the rows of a stacked bank where ``keep`` is False.

    Weight-zeroing alone cannot neutralize a poisoned row: the fused
    apply computes ``Σ w_j · Δ_j`` and ``0 · NaN = NaN``, so a NaN row
    survives any weight vector.  This ``where``-based mask rewrites the
    row storage itself (on device, one pass) and is applied before
    :func:`apply_admitted_rows` whenever :func:`robust_admission_weights`
    reports non-finite rows.  QuantStacks mask codes and scales alike.
    """
    keep = jnp.asarray(keep, bool)
    if isinstance(delta_stack, QuantStack):
        return QuantStack(q=_mask_rows_jit()(delta_stack.q, keep),
                          scales=_mask_rows_jit()(delta_stack.scales,
                                                  keep))
    return _mask_rows_jit()(delta_stack, keep)


@functools.lru_cache(maxsize=None)
def _scale_rows_jit():
    @jax.jit
    def scale(stack, factors):
        def one(x):
            f = factors.reshape((-1,) + (1,) * (x.ndim - 1))
            return (x.astype(jnp.float32) * f).astype(x.dtype)
        return jax.tree_util.tree_map(one, stack)
    return scale


def scale_rows(delta_stack, factors):
    """Per-row scaling of a stacked bank (one on-device pass).

    The adversarial-corruption injection point of the scenario engine
    (:mod:`repro.fl.scenario`): a ``[capacity]`` f32 factor vector (1.0
    honest, ±magnitude scaled/sign-flipped, NaN poisoned) multiplies each
    row in place of per-row host traffic.  QuantStacks scale their f32
    scale vectors only — int8 codes are untouched, so corruption
    round-trips the codec exactly like any other amplitude change.
    """
    factors = jnp.asarray(factors, jnp.float32)
    if isinstance(delta_stack, QuantStack):
        return QuantStack(q=delta_stack.q,
                          scales=_scale_rows_jit()(delta_stack.scales,
                                                   factors))
    return _scale_rows_jit()(delta_stack, factors)


def admission_weights(capacity: int, rows: List[Tuple[int, int]], *,
                      beta: float, count: int, damping: float = 0.0,
                      tau_max: Optional[int] = None) -> np.ndarray:
    """``[capacity]`` f32 row-weight vector for a stacked-bank server apply.

    ``rows`` is ``[(row_index, staleness τ), ...]``; every listed row gets
    ``β/count · (1+τ)^(-damping)`` and every other slot (bucket padding,
    unadmitted rows) gets 0.  With ``tau_max`` set, rows staler than the
    bound are zeroed — the bounded-staleness admission rule (Assumption 1's
    τ ≤ τ_max): a straggler delta is *re-weighted into a later window's
    apply* instead of corrupting it, and dropped only past the bound.
    Shared by the buffered scheduler (no bound: the simulator's event order
    can't exceed it) and the serving ring (bound enforced per window).
    """
    w = np.zeros(capacity, np.float32)
    for idx, tau in rows:
        if tau_max is not None and tau > tau_max:
            continue
        wt = beta / count
        if damping:
            wt *= (1.0 + tau) ** (-damping)
        # accumulate, don't overwrite: a row admitted twice in one window
        # (user_cap >= 2, transport re-submits) contributes twice while the
        # version counter t advances per admission — `w[idx] = wt` silently
        # under-applied the duplicate and skewed mean_staleness
        w[idx] += wt
    return w


def robust_admission_weights(
        capacity: int, rows: List[Tuple[int, int]], norms, *, beta: float,
        count: int, damping: float = 0.0, tau_max: Optional[int] = None,
        method: str = "clip", clip_norm: Optional[float] = None,
        trim_frac: float = 0.1) -> Tuple[np.ndarray, np.ndarray, Dict]:
    """Byzantine-robust variants of :func:`admission_weights`.

    ``norms`` is the ``[capacity]`` per-row L2 norm vector from
    :func:`bank_row_norms` (the only statistic the defense needs — delta
    rows never cross to the host).  Two methods:

      * ``"clip"`` — norm clipping: an admission whose row norm exceeds
        ``clip_norm`` keeps its direction but is scaled down by
        ``clip_norm / norm``; with ``clip_norm=None`` the bound is
        2 × median of the finite admitted norms (self-calibrating — an
        honest-majority buffer sets the scale, adversarially inflated
        rows can't move a median).  Base weight is β/count, like the
        plain path.
      * ``"trim"`` — norm-based trimmed mean: admissions are sorted by
        row norm and ``ceil(trim_frac · k)`` are discarded from EACH
        tail (sign-flipped or inflated rows live in the tails); the
        survivors split β evenly (β/|survivors| — ``count`` is ignored),
        so the flush stays a mean over what it kept.  At least one
        admission always survives.

    Both methods drop admissions on non-finite rows (NaN/Inf) outright.
    Staleness handling matches the plain path: rows past ``tau_max`` are
    zeroed, ``damping`` applies ``(1+τ)^-a`` per admission.

    Returns ``(weights, keep, info)``: the ``[capacity]`` f32 weight
    vector; a ``[capacity]`` bool row mask that is False on non-finite
    rows — the caller MUST route the stack through :func:`mask_rows`
    when ``keep`` isn't all-True, because ``0 · NaN = NaN`` means a
    zero weight alone cannot neutralize a poisoned row; and an ``info``
    dict (``clipped`` / ``trimmed`` / ``nonfinite`` admission counts and
    the effective ``clip_norm``) for the schedulers' stats surface.
    """
    if method not in ("clip", "trim"):
        raise ValueError(f"robust method must be 'clip' or 'trim', "
                         f"got {method!r}")
    norms = np.asarray(norms, np.float64)
    keep = np.isfinite(norms)
    admissible = [(idx, tau) for idx, tau in rows
                  if tau_max is None or tau <= tau_max]
    finite = [(idx, tau) for idx, tau in admissible if keep[idx]]
    info = {"clipped": 0, "trimmed": 0,
            "nonfinite": len(admissible) - len(finite), "clip_norm": 0.0}
    w = np.zeros(capacity, np.float32)
    if not finite:
        return w, keep, info
    a_norms = np.array([norms[idx] for idx, _ in finite])
    if method == "clip":
        c = float(clip_norm) if clip_norm is not None \
            else 2.0 * float(np.median(a_norms))
        info["clip_norm"] = c
        for (idx, tau), nrm in zip(finite, a_norms):
            wt = beta / count
            if damping:
                wt *= (1.0 + tau) ** (-damping)
            if nrm > c and nrm > 0.0:
                wt *= c / nrm
                info["clipped"] += 1
            w[idx] += wt
    else:
        k = len(finite)
        cut = int(np.ceil(trim_frac * k))
        if 2 * cut >= k:
            cut = (k - 1) // 2
        order = np.argsort(a_norms, kind="stable")
        survivors = order[cut: k - cut]
        info["trimmed"] = k - len(survivors)
        for j in survivors:
            idx, tau = finite[j]
            wt = beta / len(survivors)
            if damping:
                wt *= (1.0 + tau) ** (-damping)
            w[idx] += wt
    return w, keep, info


def robust_flush_weights(
        groups, *, beta: float, count: int, damping: float = 0.0,
        tau_max: Optional[int] = None, method: str = "clip",
        clip_norm: Optional[float] = None,
        trim_frac: float = 0.1) -> Tuple[Dict, Dict]:
    """:func:`robust_admission_weights` for ONE flush spanning several
    banks.

    The flush — not the bank — is the statistical population.  A buffered
    scheduler's M admissions (and a serving window's) split across banks:
    in-flight clients were computed in an earlier window's bank, so a
    group can hold just 1–2 rows — and a 1-row group cannot see that its
    own row is the outlier (the median of a single corrupted norm IS
    that norm, so self-calibrating clip never fires; a 2-row group
    clamps trim's cut to zero).  Calibrating per group let most
    adversarial rows through; calibrating here, over all of the flush's
    admissions, restores the honest-majority assumption the defenses
    rest on.

    ``groups`` maps a bank key to ``(bank, rows)`` where ``bank`` has
    ``.stacked`` / ``.capacity`` and ``rows`` is the ``(idx, tau)``
    admission list (the grouping both callers already build).  Clip
    computes ONE bound — ``clip_norm`` or 2 × median of the flush's
    finite admitted norms — and delegates per bank with that explicit
    bound; trim ranks the flush's admissions globally, cuts
    ``ceil(trim_frac · k)`` from each tail, and splits β over the global
    survivor set.  Per-row math (β/count base weight for clip,
    ``(1+τ)^-damping``, ``tau_max`` zeroing, non-finite drops) matches
    the per-bank function exactly.

    Returns ``({key: (weights, keep)}, info)`` — per-bank weight vectors
    and non-finite row masks under the same mask-don't-zero contract
    (route the stack through :func:`mask_rows` when ``keep`` isn't
    all-True), plus one aggregated ``info`` dict.
    """
    if method not in ("clip", "trim"):
        raise ValueError(f"robust method must be 'clip' or 'trim', "
                         f"got {method!r}")
    norms_by = {key: np.asarray(bank_row_norms(bank.stacked), np.float64)
                for key, (bank, _) in groups.items()}
    info = {"clipped": 0, "trimmed": 0, "nonfinite": 0, "clip_norm": 0.0}
    out = {}
    if method == "clip":
        admitted = np.array([norms_by[key][idx]
                             for key, (_, rows) in groups.items()
                             for idx, tau in rows
                             if tau_max is None or tau <= tau_max])
        finite = admitted[np.isfinite(admitted)]
        c = float(clip_norm) if clip_norm is not None \
            else (2.0 * float(np.median(finite)) if finite.size else 0.0)
        info["clip_norm"] = c
        for key, (bank, rows) in groups.items():
            w, keep, gi = robust_admission_weights(
                bank.capacity, rows, norms_by[key], beta=beta,
                count=count, damping=damping, tau_max=tau_max,
                method="clip", clip_norm=c)
            for stat in ("clipped", "trimmed", "nonfinite"):
                info[stat] += gi[stat]
            out[key] = (w, keep)
        return out, info
    entries = [(key, idx, tau, norms_by[key][idx])
               for key, (_, rows) in groups.items()
               for idx, tau in rows
               if tau_max is None or tau <= tau_max]
    finite_e = [e for e in entries if np.isfinite(e[3])]
    info["nonfinite"] = len(entries) - len(finite_e)
    survivors = []
    if finite_e:
        k = len(finite_e)
        cut = int(np.ceil(trim_frac * k))
        if 2 * cut >= k:
            cut = (k - 1) // 2
        order = np.argsort([e[3] for e in finite_e], kind="stable")
        survivors = [finite_e[j] for j in order[cut: k - cut]]
        info["trimmed"] = k - len(survivors)
    w_by = {key: np.zeros(bank.capacity, np.float32)
            for key, (bank, _) in groups.items()}
    for key, idx, tau, _ in survivors:
        wt = beta / len(survivors)
        if damping:
            wt *= (1.0 + tau) ** (-damping)
        w_by[key][idx] += wt
    return {key: (w_by[key], np.isfinite(norms_by[key]))
            for key in groups}, info


def _row_order(delta_stack, order) -> jnp.ndarray:
    """Resolve a flush's row-accumulation order to a traced int32 vector
    (identity when the caller has no admission order to impose)."""
    if order is None:
        if isinstance(delta_stack, QuantStack):
            delta_stack = delta_stack.q
        cap = jax.tree_util.tree_leaves(delta_stack)[0].shape[0]
        order = np.arange(cap, dtype=np.int32)
    return jnp.asarray(order, jnp.int32)


def apply_buffered_rows(state: ServerState, delta_stack, weights, count,
                        staleness_max, staleness_sum=0.0,
                        order=None) -> ServerState:
    """Stacked-buffer overload of :func:`apply_buffered`.

    ``delta_stack`` is a DeltaBank buffer — a params-shaped pytree whose
    leaves carry a leading ``[M]`` cohort axis and never left the device;
    ``weights`` the ``[M]`` f32 row-weight vector folding β/M, per-delta
    FedAsync staleness damping ``(1+τ_j)^{-a}`` and padding masks.  The
    whole flush is one fused read-modify-write pass per leaf
    (``apply_rows``) instead of M host-side ``tree.map``s; ``count`` is the
    number of *non-zero-weight* rows, which the version counter advances
    by.  Weights stay traced, so one compile per bucket size serves every
    staleness/damping composition.  The Pallas-vs-oracle dispatch is
    resolved HERE, on the concrete stack — a device-spanning buffer (the
    shard_map banks, 1-D or 2-D mesh alike) must take the sequential
    oracle path (``mode="seq"``: a mesh-invariant row-accumulation order,
    optionally the caller's ``order``), and inside the jit the leaves are
    tracers that can't reveal their sharding.
    """
    mode = "seq" if spans_devices(delta_stack) else "auto"
    return _apply_rows_state_jit(True)(state, delta_stack,
                                       jnp.asarray(weights, jnp.float32),
                                       _row_order(delta_stack, order),
                                       count, staleness_max, staleness_sum,
                                       mode=mode)


def apply_admitted_rows(state: ServerState, delta_stack, weights, count,
                        staleness_max, staleness_sum=0.0,
                        order=None) -> ServerState:
    """Serving-window overload of :func:`apply_buffered_rows`.

    Same fused stacked apply, but the incoming state is NOT donated: the
    caller (``repro.serving.bank.DeltaRing``) retains the pre-apply params
    as the closed window's snapshot, which straggler rows admitted into a
    *later* window are computed against (τ ≤ τ_max) — donating the old
    buffer (in-place on TPU) would invalidate exactly those snapshots.
    ``weights`` normally comes from :func:`admission_weights`.

    ``delta_stack`` may also be a *personal-subset* stack (the pruned
    structure of ``repro.core.subset``): only the subset leaves are
    rewritten and the shared backbone passes through bit-identically.

    With int8 delta banking the stack arrives as a
    :class:`repro.core.quant.QuantStack` and the apply dispatches to the
    fused dequant×weight×accumulate kernel (``apply_rows_q``) — straggler
    re-admission never materializes fp32 rows.

    ``order`` (from the serving ring) is the window's admission order — a
    mesh-independent total order on the rows.  On device-spanning stacks
    the apply accumulates rows sequentially in that order, so the
    post-advance params are bit-identical between the 1-D ``("cohort",)``
    and 2-D ``("cohort", "model")`` layouts even though the two meshes
    place the same users at different bank rows.
    """
    mode = "seq" if spans_devices(delta_stack) else "auto"
    ordv = _row_order(delta_stack, order)
    if isinstance(delta_stack, QuantStack):
        return _apply_rows_q_state_jit(False)(
            state, delta_stack, jnp.asarray(weights, jnp.float32),
            ordv, count, staleness_max, staleness_sum, mode=mode)
    return _apply_rows_state_jit(False)(state, delta_stack,
                                        jnp.asarray(weights, jnp.float32),
                                        ordv, count, staleness_max,
                                        staleness_sum, mode=mode)


def staleness_stats(state: ServerState) -> Dict:
    t = jnp.maximum(state.t.astype(jnp.float32), 1.0)
    return {"mean_staleness": state.staleness_sum / t,
            "max_staleness": state.staleness_max,
            "server_rounds": state.t}
