"""Algorithm 1 — asynchronous server.

Paper-faithful mode: on receiving Δ from any client, immediately
``w ← w − β Δ`` and bump the version counter t.  Staleness bookkeeping
(Assumption 1) tracks τ = t − Ω(t) per applied update.

Beyond-paper (FedBuff [51]; unbounded-gradient analysis [63]): a buffered
variant aggregates M deltas then applies their mean once — on the TPU mesh
this is one psum over the cohort axes per round (DESIGN.md §2/§5).  The
event-driven counterpart is :class:`repro.fl.simulator.BufferedAsyncSimulator`,
which feeds :func:`apply_buffered` one (Σ Δ, M, Σ τ, max τ) tuple per flush.

Every apply routes through ``kernels/fused_update.apply_delta_tree`` — a
single read-modify-write pass per leaf with a *traced* scale, so one compile
serves every staleness value, buffer count, and the optional FedAsync-style
polynomial staleness damping β/(1+τ)^a (``PersAFLConfig.staleness_damping``).

Server state is the typed :class:`repro.core.types.ServerState` pytree
(params, t, Σ τ, max τ) — every apply takes one and returns one; the raw
dict spelling survives only as ``state["..."]`` read compatibility.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import PersAFLConfig, ServerState
from repro.core.quant import QuantStack
from repro.core.subset import merge_subset, subset_like
from repro.kernels.fused_update.ops import (apply_delta_tree,
                                            apply_rows_q_tree,
                                            apply_rows_tree, donate_argnums,
                                            spans_devices)


def init_server_state(params) -> ServerState:
    return ServerState(
        params=params,
        t=jnp.zeros((), jnp.int32),
        staleness_sum=jnp.zeros((), jnp.float32),
        staleness_max=jnp.zeros((), jnp.int32),
    )


# the whole apply — fused param update AND the counter/staleness
# bookkeeping — is one jitted call: the schedulers invoke it once per
# server round, and a handful of eager scalar ops per round used to cost
# as much as the update itself.  beta/staleness/damping stay traced, so
# one compile serves the entire run.  The jits are built lazily (cached)
# so importing repro.core never initializes the JAX backend.

@functools.lru_cache(maxsize=None)
def _apply_update_jit():
    @functools.partial(jax.jit, donate_argnums=donate_argnums(0))
    def apply(state, delta, beta, staleness, damping):
        staleness = jnp.asarray(staleness, jnp.int32)
        scale = jnp.asarray(beta, jnp.float32) \
            * (1.0 + staleness.astype(jnp.float32)) ** (-damping)
        return ServerState(
            params=apply_delta_tree(state.params, delta, scale),
            t=state.t + 1,
            staleness_sum=state.staleness_sum
            + staleness.astype(jnp.float32),
            staleness_max=jnp.maximum(state.staleness_max, staleness),
        )
    return apply


def apply_update(state: ServerState, delta, beta: float, staleness,
                 damping: float = 0.0) -> ServerState:
    """Paper-faithful single-delta apply (Algorithm 1 step 4).

    ``damping`` > 0 enables the FedAsync-style polynomial staleness
    discount s(τ) = (1+τ)^(-damping) on the server stepsize (beyond-paper;
    0 keeps the theorems' constant β).
    """
    return _apply_update_jit()(state, delta, beta, staleness,
                               jnp.float32(damping))


@functools.lru_cache(maxsize=None)
def _apply_buffered_jit():
    @functools.partial(jax.jit, donate_argnums=donate_argnums(0))
    def apply(state, delta_sum, count, beta, staleness_max, staleness_sum):
        count = jnp.asarray(count)
        scale = beta / jnp.maximum(count.astype(jnp.float32), 1.0)
        return ServerState(
            params=apply_delta_tree(state.params, delta_sum, scale),
            t=state.t + count.astype(jnp.int32),
            staleness_sum=state.staleness_sum
            + jnp.asarray(staleness_sum, jnp.float32),
            staleness_max=jnp.maximum(state.staleness_max,
                                      jnp.asarray(staleness_max,
                                                  jnp.int32)),
        )
    return apply


def apply_buffered(state: ServerState, delta_sum, count, beta: float,
                   staleness_max, staleness_sum=0.0) -> ServerState:
    """FedBuff-style buffered apply: w ← w − β/M Σ Δ (one server round).

    ``delta_sum`` is typically the result of a psum over the cohort mesh
    axes; ``count`` the number of contributing clients M.  ``staleness_sum``
    is the Σ τ over the buffer's M contributing deltas — the version counter
    advances by M per flush, so omitting it under-reports ``mean_staleness``
    in :func:`staleness_stats` (each buffered delta is one applied update of
    Assumption 1's bookkeeping).
    """
    return _apply_buffered_jit()(state, delta_sum, count, beta,
                                 staleness_max, staleness_sum)


@functools.lru_cache(maxsize=None)
def _apply_rows_state_jit(donate: bool):
    # one body serves both stacked-apply overloads; only donation differs
    # (the serving ring must keep the pre-apply params alive as a window
    # snapshot, the simulators need not)
    @functools.partial(jax.jit, static_argnames=("mode",),
                       donate_argnums=donate_argnums(0) if donate else ())
    def apply(state, delta_stack, weights, count, staleness_max,
              staleness_sum, mode: str = "auto"):
        params = state.params
        if (jax.tree_util.tree_structure(delta_stack)
                == jax.tree_util.tree_structure(params)):
            # full-model stack: the original path, bit-for-bit
            new_params = apply_rows_tree(params, delta_stack, weights,
                                         mode=mode)
        else:
            # personal_subset stack (pruned structure, core.subset): apply
            # only the subset leaves and pass the backbone through
            # untouched.  The structure comparison is a trace-time Python
            # branch — jit already caches per treedef, so no static args.
            new_sub = apply_rows_tree(subset_like(params, delta_stack),
                                      delta_stack, weights, mode=mode)
            new_params = merge_subset(params, new_sub)
        return ServerState(
            params=new_params,
            t=state.t + jnp.asarray(count, jnp.int32),
            staleness_sum=state.staleness_sum
            + jnp.asarray(staleness_sum, jnp.float32),
            staleness_max=jnp.maximum(state.staleness_max,
                                      jnp.asarray(staleness_max,
                                                  jnp.int32)),
        )
    return apply


@functools.lru_cache(maxsize=None)
def _apply_rows_q_state_jit(donate: bool):
    # quantized twin of _apply_rows_state_jit: the stack arrives as a
    # QuantStack (int8 rows + per-row-per-leaf f32 scales) and the apply
    # routes through the fused dequant×weight×accumulate kernel — an fp32
    # copy of the bank never exists, not even transiently inside the jit
    @functools.partial(jax.jit, static_argnames=("mode",),
                       donate_argnums=donate_argnums(0) if donate else ())
    def apply(state, q_stack, weights, count, staleness_max,
              staleness_sum, mode: str = "auto"):
        params = state.params
        if (jax.tree_util.tree_structure(q_stack.q)
                == jax.tree_util.tree_structure(params)):
            new_params = apply_rows_q_tree(params, q_stack.q,
                                           q_stack.scales, weights,
                                           mode=mode)
        else:
            # personal_subset stack: apply the subset leaves only, pass
            # the backbone through untouched (same trace-time branch as
            # the fp32 overload)
            new_sub = apply_rows_q_tree(subset_like(params, q_stack.q),
                                        q_stack.q, q_stack.scales,
                                        weights, mode=mode)
            new_params = merge_subset(params, new_sub)
        return ServerState(
            params=new_params,
            t=state.t + jnp.asarray(count, jnp.int32),
            staleness_sum=state.staleness_sum
            + jnp.asarray(staleness_sum, jnp.float32),
            staleness_max=jnp.maximum(state.staleness_max,
                                      jnp.asarray(staleness_max,
                                                  jnp.int32)),
        )
    return apply


def admission_weights(capacity: int, rows: List[Tuple[int, int]], *,
                      beta: float, count: int, damping: float = 0.0,
                      tau_max: Optional[int] = None) -> np.ndarray:
    """``[capacity]`` f32 row-weight vector for a stacked-bank server apply.

    ``rows`` is ``[(row_index, staleness τ), ...]``; every listed row gets
    ``β/count · (1+τ)^(-damping)`` and every other slot (bucket padding,
    unadmitted rows) gets 0.  With ``tau_max`` set, rows staler than the
    bound are zeroed — the bounded-staleness admission rule (Assumption 1's
    τ ≤ τ_max): a straggler delta is *re-weighted into a later window's
    apply* instead of corrupting it, and dropped only past the bound.
    Shared by the buffered scheduler (no bound: the simulator's event order
    can't exceed it) and the serving ring (bound enforced per window).
    """
    w = np.zeros(capacity, np.float32)
    for idx, tau in rows:
        if tau_max is not None and tau > tau_max:
            continue
        wt = beta / count
        if damping:
            wt *= (1.0 + tau) ** (-damping)
        # accumulate, don't overwrite: a row admitted twice in one window
        # (user_cap >= 2, transport re-submits) contributes twice while the
        # version counter t advances per admission — `w[idx] = wt` silently
        # under-applied the duplicate and skewed mean_staleness
        w[idx] += wt
    return w


def apply_buffered_rows(state: ServerState, delta_stack, weights, count,
                        staleness_max, staleness_sum=0.0) -> ServerState:
    """Stacked-buffer overload of :func:`apply_buffered`.

    ``delta_stack`` is a DeltaBank buffer — a params-shaped pytree whose
    leaves carry a leading ``[M]`` cohort axis and never left the device;
    ``weights`` the ``[M]`` f32 row-weight vector folding β/M, per-delta
    FedAsync staleness damping ``(1+τ_j)^{-a}`` and padding masks.  The
    whole flush is one fused read-modify-write pass per leaf
    (``apply_rows``) instead of M host-side ``tree.map``s; ``count`` is the
    number of *non-zero-weight* rows, which the version counter advances
    by.  Weights stay traced, so one compile per bucket size serves every
    staleness/damping composition.  The Pallas-vs-oracle dispatch is
    resolved HERE, on the concrete stack — a cohort-sharded buffer must
    take the oracle path (per-shard partial sums + one psum), and inside
    the jit the leaves are tracers that can't reveal their sharding.
    """
    mode = "ref" if spans_devices(delta_stack) else "auto"
    return _apply_rows_state_jit(True)(state, delta_stack,
                                       jnp.asarray(weights, jnp.float32),
                                       count, staleness_max, staleness_sum,
                                       mode=mode)


def apply_admitted_rows(state: ServerState, delta_stack, weights, count,
                        staleness_max, staleness_sum=0.0) -> ServerState:
    """Serving-window overload of :func:`apply_buffered_rows`.

    Same fused stacked apply, but the incoming state is NOT donated: the
    caller (``repro.serving.bank.DeltaRing``) retains the pre-apply params
    as the closed window's snapshot, which straggler rows admitted into a
    *later* window are computed against (τ ≤ τ_max) — donating the old
    buffer (in-place on TPU) would invalidate exactly those snapshots.
    ``weights`` normally comes from :func:`admission_weights`.

    ``delta_stack`` may also be a *personal-subset* stack (the pruned
    structure of ``repro.core.subset``): only the subset leaves are
    rewritten and the shared backbone passes through bit-identically.

    With int8 delta banking the stack arrives as a
    :class:`repro.core.quant.QuantStack` and the apply dispatches to the
    fused dequant×weight×accumulate kernel (``apply_rows_q``) — straggler
    re-admission never materializes fp32 rows.
    """
    mode = "ref" if spans_devices(delta_stack) else "auto"
    if isinstance(delta_stack, QuantStack):
        return _apply_rows_q_state_jit(False)(
            state, delta_stack, jnp.asarray(weights, jnp.float32),
            count, staleness_max, staleness_sum, mode=mode)
    return _apply_rows_state_jit(False)(state, delta_stack,
                                        jnp.asarray(weights, jnp.float32),
                                        count, staleness_max, staleness_sum,
                                        mode=mode)


def staleness_stats(state: ServerState) -> Dict:
    t = jnp.maximum(state.t.astype(jnp.float32), 1.0)
    return {"mean_staleness": state.staleness_sum / t,
            "max_staleness": state.staleness_max,
            "server_rounds": state.t}
