"""Algorithm 1 — asynchronous server.

Paper-faithful mode: on receiving Δ from any client, immediately
``w ← w − β Δ`` and bump the version counter t.  Staleness bookkeeping
(Assumption 1) tracks τ = t − Ω(t) per applied update.

Beyond-paper (FedBuff [51]; unbounded-gradient analysis [63]): a buffered
variant aggregates M deltas then applies their mean once — on the TPU mesh
this is one psum over the cohort axes per round (DESIGN.md §2/§5).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import PersAFLConfig


def init_server_state(params) -> Dict:
    return {
        "params": params,
        "t": jnp.zeros((), jnp.int32),
        "staleness_sum": jnp.zeros((), jnp.float32),
        "staleness_max": jnp.zeros((), jnp.int32),
    }


def apply_update(state: Dict, delta, beta: float, staleness) -> Dict:
    """Paper-faithful single-delta apply (Algorithm 1 step 4)."""
    staleness = jnp.asarray(staleness, jnp.int32)
    params = jax.tree.map(
        lambda w, d: (w.astype(jnp.float32) - beta * d.astype(jnp.float32))
        .astype(w.dtype), state["params"], delta)
    return {
        "params": params,
        "t": state["t"] + 1,
        "staleness_sum": state["staleness_sum"] + staleness.astype(jnp.float32),
        "staleness_max": jnp.maximum(state["staleness_max"], staleness),
    }


def apply_buffered(state: Dict, delta_sum, count, beta: float,
                   staleness_max) -> Dict:
    """FedBuff-style buffered apply: w ← w − β/M Σ Δ (one server round).

    ``delta_sum`` is typically the result of a psum over the cohort mesh
    axes; ``count`` the number of contributing clients M.
    """
    scale = beta / jnp.maximum(count.astype(jnp.float32), 1.0)
    params = jax.tree.map(
        lambda w, d: (w.astype(jnp.float32) - scale * d.astype(jnp.float32))
        .astype(w.dtype), state["params"], delta_sum)
    return {
        "params": params,
        "t": state["t"] + count.astype(jnp.int32),
        "staleness_sum": state["staleness_sum"],
        "staleness_max": jnp.maximum(state["staleness_max"],
                                     jnp.asarray(staleness_max, jnp.int32)),
    }


def staleness_stats(state: Dict) -> Dict:
    t = jnp.maximum(state["t"].astype(jnp.float32), 1.0)
    return {"mean_staleness": state["staleness_sum"] / t,
            "max_staleness": state["staleness_max"],
            "server_rounds": state["t"]}
