"""Option C — Moreau-envelope personalization (paper Eq. 6–8, 10).

F_i(w) = min_θ [ f_i(θ) + λ/2 ‖θ − w‖² ]         (Moreau envelope)
∇F_i(w) = λ (w − θ̂_i(w))                          (Eq. 7, Appendix C)

θ̂ is approximated by θ̃: K steps of SGD on the λ-regularized stochastic
loss h̃ (Algorithm 2 step 11), giving the paper's inexactness level
ν = ‖∇h̃(θ̃)‖ which we *measure and return* (the theory consumes it via
Lemma 6).  For λ > L the inner problem is (λ−L)-strongly convex, so K =
O(log 1/ν) steps suffice (paper §3).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.maml import _axpy, tree_norm

Loss = Callable


def prox_inner_grad(loss_fn: Loss, theta, w, batch, lam: float):
    """∇_θ h̃(θ, w; D) = ∇f̃(θ; D) + λ(θ − w)."""
    g = jax.grad(loss_fn)(theta, batch)
    return jax.tree.map(lambda gg, th, ww: gg + lam * (th - ww).astype(gg.dtype),
                        g, theta, w)


def solve_prox(loss_fn: Loss, w, batch, lam: float, inner_eta: float,
               inner_steps: int) -> Tuple:
    """Inexactly minimize h̃(θ, w; D) from θ₀ = w.

    Returns (θ̃, ν_achieved) where ν = ‖∇h̃(θ̃)‖ (paper Algorithm 2 step 11).
    """
    def step(theta, _):
        g = prox_inner_grad(loss_fn, theta, w, batch, lam)
        return _axpy(-inner_eta, g, theta), None

    theta, _ = jax.lax.scan(step, w, None, length=inner_steps)
    nu = tree_norm(prox_inner_grad(loss_fn, theta, w, batch, lam))
    return theta, nu


def me_grad(loss_fn: Loss, params, batch, lam: float, inner_eta: float,
            inner_steps: int):
    """Stochastic ME gradient ∇F̃_i(w; D) = λ(w − θ̃(w))  (Eq. 10).

    Returns (grad pytree, ν achieved).
    """
    theta, nu = solve_prox(loss_fn, params, batch, lam, inner_eta, inner_steps)
    g = jax.tree.map(lambda ww, th: (lam * (ww - th)).astype(ww.dtype),
                     params, theta)
    return g, nu


def personalize_me(loss_fn: Loss, params, batch, lam: float, inner_eta: float,
                   inner_steps: int):
    """Client-side personalization: return θ̃_i(w) — the personalized model
    the ME formulation serves (pFedMe-style evaluation budget)."""
    theta, _ = solve_prox(loss_fn, params, batch, lam, inner_eta, inner_steps)
    return theta
