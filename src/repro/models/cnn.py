"""The paper's experimental models (§5, Appendix D): small CNNs with
pooling + dropout + cross-entropy for MNIST / CIFAR-10 classification.

Pure-functional JAX; used by the FLRun event loop and the
paper-reproduction benchmarks (Figure 2b/2c).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.paper_models import CNNConfig
from repro.models.layers import cross_entropy, dense_init


def init_cnn(cfg: CNNConfig, key) -> Dict:
    ks = jax.random.split(key, len(cfg.conv_channels) + len(cfg.fc_sizes))
    params: Dict = {"conv": [], "fc": []}
    c_in = cfg.channels
    for i, c_out in enumerate(cfg.conv_channels):
        params["conv"].append({
            "w": dense_init(ks[i], (3, 3, c_in, c_out), scale=0.1),
            "b": jnp.zeros((c_out,), jnp.float32),
        })
        c_in = c_out
    # spatial size after len(conv) stride-2 maxpools
    side = cfg.image_size
    for _ in cfg.conv_channels:
        side = side // 2
    d = side * side * c_in
    for j, width in enumerate(cfg.fc_sizes):
        params["fc"].append({
            "w": dense_init(ks[len(cfg.conv_channels) + j], (d, width)),
            "b": jnp.zeros((width,), jnp.float32),
        })
        d = width
    return params


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_logits(cfg: CNNConfig, params, images, *, rng=None,
               train: bool = False):
    """images: (B, H, W, C) f32 -> (B, n_classes)."""
    h = images.astype(jnp.float32)
    for cp in params["conv"]:
        h = jax.lax.conv_general_dilated(
            h, cp["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + cp["b"])
        h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    n_fc = len(params["fc"])
    for j, fp in enumerate(params["fc"]):
        h = h @ fp["w"] + fp["b"]
        if j < n_fc - 1:
            h = jax.nn.relu(h)
            if train and rng is not None and cfg.dropout > 0:
                rng, sub = jax.random.split(rng)
                keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, h.shape)
                h = jnp.where(keep, h / (1.0 - cfg.dropout), 0.0)
    return h


def cnn_loss(cfg: CNNConfig, params, batch: Dict, *, rng=None,
             train: bool = True) -> jnp.ndarray:
    """batch: images (B,H,W,C), labels (B,) int32."""
    logits = cnn_logits(cfg, params, batch["images"], rng=rng, train=train)
    return cross_entropy(logits, batch["labels"])


def cnn_accuracy(cfg: CNNConfig, params, batch: Dict) -> jnp.ndarray:
    logits = cnn_logits(cfg, params, batch["images"], train=False)
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                    .astype(jnp.float32))
