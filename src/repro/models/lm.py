"""Decoder-only LM covering the dense / moe / vlm families.

Features (per-arch flags in :class:`ArchConfig`):
  * GQA with optional QKV bias (qwen), logit softcaps + post-block norms +
    local/global alternation (gemma2), MLA (deepseek-v3).
  * Dense SwiGLU FFN or MoE (shared + routed experts, first-k-dense).
  * VLM: stubbed visual patch embeddings prepended to the text stream
    (assignment carve-out; the ViT is NOT implemented).
  * DeepSeek MTP: one extra transformer block predicting token t+2,
    sharing the unembedding (train-time only, weight 0.1).

Scan-over-layers with per-layer remat keeps the lowered HLO to one stacked
layer regardless of depth; heterogeneous layers (gemma2 local/global) are
handled with a scanned boolean, deepseek's first-k dense layers as an
unrolled prefix.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (cross_entropy, dense_init, embed_tokens,
                                 init_embed, init_mlp, init_rms_norm,
                                 mlp_forward, rms_norm, unembed)
from repro.sharding.ctx import shard_activation


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, *, dense_ffn_width: int = 0):
    """One transformer layer; dense_ffn_width overrides MoE (deepseek prefix)."""
    k1, k2 = jax.random.split(key)
    p = {"ln1": init_rms_norm(cfg.d_model), "ln2": init_rms_norm(cfg.d_model)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(k1, cfg)
    else:
        p["attn"] = attn.init_attn(k1, cfg)
    if dense_ffn_width:
        p["mlp"] = init_mlp(k2, cfg.d_model, dense_ffn_width)
    elif cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
    if cfg.post_block_norm:
        p["ln1_post"] = init_rms_norm(cfg.d_model)
        p["ln2_post"] = init_rms_norm(cfg.d_model)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def n_scanned_layers(cfg: ArchConfig) -> int:
    k = cfg.moe.first_k_dense if cfg.moe is not None else 0
    return cfg.n_layers - k


def init_lm(cfg: ArchConfig, key) -> Dict:
    ks = jax.random.split(key, 5)
    params: Dict = {"embed": init_embed(ks[0], cfg.vocab, cfg.d_model),
                    "final_norm": init_rms_norm(cfg.d_model)}
    first_k = cfg.moe.first_k_dense if cfg.moe is not None else 0
    if first_k:
        dkeys = jax.random.split(ks[1], first_k)
        params["dense_prefix"] = [
            _init_layer(k, cfg, dense_ffn_width=cfg.moe.dense_d_ff)
            for k in dkeys]
    n_scan = n_scanned_layers(cfg)
    lkeys = jax.random.split(ks[2], n_scan)
    params["layers"] = _stack([_init_layer(k, cfg) for k in lkeys])
    if cfg.n_visual_tokens:
        params["vis_proj"] = dense_init(ks[3], (cfg.d_model, cfg.d_model))
    if cfg.use_mtp:
        k1, k2 = jax.random.split(ks[4])
        params["mtp"] = {
            "proj": dense_init(k1, (2 * cfg.d_model, cfg.d_model)),
            "block": _init_layer(k2, cfg, dense_ffn_width=cfg.d_ff or 2048),
            "norm": init_rms_norm(cfg.d_model),
        }
    return params


def _is_local_flags(cfg: ArchConfig, n: int, offset: int = 0):
    idx = jnp.arange(offset, offset + n)
    if cfg.local_global_period:
        return (idx % cfg.local_global_period) == 0
    if cfg.sliding_window:
        return jnp.ones((n,), bool)
    return jnp.zeros((n,), bool)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_forward(cfg: ArchConfig, p, h, positions, is_local):
    dt = h.dtype
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, _ = attn.mla_forward(cfg, p["attn"], x, positions=positions)
    else:
        a, _ = attn.attn_forward(cfg, p["attn"], x, positions=positions,
                                 window=cfg.sliding_window,
                                 local_flag=is_local)
    if cfg.post_block_norm:
        a = rms_norm(a, p["ln1_post"], cfg.norm_eps)
    h = h + a
    x = rms_norm(h, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        f, aux = moe_mod.moe_forward(cfg, p["moe"], x)
    else:
        f = mlp_forward(p["mlp"], x)
    if cfg.post_block_norm:
        f = rms_norm(f, p["ln2_post"], cfg.norm_eps)
    h = h + f
    h = shard_activation(h, "residual")
    return h.astype(dt), aux


def lm_hidden(cfg: ArchConfig, params, tokens, visual: Optional[jnp.ndarray]
              = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B,S_text) int32 [; visual: (B,V,d)] -> (h (B,S,d), aux)."""
    dt = cfg.activation_dtype
    h = embed_tokens(params["embed"], tokens, dt)
    if cfg.post_block_norm:  # gemma-style embedding scale
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.n_visual_tokens:
        assert visual is not None, "vlm arch needs visual embeddings"
        vis = visual.astype(dt) @ params["vis_proj"].astype(dt)
        h = jnp.concatenate([vis, h], axis=1)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    offset = 0
    for p in params.get("dense_prefix", []):
        fwd = jax.checkpoint(lambda pp, hh: _layer_forward(
            cfg, pp, hh, positions, jnp.asarray(False))) if cfg.remat else \
            (lambda pp, hh: _layer_forward(cfg, pp, hh, positions,
                                           jnp.asarray(False)))
        h, aux = fwd(p, h)
        aux_total = aux_total + aux
        offset += 1

    n_scan = n_scanned_layers(cfg)
    flags = _is_local_flags(cfg, n_scan, offset)

    def body(carry, xs):
        hh, auxc = carry
        lp, flag = xs
        hh, aux = _layer_forward(cfg, lp, hh, positions, flag)
        return (hh, auxc + aux), None

    if cfg.remat and cfg.remat_policy == "dots":
        scan_body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat:
        scan_body = jax.checkpoint(body)
    else:
        scan_body = body
    (h, aux_total), _ = jax.lax.scan(scan_body, (h, aux_total),
                                     (params["layers"], flags))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux_total


def lm_logits(cfg: ArchConfig, params, tokens, visual=None):
    h, aux = lm_hidden(cfg, params, tokens, visual)
    return unembed(params["embed"], h, cfg.final_softcap), aux


def lm_loss(cfg: ArchConfig, params, batch: Dict) -> jnp.ndarray:
    """batch: tokens (B,S), labels (B,S) [, visual (B,V,d)].

    For VLM archs the visual positions get label -1 (masked).
    """
    tokens, labels = batch["tokens"], batch["labels"]
    h, aux = lm_hidden(cfg, params, tokens, batch.get("visual"))
    if cfg.n_visual_tokens:
        h_text = h[:, cfg.n_visual_tokens:, :]
    else:
        h_text = h
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    # chunked unembed keeps the (B,S,V) logits out of HBM all at once
    logits = unembed(params["embed"], h_text, cfg.final_softcap)
    loss = cross_entropy(logits, labels, mask)
    if cfg.use_mtp and "mtp" in params:
        loss = loss + 0.1 * _mtp_loss(cfg, params, h_text, tokens, labels, mask)
    return loss + aux


def _mtp_loss(cfg: ArchConfig, params, h, tokens, labels, mask):
    """DeepSeek-V3 multi-token prediction: predict token t+2 from
    concat(h_t, embed(token_{t+1})) through one extra block."""
    mp = params["mtp"]
    dt = h.dtype
    B, S = tokens.shape
    # next-token embeddings, shifted left by one
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    e = embed_tokens(params["embed"], nxt, dt)
    hcat = jnp.concatenate([h, e], axis=-1) @ mp["proj"].astype(dt)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    hm, _ = _layer_forward(cfg, mp["block"], hcat, positions,
                           jnp.asarray(False))
    hm = rms_norm(hm, mp["norm"], cfg.norm_eps)
    logits = unembed(params["embed"], hm, cfg.final_softcap)
    # target: token t+2  -> labels shifted left by one
    lab2 = jnp.concatenate([labels[:, 1:], -jnp.ones((B, 1), labels.dtype)],
                           axis=1)
    m2 = mask * (lab2 >= 0).astype(jnp.float32)
    return cross_entropy(logits, jnp.maximum(lab2, 0), m2)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_lm_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    """Stacked per-layer KV caches (scanned-layer portion + dense prefix)."""
    n_scan = n_scanned_layers(cfg)
    first_k = cfg.moe.first_k_dense if cfg.moe is not None else 0
    if cfg.mla is not None:
        m = cfg.mla
        def one(n):
            return {"ckv": jnp.zeros((n, batch, max_len, m.kv_lora_rank), dtype),
                    "krope": jnp.zeros((n, batch, max_len, m.qk_rope_head_dim),
                                       dtype)}
    else:
        hd = cfg.resolved_head_dim
        def one(n):
            return {"k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), dtype)}
    cache = {"layers": one(n_scan)}
    if first_k:
        cache["dense_prefix"] = one(first_k)
    return cache


def _layer_decode(cfg: ArchConfig, p, h, lcache, pos, is_local):
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, ckv, krope = attn.mla_decode(cfg, p["attn"], x, lcache["ckv"],
                                        lcache["krope"], pos)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        a, k, v = attn.attn_decode(cfg, p["attn"], x, lcache["k"], lcache["v"],
                                   pos, window=cfg.sliding_window,
                                   local_flag=is_local)
        new_cache = {"k": k, "v": v}
    if cfg.post_block_norm:
        a = rms_norm(a, p["ln1_post"], cfg.norm_eps)
    h = h + a
    x = rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_mod.moe_forward(cfg, p["moe"], x)
    else:
        f = mlp_forward(p["mlp"], x)
    if cfg.post_block_norm:
        f = rms_norm(f, p["ln2_post"], cfg.norm_eps)
    return h + f, new_cache


def lm_decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """tokens: (B,1) int32; pos: scalar int32 -> (logits (B,1,V), cache)."""
    dt = cfg.activation_dtype
    h = embed_tokens(params["embed"], tokens, dt)
    if cfg.post_block_norm:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)

    new_cache = {}
    if "dense_prefix" in params:
        upd = []
        for i, p in enumerate(params["dense_prefix"]):
            lcache = jax.tree.map(lambda c: c[i], cache["dense_prefix"])
            h, nc = _layer_decode(cfg, p, h, lcache, pos, jnp.asarray(False))
            upd.append(nc)
        new_cache["dense_prefix"] = _stack(upd)

    n_scan = n_scanned_layers(cfg)
    offset = len(params.get("dense_prefix", []))
    flags = _is_local_flags(cfg, n_scan, offset)

    def body(h, xs):
        lp, lcache, flag = xs
        h, nc = _layer_decode(cfg, lp, h, lcache, pos, flag)
        return h, nc

    h, scanned_cache = jax.lax.scan(body, h,
                                    (params["layers"], cache["layers"], flags))
    new_cache["layers"] = scanned_cache
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg.final_softcap)
    return logits, new_cache
