"""Unified model API — family dispatch used by PersA-FL core, the launch
layer, tests and benchmarks.

    init_params(cfg, key)                 -> params pytree
    loss_fn(cfg, params, batch)           -> scalar loss  (the f_i of Eq. 2)
    init_cache(cfg, params?, batch, ...)  -> decode cache
    decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)
    make_train_batch_spec / make_decode_spec come from repro.launch.specs
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec as ed
from repro.models import lm
from repro.models import ssm_lm


def init_params(cfg: ArchConfig, key) -> Dict:
    if cfg.family in ("ssm", "hybrid"):
        return ssm_lm.init_ssm_lm(cfg, key)
    if cfg.is_encdec:
        return ed.init_encdec(cfg, key)
    return lm.init_lm(cfg, key)


def loss_fn(cfg: ArchConfig, params, batch: Dict) -> jnp.ndarray:
    """The client loss f_i(w; D_i) — Eq. (2) of the paper, per-arch."""
    if cfg.family in ("ssm", "hybrid"):
        return ssm_lm.ssm_lm_loss(cfg, params, batch)
    if cfg.is_encdec:
        return ed.encdec_loss(cfg, params, batch)
    return lm.lm_loss(cfg, params, batch)


def prefill_logits(cfg: ArchConfig, params, batch: Dict) -> jnp.ndarray:
    """Inference-prefill: full forward, last-position logits (B, V)."""
    from repro.models.layers import unembed
    if cfg.family in ("ssm", "hybrid"):
        h = ssm_lm.ssm_lm_hidden(cfg, params, batch["tokens"],
                                 window=cfg.sliding_window)
        return unembed(params["embed"], h[:, -1:, :], cfg.final_softcap)[:, 0]
    if cfg.is_encdec:
        enc_h = ed.encode(cfg, params, batch["frames"])
        h = ed.decode_full(cfg, params, batch["tokens"], enc_h)
        return unembed(params["embed"], h[:, -1:, :], cfg.final_softcap)[:, 0]
    h, _ = lm.lm_hidden(cfg, params, batch["tokens"], batch.get("visual"))
    return unembed(params["embed"], h[:, -1:, :], cfg.final_softcap)[:, 0]


def init_cache(cfg: ArchConfig, params, batch: Dict, max_len: int, dtype):
    """Decode cache; enc-dec additionally runs the encoder on batch['frames']."""
    B = batch["tokens"].shape[0]
    if cfg.family in ("ssm", "hybrid"):
        return ssm_lm.init_ssm_cache(cfg, B, max_len, dtype)
    if cfg.is_encdec:
        return ed.init_encdec_cache(cfg, params, batch["frames"], max_len,
                                    dtype)
    return lm.init_lm_cache(cfg, B, max_len, dtype)


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    if cfg.family in ("ssm", "hybrid"):
        return ssm_lm.ssm_lm_decode_step(cfg, params, cache, tokens, pos)
    if cfg.is_encdec:
        return ed.encdec_decode_step(cfg, params, cache, tokens, pos)
    return lm.lm_decode_step(cfg, params, cache, tokens, pos)
