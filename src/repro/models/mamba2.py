"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) layer.

Full-sequence path uses the chunked SSD algorithm: quadratic attention-like
intra-chunk term + linear inter-chunk recurrence (``lax.scan`` over chunks).
This is the pure-jnp reference; the Pallas TPU kernel lives in
``repro.kernels.ssd`` and computes the identical chunked algorithm with
VMEM-tiled BlockSpecs.

Decode path is the O(1)-per-token recurrence with a conv ring buffer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import dense_init, rms_norm, init_rms_norm
from repro.sharding.ctx import shard_activation


def dims(cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.state_dim
    return d_inner, n_heads, conv_dim


def init_mamba2(key, cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim = dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * s.n_groups * s.state_dim + n_heads
    return {
        "in_proj": dense_init(ks[0], (d, proj_out)),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": init_rms_norm(d_inner),
        "out_proj": dense_init(ks[3], (d_inner, d)),
    }


def _split_proj(cfg: ArchConfig, proj):
    s = cfg.ssm
    d_inner, n_heads, _ = dims(cfg)
    gn = s.n_groups * s.state_dim
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt  # dt: (..., n_heads)


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv, width W.  xbc: (B,S,Cdim); conv_w: (W,Cdim)."""
    W = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * conv_w[i].astype(xbc.dtype)
              for i in range(W))
    return jax.nn.silu(out + conv_b.astype(xbc.dtype))


def ssd_chunked(x, dt, a_log, B_mat, C_mat, chunk: int):
    """Chunked SSD scan (pure jnp reference; f32 internals).

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); a_log: (H,) (A = -exp(a_log));
    B_mat/C_mat: (B,S,G,N) with H % G == 0.  Returns y: (B,S,H,P).
    """
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc, rep = S // chunk, H // G
    f32 = jnp.float32

    x = x.astype(f32).reshape(Bb, nc, chunk, H, P)
    dt = dt.astype(f32).reshape(Bb, nc, chunk, H)
    Bm = jnp.repeat(B_mat.astype(f32), rep, axis=2).reshape(Bb, nc, chunk, H, N)
    Cm = jnp.repeat(C_mat.astype(f32), rep, axis=2).reshape(Bb, nc, chunk, H, N)

    A = -jnp.exp(a_log.astype(f32))              # (H,) negative
    a = dt * A                                   # (B,nc,l,H) log-decay
    a_cum = jnp.cumsum(a, axis=2)                # inclusive cumsum within chunk
    x_dt = x * dt[..., None]

    # intra-chunk (quadratic, attention-like): L[l,s] = exp(acum_l - acum_s), l>=s
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]   # (B,nc,l,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: upper-triangular seg is positive and exp overflows,
    # poisoning gradients through the where
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    L = jnp.exp(seg)
    y_diag = jnp.einsum("bclhn,bcshn,bclsh,bcshp->bclhp", Cm, Bm, L, x_dt)

    # per-chunk terminal states
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)       # (B,nc,l,H)
    chunk_states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bm, decay_to_end, x_dt)
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))                 # (B,nc,H)

    def carry_fn(state, inp):
        cs, cd = inp                                          # (B,H,P,N),(B,H)
        new = state * cd[:, :, None, None] + cs
        return new, state                                      # emit state *before* chunk

    init = jnp.zeros((Bb, H, P, N), f32)
    _, prev_states = jax.lax.scan(
        carry_fn, init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (B,nc,H,P,N)

    # inter-chunk contribution
    decay_from_start = jnp.exp(a_cum)                         # (B,nc,l,H)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cm, prev_states,
                       decay_from_start)
    return (y_diag + y_off).reshape(Bb, S, H, P)


def mamba2_forward(cfg: ArchConfig, p, x, *, use_kernel: bool = False):
    """Full-sequence Mamba2 block. x: (B,S,d) -> (B,S,d)."""
    s = cfg.ssm
    d_inner, n_heads, _ = dims(cfg)
    dt_ = x.dtype
    proj = x @ p["in_proj"].astype(dt_)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    gn = s.n_groups * s.state_dim
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    Bb, S = x.shape[:2]
    xs = xs.reshape(Bb, S, n_heads, s.head_dim)
    Bm = Bm.reshape(Bb, S, s.n_groups, s.state_dim)
    Cm = Cm.reshape(Bb, S, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    if use_kernel:
        from repro.kernels.ssd import ops as ssd_ops
        y = ssd_ops.ssd(xs, dt, p["a_log"], Bm, Cm, chunk=s.chunk)
    else:
        y = ssd_chunked(xs, dt, p["a_log"], Bm, Cm, chunk=s.chunk)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(Bb, S, d_inner).astype(dt_)
    y = shard_activation(y, "ssm_out")
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dt_)


# ---------------------------------------------------------------------------
# decode: O(1) recurrence
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, n_heads, s.head_dim, s.state_dim),
                           jnp.float32),
    }


def mamba2_decode(cfg: ArchConfig, p, x, cache) -> Tuple[jnp.ndarray, dict]:
    """One-token step. x: (B,1,d) -> (out (B,1,d), new cache)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = dims(cfg)
    dt_ = x.dtype
    Bb = x.shape[0]
    proj = x[:, 0] @ p["in_proj"].astype(dt_)                 # (B, proj)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window,
                          p["conv_w"].astype(dt_)) + p["conv_b"].astype(dt_)
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]
    gn = s.n_groups * s.state_dim
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    xs = xs.reshape(Bb, n_heads, s.head_dim).astype(jnp.float32)
    Bm = Bm.reshape(Bb, s.n_groups, s.state_dim).astype(jnp.float32)
    Cm = Cm.reshape(Bb, s.n_groups, s.state_dim).astype(jnp.float32)
    rep = n_heads // s.n_groups
    Bm = jnp.repeat(Bm, rep, axis=1)                          # (B,H,N)
    Cm = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)                                      # (B,H)
    state = cache["state"] * da[:, :, None, None] \
        + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bm, xs)
    y = jnp.einsum("bhpn,bhn->bhp", state, Cm)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(Bb, d_inner).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    return out, {"conv": new_conv, "state": state}
