"""Attention variants: GQA, sliding-window/global alternation, logit
softcap, QKV bias, cross-attention, and DeepSeek-V3 MLA.

Two execution paths per variant:
  * full-sequence (train / prefill) — optionally backed by the Pallas flash
    kernel on TPU (``repro.kernels.flash_attention``); pure-jnp on CPU.
  * single-token decode against a KV cache.

Softmax is always computed in f32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.models.layers import apply_rope, dense_init, softcap
from repro.sharding.ctx import shard_activation

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# standard (GQA) attention
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ArchConfig, d_in: Optional[int] = None):
    """d_in lets hybrid blocks feed concat(h, emb) (zamba2)."""
    d = cfg.d_model
    d_in = d_in or d
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d_in, cfg.n_heads * hd)),
        "wk": dense_init(k2, (d_in, cfg.n_kv_heads * hd)),
        "wv": dense_init(k3, (d_in, cfg.n_kv_heads * hd)),
        "wo": dense_init(k4, (cfg.n_heads * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    return p


def _project_qkv(cfg: ArchConfig, p, x, kv_x=None):
    """-> q (B,S,Hq,hd), k/v (B,Skv,Hkv,hd)."""
    dt = x.dtype
    hd = cfg.resolved_head_dim
    kv_x = x if kv_x is None else kv_x
    q = x @ p["wq"].astype(dt)
    k = kv_x @ p["wk"].astype(dt)
    v = kv_x @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    B = x.shape[0]
    q = q.reshape(B, x.shape[1], cfg.n_heads, hd)
    k = k.reshape(B, kv_x.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(B, kv_x.shape[1], cfg.n_kv_heads, hd)
    return q, k, v


def sdpa(q, k, v, *, mask=None, cap: float = 0.0):
    """Grouped scaled-dot-product attention.

    q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd); Hq % Hkv == 0.
    mask: broadcastable to (B,1,1,S,T), True = attend.
    """
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, S, Hkv, group, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    logits = softcap(logits, cap)
    if mask is not None:
        # mask (B,1,1,S,T) -> (B,1,1,S,T) matches (b,k,g,s,t)
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, Hq, hd)


def causal_mask(S: int, T: int, q_offset, window: int = 0, local_flag=None):
    """(1,1,1,S,T) boolean mask, True = attend.

    ``window`` is a static int; ``local_flag`` may be a *traced* boolean
    (scan-over-layers local/global alternation, gemma2): when False the
    window constraint is disabled for that layer.
    """
    qi = q_offset + jnp.arange(S)[:, None]
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window:
        win = kj > qi - window
        if local_flag is not None:
            win = win | jnp.logical_not(local_flag)
        m = m & win
    return m[None, None, None]


def attn_forward(cfg: ArchConfig, p, x, *, positions, window: int = 0,
                 local_flag=None, kv_x=None, kv_positions=None,
                 causal: bool = True):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q, k, v = _project_qkv(cfg, p, x, kv_x)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        kpos = kv_positions if kv_positions is not None else positions
        k = apply_rope(k, kpos, cfg.rope_theta)
    mask = None
    if causal:
        mask = causal_mask(q.shape[1], k.shape[1], 0, window, local_flag)
    out = sdpa(q, k, v, mask=mask, cap=cfg.attn_softcap)
    out = shard_activation(out, "attn_out")
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"].astype(x.dtype), (k, v)


def attn_decode(cfg: ArchConfig, p, x, k_cache, v_cache, pos, *,
                window: int = 0, local_flag=None, rope: bool = True,
                mask_pos=None, rope_pos=None):
    """One-token decode. x: (B,1,d_in); caches: (B,T,Hkv,hd); pos scalar.

    ``mask_pos`` overrides the causal-mask position and ``rope_pos`` the
    rotary position (ring-buffer caches write at ``pos`` = slot while the
    rotary/mask positions stay absolute).
    Returns (out (B,1,d), new_k_cache, new_v_cache).
    """
    q, k, v = _project_qkv(cfg, p, x)
    if rope:
        rp = pos if rope_pos is None else rope_pos
        posv = jnp.full((x.shape[0], 1), rp, jnp.int32)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, pos, 0, 0))
    T = k_cache.shape[1]
    kj = jnp.arange(T)
    mpos = pos if mask_pos is None else mask_pos
    m = kj <= mpos
    if window:
        win = kj > mpos - window
        if local_flag is not None:
            win = win | jnp.logical_not(local_flag)
        m = m & win
    mask = m[None, None, None, None, :]
    out = sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
               mask=mask, cap=cfg.attn_softcap)
    B = x.shape[0]
    return out.reshape(B, 1, -1) @ p["wo"].astype(x.dtype), k_cache, v_cache


def cross_attn_decode(cfg: ArchConfig, p, x, enc_k, enc_v):
    """Decode-time cross attention against precomputed encoder K/V."""
    dt = x.dtype
    hd = cfg.resolved_head_dim
    B = x.shape[0]
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, cfg.n_heads, hd)
    if "bq" in p:
        q = q + p["bq"].astype(dt).reshape(1, 1, cfg.n_heads, hd)
    out = sdpa(q, enc_k.astype(dt), enc_v.astype(dt), mask=None,
               cap=cfg.attn_softcap)
    return out.reshape(B, 1, -1) @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# DeepSeek-V3 Multi-head Latent Attention (MLA)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig):
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank)),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, H * qk_head)),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank)),
        "w_krope": dense_init(ks[3], (d, m.qk_rope_head_dim)),
        "w_uk": dense_init(ks[4], (m.kv_lora_rank, H * m.qk_nope_head_dim)),
        "w_uv": dense_init(ks[5], (m.kv_lora_rank, H * m.v_head_dim)),
        "wo": dense_init(ks[6], (H * m.v_head_dim, d)),
    }


def _mla_q(cfg: ArchConfig, p, x, positions):
    m, H = cfg.mla, cfg.n_heads
    dt = x.dtype
    B, S = x.shape[:2]
    q = (x @ p["w_dq"].astype(dt)) @ p["w_uq"].astype(dt)
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(cfg: ArchConfig, p, x, *, positions):
    """Full-sequence MLA (train / prefill): materialize per-head k/v."""
    m, H = cfg.mla, cfg.n_heads
    dt = x.dtype
    B, S = x.shape[:2]
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv = x @ p["w_dkv"].astype(dt)                        # (B,S,r_kv)
    k_rope = apply_rope((x @ p["w_krope"].astype(dt))[:, :, None, :],
                        positions, cfg.rope_theta)          # (B,S,1,rope)
    k_nope = (c_kv @ p["w_uk"].astype(dt)).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ p["w_uv"].astype(dt)).reshape(B, S, H, m.v_head_dim)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
              + jnp.einsum("bshd,btod->bhst", q_rope, k_rope))
    logits = logits.astype(jnp.float32) * scale
    mask = causal_mask(S, S, 0)[:, :, 0]                    # (1,1,S,T)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(dt)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, -1)
    out = shard_activation(out, "attn_out")
    return out @ p["wo"].astype(dt), (c_kv, k_rope[:, :, 0, :])


def mla_decode(cfg: ArchConfig, p, x, ckv_cache, krope_cache, pos):
    """Absorbed-matrix MLA decode: attend in the latent space.

    score_h(t) = q_nope_h^T W_uk_h c_t + q_rope_h^T k_rope_t  — we absorb
    W_uk into the query and W_uv into the output so the cache stays
    (B,T,r_kv)+(B,T,rope): the memory win MLA exists for.
    """
    m, H = cfg.mla, cfg.n_heads
    dt = x.dtype
    B = x.shape[0]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, posv)                 # (B,1,H,·)
    c_kv = x @ p["w_dkv"].astype(dt)                         # (B,1,r)
    k_rope = apply_rope((x @ p["w_krope"].astype(dt))[:, :, None, :],
                        posv, cfg.rope_theta)[:, :, 0, :]    # (B,1,rope)
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, c_kv.astype(ckv_cache.dtype), (0, pos, 0))
    krope_cache = jax.lax.dynamic_update_slice(
        krope_cache, k_rope.astype(krope_cache.dtype), (0, pos, 0))
    w_uk = p["w_uk"].astype(dt).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)       # (B,1,H,r)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    ckv = ckv_cache.astype(dt)
    logits = (jnp.einsum("bshr,btr->bhst", q_abs, ckv)
              + jnp.einsum("bshd,btd->bhst", q_rope, krope_cache.astype(dt)))
    logits = logits.astype(jnp.float32) * scale
    mask = (jnp.arange(ckv_cache.shape[1]) <= pos)[None, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(dt)
    ctx = jnp.einsum("bhst,btr->bshr", probs, ckv)           # (B,1,H,r)
    w_uv = p["w_uv"].astype(dt).reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", ctx, w_uv).reshape(B, 1, -1)
    return out @ p["wo"].astype(dt), ckv_cache, krope_cache
