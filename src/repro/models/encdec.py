"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Assignment carve-out: the mel-spectrogram + conv feature extractor is a
STUB — the model consumes precomputed frame embeddings (B, enc_len, d).
Positions are sinusoidal (whisper uses sinusoidal enc / learned dec; we use
sinusoidal for both — noted deviation, parameter-free and length-agnostic).
MLPs are 2-matrix GELU (faithful to whisper's param count).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (cross_entropy, embed_tokens, init_embed,
                                 init_mlp_gelu, init_rms_norm,
                                 mlp_gelu_forward, rms_norm,
                                 sinusoidal_positions, unembed)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_encdec(cfg: ArchConfig, key) -> Dict:
    ks = jax.random.split(key, 5)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_rms_norm(cfg.d_model),
                "attn": attn.init_attn(k1, cfg),
                "ln2": init_rms_norm(cfg.d_model),
                "mlp": init_mlp_gelu(k2, cfg.d_model, cfg.d_ff)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_rms_norm(cfg.d_model),
                "self": attn.init_attn(k1, cfg),
                "ln_x": init_rms_norm(cfg.d_model),
                "cross": attn.init_attn(k2, cfg),
                "ln2": init_rms_norm(cfg.d_model),
                "mlp": init_mlp_gelu(k3, cfg.d_model, cfg.d_ff)}

    return {
        "embed": init_embed(ks[0], cfg.vocab, cfg.d_model),
        "enc_layers": _stack([enc_layer(k)
                              for k in jax.random.split(ks[1], cfg.enc_layers)]),
        "enc_norm": init_rms_norm(cfg.d_model),
        "dec_layers": _stack([dec_layer(k)
                              for k in jax.random.split(ks[2], cfg.n_layers)]),
        "final_norm": init_rms_norm(cfg.d_model),
    }


def encode(cfg: ArchConfig, params, frames) -> jnp.ndarray:
    """frames: (B, enc_len, d) stubbed frontend embeddings -> (B, enc_len, d)."""
    dt = cfg.activation_dtype
    h = frames.astype(dt)
    h = h + sinusoidal_positions(h.shape[1], cfg.d_model).astype(dt)[None]

    def body(h, lp):
        x = rms_norm(h, lp["ln1"], cfg.norm_eps)
        a, _ = attn.attn_forward(cfg, lp["attn"], x, positions=None,
                                 causal=False)
        h = h + a
        x = rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + mlp_gelu_forward(lp["mlp"], x), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(scan_body, h, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def decode_full(cfg: ArchConfig, params, tokens, enc_h) -> jnp.ndarray:
    """Teacher-forced decoder (training). tokens: (B,S) -> hidden (B,S,d)."""
    dt = cfg.activation_dtype
    h = embed_tokens(params["embed"], tokens, dt)
    h = h + sinusoidal_positions(h.shape[1], cfg.d_model).astype(dt)[None]

    def body(h, lp):
        x = rms_norm(h, lp["ln1"], cfg.norm_eps)
        a, _ = attn.attn_forward(cfg, lp["self"], x, positions=None,
                                 causal=True)
        h = h + a
        x = rms_norm(h, lp["ln_x"], cfg.norm_eps)
        a, _ = attn.attn_forward(cfg, lp["cross"], x, positions=None,
                                 kv_x=enc_h, causal=False)
        h = h + a
        x = rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + mlp_gelu_forward(lp["mlp"], x), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(scan_body, h, params["dec_layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def encdec_loss(cfg: ArchConfig, params, batch: Dict) -> jnp.ndarray:
    """batch: frames (B,enc_len,d), tokens (B,S), labels (B,S)."""
    enc_h = encode(cfg, params, batch["frames"])
    h = decode_full(cfg, params, batch["tokens"], enc_h)
    logits = unembed(params["embed"], h, cfg.final_softcap)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return cross_entropy(logits, jnp.maximum(labels, 0), mask)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_encdec_cache(cfg: ArchConfig, params, frames, max_len: int, dtype):
    """Encode once; precompute per-layer cross K/V; empty self caches."""
    enc_h = encode(cfg, params, frames)
    B = frames.shape[0]
    hd = cfg.resolved_head_dim

    def cross_kv(lp):
        dt = enc_h.dtype
        k = (enc_h @ lp["cross"]["wk"].astype(dt))
        v = (enc_h @ lp["cross"]["wv"].astype(dt))
        if "bk" in lp["cross"]:
            k = k + lp["cross"]["bk"].astype(dt)
            v = v + lp["cross"]["bv"].astype(dt)
        T = enc_h.shape[1]
        return (k.reshape(B, T, cfg.n_kv_heads, hd).astype(dtype),
                v.reshape(B, T, cfg.n_kv_heads, hd).astype(dtype))

    ck, cv = jax.vmap(cross_kv)(params["dec_layers"])
    return {
        "self_k": jnp.zeros((cfg.n_layers, B, max_len, cfg.n_kv_heads, hd),
                            dtype),
        "self_v": jnp.zeros((cfg.n_layers, B, max_len, cfg.n_kv_heads, hd),
                            dtype),
        "cross_k": ck, "cross_v": cv,
    }


def encdec_decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """tokens (B,1), pos scalar -> (logits, new cache)."""
    dt = cfg.activation_dtype
    h = embed_tokens(params["embed"], tokens, dt)
    h = h + sinusoidal_positions(1, cfg.d_model, offset=pos).astype(dt)[None]

    def body(h, xs):
        lp, sk, sv, ck, cv = xs
        x = rms_norm(h, lp["ln1"], cfg.norm_eps)
        a, sk, sv = attn.attn_decode(cfg, lp["self"], x, sk, sv, pos,
                                     rope=False)
        h = h + a
        x = rms_norm(h, lp["ln_x"], cfg.norm_eps)
        h = h + attn.cross_attn_decode(cfg, lp["cross"], x, ck, cv)
        x = rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + mlp_gelu_forward(lp["mlp"], x), (sk, sv)

    h, (sk, sv) = jax.lax.scan(
        body, h, (params["dec_layers"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache, self_k=sk, self_v=sv)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return unembed(params["embed"], h, cfg.final_softcap), new_cache
