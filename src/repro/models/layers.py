"""Shared model building blocks: norms, MLPs, rotary embeddings, init.

All models are pure-functional: parameters are nested dicts of jnp arrays,
built by ``init_*`` functions and consumed by forward functions.  Stacked
(scan-over-layers) parameters carry a leading ``n_layers`` axis.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.ctx import shard_activation


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal (fan-in) init, stored in f32 and cast at use."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def rms_norm(x, gamma, eps: float = 1e-6):
    """RMSNorm in f32 (bf16-safe), cast back to input dtype."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dtype)


def init_rms_norm(d: int):
    # gemma-style (1 + gamma) parameterization; init gamma = 0.
    return jnp.zeros((d,), jnp.float32)


def softcap(logits, cap: float):
    """Logit soft-capping (gemma2): cap * tanh(x / cap)."""
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, d_in: Optional[int] = None):
    d_in = d_in or d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, (d_in, d_ff)),
        "wu": dense_init(k2, (d_in, d_ff)),
        "wd": dense_init(k3, (d_ff, d_model)),
    }


def mlp_forward(p, x):
    dt = x.dtype
    h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
    h = shard_activation(h, "ffn")
    return h @ p["wd"].astype(dt)


def init_mlp_gelu(key, d_model: int, d_ff: int):
    """2-matrix GELU MLP (whisper-style) — keeps the param count faithful."""
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, (d_model, d_ff)),
            "w2": dense_init(k2, (d_ff, d_model))}


def mlp_gelu_forward(p, x):
    dt = x.dtype
    h = jax.nn.gelu(x @ p["w1"].astype(dt))
    h = shard_activation(h, "ffn")
    return h @ p["w2"].astype(dt)


def sinusoidal_positions(n: int, d: int, offset=0):
    """(n, d) sinusoidal position embeddings (whisper enc/dec)."""
    pos = (jnp.arange(n) + offset)[:, None].astype(jnp.float32)
    div = 1.0 / (10_000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int):
    k1, k2 = jax.random.split(key)
    return {
        "tok": dense_init(k1, (vocab, d_model), scale=1.0),
        "unembed": dense_init(k2, (d_model, vocab)),
    }


def embed_tokens(p, tokens, dtype):
    return p["tok"].astype(dtype)[tokens]


def unembed(p, h, final_softcap: float = 0.0):
    logits = h.astype(jnp.float32) @ p["unembed"].astype(jnp.float32)
    return softcap(logits, final_softcap)


def cross_entropy(logits, labels, mask=None):
    """Token-mean CE in f32. labels: int32; mask: 0/1 same shape."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
