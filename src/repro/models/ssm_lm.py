"""SSM / hybrid language models: mamba2-130m (pure SSD) and zamba2-1.2b
(Mamba2 backbone + one *shared* transformer block every ``attn_every``
layers, applied to concat(hidden, original embedding) — arXiv:2411.15242;
the per-invocation LoRA adapters of the original are simplified away, noted
in DESIGN.md §4).

Both are scan-over-layers; the hybrid's shared-attention invocations are a
``lax.cond`` inside the scan (slot index = layer // attn_every), so the
lowered HLO stays one stacked Mamba2 layer + one shared block.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models.layers import (cross_entropy, embed_tokens, init_embed,
                                 init_mlp, init_rms_norm, mlp_forward,
                                 rms_norm, unembed)


def n_shared_slots(cfg: ArchConfig) -> int:
    if not cfg.attn_every:
        return 0
    return cfg.n_layers // cfg.attn_every


def init_ssm_lm(cfg: ArchConfig, key) -> Dict:
    ks = jax.random.split(key, 4)
    params: Dict = {"embed": init_embed(ks[0], cfg.vocab, cfg.d_model),
                    "final_norm": init_rms_norm(cfg.d_model)}
    lkeys = jax.random.split(ks[1], cfg.n_layers)

    def one_layer(k):
        return {"ln": init_rms_norm(cfg.d_model),
                "mamba": m2.init_mamba2(k, cfg)}

    params["layers"] = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[one_layer(k) for k in lkeys])
    if cfg.attn_every:
        k1, k2 = jax.random.split(ks[2])
        params["shared"] = {
            "ln_in": init_rms_norm(2 * cfg.d_model),
            "attn": attn.init_attn(k1, cfg, d_in=2 * cfg.d_model),
            "ln_mlp": init_rms_norm(cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff),
        }
    return params


def _shared_block(cfg: ArchConfig, sp, h, emb0, positions, window: int):
    """Shared attention+MLP block on concat(h, emb0)."""
    u = jnp.concatenate([h, emb0], axis=-1)
    u = rms_norm(u, sp["ln_in"], cfg.norm_eps)
    a, _ = attn.attn_forward(cfg, sp["attn"], u, positions=positions,
                             window=window)
    h = h + a
    x = rms_norm(h, sp["ln_mlp"], cfg.norm_eps)
    return h + mlp_forward(sp["mlp"], x)


def ssm_lm_hidden(cfg: ArchConfig, params, tokens, *, window: int = 0):
    dt = cfg.activation_dtype
    emb0 = embed_tokens(params["embed"], tokens, dt)
    h = emb0
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    use_kernel = False  # jnp reference on CPU; kernels validated separately
    shared = params.get("shared")

    def body(h, xs):
        lp, idx = xs
        x = rms_norm(h, lp["ln"], cfg.norm_eps)
        h = h + m2.mamba2_forward(cfg, lp["mamba"], x, use_kernel=use_kernel)
        if shared is not None:
            flag = (idx % cfg.attn_every) == (cfg.attn_every - 1)
            h = jax.lax.cond(
                flag,
                lambda hh: _shared_block(cfg, shared, hh, emb0, positions,
                                         window),
                lambda hh: hh,
                h)
        return h, None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(scan_body, h,
                        (params["layers"], jnp.arange(cfg.n_layers)))
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def ssm_lm_loss(cfg: ArchConfig, params, batch: Dict) -> jnp.ndarray:
    tokens, labels = batch["tokens"], batch["labels"]
    # the shared attn block (zamba2) uses its sliding window in training too
    h = ssm_lm_hidden(cfg, params, tokens,
                      window=cfg.sliding_window)
    logits = unembed(params["embed"], h, cfg.final_softcap)
    mask = (labels >= 0).astype(jnp.float32)
    return cross_entropy(logits, jnp.maximum(labels, 0), mask)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    L = cfg.n_layers
    per = m2.init_ssm_cache(cfg, batch, dtype)
    cache = {"ssm": jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (L,) + x.shape).copy(), per)}
    slots = n_shared_slots(cfg)
    if slots:
        hd = cfg.resolved_head_dim
        # sliding-window shared attention at decode: cache only the window
        # (sub-quadratic at long_500k — DESIGN.md §4)
        T = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        cache["attn"] = {
            "k": jnp.zeros((slots, batch, T, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((slots, batch, T, cfg.n_kv_heads, hd), dtype),
        }
        cache["emb0"] = None  # filled per-step (decode embeds current token)
    return cache


def ssm_lm_decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """tokens (B,1), pos scalar -> (logits (B,1,V), new cache).

    The shared attention block's KV cache is a ring buffer of the sliding
    window; positions use rotary offsets so ring wrap-around is exact for
    window-limited attention.
    """
    dt = cfg.activation_dtype
    emb0 = embed_tokens(params["embed"], tokens, dt)
    h = emb0
    shared = params.get("shared")
    attn_cache = cache.get("attn")

    def shared_decode(hh, ac, slot):
        u = jnp.concatenate([hh, emb0], axis=-1)
        u = rms_norm(u, shared["ln_in"], cfg.norm_eps)
        T = ac["k"].shape[2]
        write = jnp.mod(pos, T)          # ring-buffer slot
        kc = ac["k"][slot]
        vc = ac["v"][slot]
        # ring buffer of size window: after wrap every entry is live, so the
        # causal mask position is min(pos, T-1) while writes go to pos % T
        # and rotary positions stay absolute (matching the train path).
        a, kc, vc = attn.attn_decode(
            cfg, shared["attn"], u, kc, vc, write,
            window=0, rope=True, rope_pos=pos,
            mask_pos=jnp.minimum(pos, T - 1))
        ac = {"k": ac["k"].at[slot].set(kc), "v": ac["v"].at[slot].set(vc)}
        hh = hh + a
        x = rms_norm(hh, shared["ln_mlp"], cfg.norm_eps)
        return hh + mlp_forward(shared["mlp"], x), ac

    new_ssm = []
    ac = attn_cache
    L = cfg.n_layers
    for i in range(L):  # decode is unrolled: tiny per-layer compute
        lp = jax.tree.map(lambda x: x[i], params["layers"])
        lc = jax.tree.map(lambda x: x[i], cache["ssm"])
        x = rms_norm(h, lp["ln"], cfg.norm_eps)
        out, nc = m2.mamba2_decode(cfg, lp["mamba"], x, lc)
        h = h + out
        new_ssm.append(nc)
        if shared is not None and (i % cfg.attn_every) == (cfg.attn_every - 1):
            slot = i // cfg.attn_every
            h, ac = shared_decode(h, ac, slot)

    new_cache = {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_ssm)}
    if ac is not None:
        new_cache["attn"] = ac
        new_cache["emb0"] = None
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg.final_softcap)
    return logits, new_cache
