"""Mixture-of-Experts layer: top-k router, grouped capacity dispatch,
optional shared experts (DeepSeek-V3) and router load-balance aux loss.

TPU-native expert parallelism: experts live on the ``model`` mesh axis,
tokens on ``data``.  Dispatch is the GShard-style grouped one-hot einsum —
tokens are grouped per sequence so capacity is per (group, expert) and the
dispatch tensor stays small; the (group <-> expert) einsum is exactly the
transpose XLA SPMD lowers to an all-to-all (see DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import dense_init
from repro.sharding.ctx import shard_activation


def init_moe(key, cfg: ArchConfig):
    mo: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, mo.n_experts), scale=0.02),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "wg": dense_init(ks[1], (mo.n_experts, d, mo.expert_d_ff)),
        "wu": dense_init(ks[2], (mo.n_experts, d, mo.expert_d_ff)),
        "wd": dense_init(ks[3], (mo.n_experts, mo.expert_d_ff, d)),
    }
    if mo.n_shared_experts:
        kk = jax.random.split(ks[4], 3)
        f = mo.shared_d_ff * mo.n_shared_experts
        p["shared"] = {
            "wg": dense_init(kk[0], (d, f)),
            "wu": dense_init(kk[1], (d, f)),
            "wd": dense_init(kk[2], (f, d)),
        }
    return p


def expert_capacity(tokens_per_group: int, mo: MoEConfig) -> int:
    c = math.ceil(tokens_per_group * mo.top_k / mo.n_experts * mo.capacity_factor)
    return max(int(c), mo.top_k)


def moe_forward(cfg: ArchConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    Groups = batch rows (one sequence per group).  Tokens over capacity are
    dropped (standard GShard semantics, capacity_factor 1.25).
    """
    mo: MoEConfig = cfg.moe
    dt = x.dtype
    G, S, d = x.shape
    E, K = mo.n_experts, mo.top_k
    C = expert_capacity(S, mo)

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)   # (G,S,E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, K)                      # (G,S,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- capacity assignment -------------------------------------------------
    # one-hot over experts per (token, k-slot); earlier tokens get priority.
    oh = jax.nn.one_hot(top_i, E, dtype=jnp.float32)            # (G,S,K,E)
    # queue position of each (token,slot) within its expert
    flat = oh.reshape(G, S * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, S, K, E)
    p_sel = jnp.einsum("gske,gske->gsk", pos, oh).astype(jnp.int32)
    # one_hot(index >= C) is all-zero -> over-capacity tokens drop out here
    oh_c = jax.nn.one_hot(p_sel, C, dtype=jnp.float32)          # (G,S,K,C)
    # dispatch: (G,S,E,C) in {0,1}; combine additionally carries router weights
    dispatch = jnp.einsum("gske,gskc->gsec", oh, oh_c)
    combine = jnp.einsum("gsk,gske,gskc->gsec", top_w, oh, oh_c)

    dispatch = dispatch.astype(dt)
    combine = combine.astype(dt)
    dispatch = shard_activation(dispatch, "moe_dispatch")

    # --- expert compute (expert-parallel) ------------------------------------
    xin = jnp.einsum("gsec,gsd->egcd", dispatch, x)             # all-to-all
    xin = shard_activation(xin, "moe_expert_in")
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, p["wg"].astype(dt)))
    h = h * jnp.einsum("egcd,edf->egcf", xin, p["wu"].astype(dt))
    out_e = jnp.einsum("egcf,efd->egcd", h, p["wd"].astype(dt))
    out = jnp.einsum("gsec,egcd->gsd", combine, out_e)          # all-to-all back

    # --- shared experts -------------------------------------------------------
    if "shared" in p:
        sh = p["shared"]
        hs = jax.nn.silu(x @ sh["wg"].astype(dt)) * (x @ sh["wu"].astype(dt))
        out = out + hs @ sh["wd"].astype(dt)

    # --- load-balance aux loss (Switch/GShard style) --------------------------
    me = jnp.mean(gates.reshape(-1, E), axis=0)                  # avg router prob
    ce = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32).reshape(-1, E),
        axis=0)                                                  # top-1 load
    aux = E * jnp.sum(me * ce) * mo.router_aux_weight
    return out, aux
