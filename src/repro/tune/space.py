"""Declarative sweep space: JSON-round-tripping :class:`SweepSpec` →
fingerprinted :class:`Arm`\\ s, plus the successive-halving ``hillclimb``
expansion.

A sweep is a cartesian grid over the run surfaces the Strategy registry
and :class:`repro.fl.FLRun` already make one-liners:

  * **strategies** — registry names plus constructor kwargs
    (``{"name": "persafl", "option": "B"}``, ``{"name": "fedprox",
    "mu": 0.1}``);
  * **schedules** — spelled as strings (``"immediate"``, ``"buffered(8)"``,
    ``"buffered(8, robust=clip)"``, ``"sync(10)"``) so specs stay plain
    data; :func:`parse_schedule` turns a spelling into the live
    :class:`repro.fl.api.ApplyPolicy`;
  * **pcfg_grid** — axes over :class:`repro.core.PersAFLConfig` fields
    (``{"eta": [0.002, 0.005]}``);
  * an optional :class:`repro.fl.scenario.ScenarioSpec` (churn /
    adversaries) shared by every arm;
  * **seeds** — one arm per seed; arms with equal seeds replay *paired*
    client/delay streams (the counter-based hash streams of
    :mod:`repro.fl.delays` make timelines a pure function of (seed,
    client, cycle), so two arms differing only in strategy/schedule see
    bit-identical event timelines — what makes grid cells comparable).

:meth:`SweepSpec.arms` expands the grid into :class:`Arm` records, each
with a stable content :meth:`~Arm.fingerprint` — the resume key the
:class:`repro.tune.runner.TuneRunner` journal skips completed trials by.

``hillclimb`` (successive halving): :func:`rung_arms` re-budgets a
surviving population onto the next rung, :func:`promote` keeps the top
``ceil(n/eta)`` scored arms.  Because an :class:`Arm`'s fingerprint
covers its budget, every (arm, rung) pair is its own journaled trial and
a killed hillclimb resumes mid-ladder.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import math
import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fl.api import (ApplyPolicy, buffered, immediate, strategy,
                          sync_barrier)
from repro.fl.scenario import ScenarioSpec

# ---------------------------------------------------------------------------
# schedule spellings
# ---------------------------------------------------------------------------

_SCHED_RE = re.compile(r"^\s*([A-Za-z_][A-Za-z_0-9]*)\s*(?:\((.*)\))?\s*$")


def _literal(tok: str):
    """Parse one schedule-argument token: int, float, bool, None, or a
    (possibly quoted) bare string — ``robust=clip`` and ``robust='clip'``
    mean the same thing."""
    t = tok.strip()
    low = t.lower()
    if low in ("none", "null"):
        return None
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(t)
        except ValueError:
            pass
    if len(t) >= 2 and t[0] == t[-1] and t[0] in "'\"":
        return t[1:-1]
    return t


def parse_schedule(spelling: str) -> ApplyPolicy:
    """``"immediate"`` / ``"buffered(8)"`` / ``"buffered(4, robust=clip,
    trim_frac=0.2)"`` / ``"sync(10)"`` → a fresh :class:`ApplyPolicy`.

    Every call constructs a new policy instance (policies hold per-run
    state), so one spelling can drive many arms.
    """
    m = _SCHED_RE.match(spelling)
    if not m:
        raise ValueError(f"unparseable schedule spelling {spelling!r}")
    name, argstr = m.group(1), m.group(2)
    args: List = []
    kwargs: Dict = {}
    if argstr and argstr.strip():
        for tok in argstr.split(","):
            if "=" in tok:
                k, v = tok.split("=", 1)
                kwargs[k.strip()] = _literal(v)
            else:
                args.append(_literal(tok))
    factories = {"immediate": immediate, "buffered": buffered,
                 "sync": sync_barrier, "sync_barrier": sync_barrier}
    try:
        factory = factories[name]
    except KeyError:
        raise ValueError(f"unknown schedule {name!r}; "
                         f"have {sorted(factories)}") from None
    return factory(*args, **kwargs)


# ---------------------------------------------------------------------------
# Arm
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Arm:
    """One fully-specified grid cell: everything the runner needs to build
    and drive an :class:`repro.fl.FLRun`, as plain data.

    ``budget`` is the arm's simulated-time budget (``FLRun(max_time=)``);
    ``max_rounds`` the generous round cap that keeps time — not rounds —
    the binding constraint.  ``group`` is a free-form report-grouping key
    (typically the dataset name plus the grid the arm belongs to).
    """
    strategy: str
    strategy_kwargs: Tuple[Tuple[str, object], ...] = ()
    schedule: str = "immediate"
    pcfg: Tuple[Tuple[str, object], ...] = ()
    scenario: Optional[ScenarioSpec] = None
    seed: int = 0
    budget: Optional[float] = None
    max_rounds: int = 100
    group: str = ""

    def __post_init__(self):
        # dict spellings are friendlier at call sites; store as sorted
        # item-tuples so the dataclass stays hashable/frozen
        for f in ("strategy_kwargs", "pcfg"):
            v = getattr(self, f)
            if isinstance(v, dict):
                object.__setattr__(self, f, tuple(sorted(v.items())))
            else:
                object.__setattr__(self, f, tuple(tuple(kv) for kv in v))
        parse_schedule(self.schedule)      # fail at expansion, not mid-sweep
        strategy(self.strategy, **dict(self.strategy_kwargs))

    @property
    def name(self) -> str:
        kw = ",".join(f"{k}={v}" for k, v in self.strategy_kwargs)
        return (f"{self.strategy}({kw})" if kw else self.strategy) \
            + f"/{self.schedule}" \
            + (f"/seed{self.seed}" if self.seed else "")

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["strategy_kwargs"] = dict(self.strategy_kwargs)
        d["pcfg"] = dict(self.pcfg)
        d["scenario"] = json.loads(self.scenario.to_json()) \
            if self.scenario is not None else None
        return d

    @staticmethod
    def from_dict(d: Dict) -> "Arm":
        d = dict(d)
        if d.get("scenario") is not None:
            d["scenario"] = ScenarioSpec.from_json(json.dumps(d["scenario"]))
        return Arm(**d)

    def fingerprint(self) -> str:
        """Stable content hash over the arm's canonical JSON — the
        journal's resume key.  Covers the budget: the same configuration
        at a larger hillclimb rung is a different trial."""
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def with_budget(self, budget: Optional[float],
                    max_rounds: Optional[int] = None) -> "Arm":
        """The same configuration at a different simulated-time budget
        (hillclimb promotion re-fingerprints through this)."""
        return dataclasses.replace(
            self, budget=budget,
            max_rounds=self.max_rounds if max_rounds is None else max_rounds)


# ---------------------------------------------------------------------------
# SweepSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """The declarative sweep grid (see module docstring).  ``strategies``
    entries are ``{"name": ..., **ctor_kwargs}`` dicts; ``pcfg`` holds
    shared :class:`PersAFLConfig` overrides and ``pcfg_grid`` per-field
    axes the grid products over."""
    strategies: Tuple[Dict, ...]
    schedules: Tuple[str, ...] = ("immediate",)
    pcfg: Tuple[Tuple[str, object], ...] = ()
    pcfg_grid: Tuple[Tuple[str, Tuple], ...] = ()
    scenario: Optional[ScenarioSpec] = None
    seeds: Tuple[int, ...] = (0,)
    group: str = ""

    def __post_init__(self):
        if not self.strategies:
            raise ValueError("need at least one strategy")
        if not self.schedules:
            raise ValueError("need at least one schedule")
        if not self.seeds:
            raise ValueError("need at least one seed")
        object.__setattr__(self, "strategies",
                           tuple(dict(s) for s in self.strategies))
        for s in self.strategies:
            if "name" not in s:
                raise ValueError(f"strategy entry {s} lacks 'name'")
        for f in ("pcfg",):
            v = getattr(self, f)
            if isinstance(v, dict):
                object.__setattr__(self, f, tuple(sorted(v.items())))
        g = self.pcfg_grid
        if isinstance(g, dict):
            object.__setattr__(
                self, "pcfg_grid",
                tuple(sorted((k, tuple(vs)) for k, vs in g.items())))
        else:
            object.__setattr__(
                self, "pcfg_grid",
                tuple((k, tuple(vs)) for k, vs in g))
        object.__setattr__(self, "schedules", tuple(self.schedules))
        object.__setattr__(self, "seeds", tuple(int(s) for s in self.seeds))

    # -- JSON round-trip ---------------------------------------------------

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["pcfg"] = dict(self.pcfg)
        d["pcfg_grid"] = {k: list(vs) for k, vs in self.pcfg_grid}
        d["scenario"] = json.loads(self.scenario.to_json()) \
            if self.scenario is not None else None
        return json.dumps(d, indent=2, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "SweepSpec":
        d = json.loads(s)
        if d.get("scenario") is not None:
            d["scenario"] = ScenarioSpec.from_json(json.dumps(d["scenario"]))
        d["strategies"] = tuple(d["strategies"])
        d["schedules"] = tuple(d["schedules"])
        d["seeds"] = tuple(d["seeds"])
        d["pcfg"] = d.get("pcfg", {})
        d["pcfg_grid"] = d.get("pcfg_grid", {})
        return SweepSpec(**d)

    # -- expansion ---------------------------------------------------------

    def arms(self, *, max_rounds: int,
             budget: Optional[float] = None) -> List[Arm]:
        """Expand the grid: strategies × schedules × pcfg_grid × seeds,
        every cell a fingerprinted :class:`Arm` at the given budget."""
        grid_keys = [k for k, _ in self.pcfg_grid]
        grid_vals = [vs for _, vs in self.pcfg_grid]
        out = []
        for strat, sched, combo, seed in itertools.product(
                self.strategies, self.schedules,
                itertools.product(*grid_vals) if grid_vals else [()],
                self.seeds):
            skw = {k: v for k, v in strat.items() if k != "name"}
            pc = dict(self.pcfg)
            pc.update(zip(grid_keys, combo))
            out.append(Arm(strategy=strat["name"], strategy_kwargs=skw,
                           schedule=sched, pcfg=pc, scenario=self.scenario,
                           seed=seed, budget=budget, max_rounds=max_rounds,
                           group=self.group))
        return out


# ---------------------------------------------------------------------------
# hillclimb (successive halving)
# ---------------------------------------------------------------------------

def promote(scored: Sequence[Tuple[Arm, float]],
            eta: float = 2.0) -> List[Arm]:
    """Keep the top ``ceil(n/eta)`` arms by score (descending; ties break
    deterministically on the arm name, then fingerprint).  Always keeps at
    least one arm; non-finite scores (a diverged rung trial) sort last."""
    if not scored:
        return []
    keep = max(1, math.ceil(len(scored) / float(eta)))

    def key(pair):
        arm, score = pair
        finite = isinstance(score, (int, float)) and math.isfinite(score)
        return (-(score if finite else float("-inf")),
                arm.name, arm.fingerprint())

    return [arm for arm, _ in sorted(scored, key=key)[:keep]]


def rung_arms(arms: Sequence[Arm], budget: Optional[float],
              max_rounds: Optional[int] = None) -> List[Arm]:
    """Re-budget a surviving population onto the next rung (each result is
    a fresh fingerprint — its own resumable trial)."""
    return [a.with_budget(budget, max_rounds) for a in arms]
