"""Arm executor: drives one :class:`repro.fl.FLRun` per :class:`Arm`,
self-stopping through the ``on_eval`` hook, journaling one JSONL row per
trial so a killed sweep resumes by fingerprint skip.

The runner is problem-agnostic: the caller supplies a ``problem(arm)``
factory returning the concrete ingredients —

    {"clients": [...], "loss_fn": f, "init_params": tree,
     "eval_fn": eval, "pcfg": PersAFLConfig(...),        # base config
     "batch_size": 16, "eval_every": 20}                  # optional

— and the runner turns the arm's declarative fields into the live run:
strategy from the registry, schedule via
:func:`repro.tune.space.parse_schedule`, ``PersAFLConfig`` overrides via
``dataclasses.replace``, delays from the arm's
:class:`~repro.fl.scenario.ScenarioSpec` (or a plain
:class:`~repro.fl.DelayModel` on the arm's seed).  Arms sharing a seed
replay *paired* client/delay streams: the counter-based hash streams of
:mod:`repro.fl.delays` make every client's timeline a pure function of
(seed, client, cycle), so two arms differing only in strategy/schedule
see bit-identical event timelines and their scores differ only by what
the tuner varies (regression-pinned in ``tests/test_tune.py``).

Every finished arm appends a :class:`Trial` row to the journal
(``journal.jsonl``); re-running a sweep skips rows whose trial key —
arm fingerprint + stop-rule hash — is already present, so the marginal
cost of resuming is zero and a hillclimb ladder picks up mid-rung.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import PersAFLConfig
from repro.fl.api import FLRun
from repro.fl.delays import DelayModel
from repro.tune.space import Arm, parse_schedule, promote, rung_arms
from repro.tune.stop import StopRule, rule_to_dict

# run.stats counters worth journaling per trial (scheduler + robustness
# observability; missing keys — e.g. on schedules without robust
# admission — are simply absent)
_STAT_KEYS = ("dropouts", "corrupted_rows", "robust_clipped",
              "robust_trimmed", "robust_nonfinite", "mean_cohort_fill",
              "windows")


@dataclasses.dataclass
class Trial:
    """One journaled arm execution (a JSONL row)."""
    key: str
    arm: Arm
    status: str                       # "completed" | "stopped"
    stop_reason: Optional[str]
    stop_rule: Optional[Dict]
    sim_time: float
    rounds: int
    final_acc: float
    final_loss: Optional[float]
    times: List[float]
    acc: List[float]
    loss: List[float]
    staleness_mean: float
    staleness_max: int
    host_materializations: int
    params_finite: bool
    stats: Dict
    wall_s: float
    resumed: bool = False

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["arm"] = self.arm.to_dict()
        return d

    @staticmethod
    def from_dict(d: Dict) -> "Trial":
        d = dict(d)
        d["arm"] = Arm.from_dict(d["arm"])
        return Trial(**d)

    @property
    def score(self) -> float:
        """The hillclimb promotion score (final accuracy; NaN sorts
        last in :func:`repro.tune.space.promote`)."""
        return self.final_acc


def trial_key(arm: Arm, stop_rule: Optional[StopRule]) -> str:
    """Resume key: the arm fingerprint extended by the stop-rule hash —
    an exhaustive trial and a self-stopped trial of the same arm are
    different rows (the former is the latter's superset trace)."""
    fp = arm.fingerprint()
    if stop_rule is None:
        return fp
    blob = json.dumps(rule_to_dict(stop_rule), sort_keys=True)
    return fp + "-" + hashlib.sha256(blob.encode()).hexdigest()[:8]


class TuneRunner:
    """Executes arms against a ``problem`` factory with optional
    self-stopping and a resumable JSONL journal.

    ``stop_rule=None`` runs every arm to its full budget (the exhaustive
    grid); a :class:`repro.tune.stop.StopRule` turns on self-stopping —
    the rule is checked on the live History after every recorded eval and
    a firing halts the event loop through ``FLRun.run(on_eval=...)``.
    """

    def __init__(self, problem: Callable[[Arm], Dict], *,
                 journal: Optional[str] = None,
                 stop_rule: Optional[StopRule] = None,
                 verbose: bool = False):
        self.problem = problem
        self.stop_rule = stop_rule
        self.journal = journal
        self.verbose = verbose
        self._done: Dict[str, Trial] = {}
        if journal and os.path.exists(journal):
            with open(journal) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    t = Trial.from_dict(json.loads(line))
                    self._done[t.key] = t

    # -- journal -----------------------------------------------------------

    def _journal_append(self, trial: Trial) -> None:
        if not self.journal:
            return
        os.makedirs(os.path.dirname(self.journal) or ".", exist_ok=True)
        with open(self.journal, "a") as f:
            f.write(json.dumps(trial.to_dict(), sort_keys=True) + "\n")

    @property
    def completed_keys(self) -> Tuple[str, ...]:
        return tuple(self._done)

    # -- execution ---------------------------------------------------------

    def run_arm(self, arm: Arm) -> Trial:
        """Execute one arm (or return its journaled record, marked
        ``resumed=True``, if this exact trial already completed)."""
        key = trial_key(arm, self.stop_rule)
        if key in self._done:
            prior = self._done[key]
            return dataclasses.replace(prior, resumed=True)

        prob = self.problem(arm)
        clients = prob["clients"]
        pcfg: PersAFLConfig = prob["pcfg"]
        if arm.pcfg:
            pcfg = dataclasses.replace(pcfg, **dict(arm.pcfg))
        delays = arm.scenario.build() if arm.scenario is not None \
            else DelayModel(len(clients), seed=arm.seed)
        from repro.fl.api import strategy as make_strategy
        run = FLRun(clients=clients, loss_fn=prob["loss_fn"],
                    init_params=prob["init_params"], pcfg=pcfg,
                    delays=delays,
                    strategy=make_strategy(arm.strategy,
                                           **dict(arm.strategy_kwargs)),
                    schedule=parse_schedule(arm.schedule),
                    batch_size=prob.get("batch_size", 32), seed=arm.seed)

        stop_reason: List[Optional[str]] = [None]
        on_eval = None
        if self.stop_rule is not None:
            def on_eval(hist, _rule=self.stop_rule):
                reason = _rule.check(hist)
                if reason is not None:
                    stop_reason[0] = reason
                    return "stop"
                return None

        t0 = time.time()
        hist = run.run(max_rounds=arm.max_rounds,
                       eval_every=prob.get("eval_every"),
                       eval_fn=prob["eval_fn"], max_time=arm.budget,
                       on_eval=on_eval, final_eval=True)
        wall = time.time() - t0

        stats = run.stats
        finite = all(np.isfinite(np.asarray(x)).all()
                     for x in jax.tree.leaves(run.state.params))
        trial = Trial(
            key=key, arm=arm,
            status="stopped" if stop_reason[0] is not None else "completed",
            stop_reason=stop_reason[0],
            stop_rule=rule_to_dict(self.stop_rule),
            sim_time=float(hist.end_time),
            rounds=int(run.final_stats["server_rounds"]),
            final_acc=hist.acc[-1] if hist.acc else float("nan"),
            final_loss=hist.loss[-1] if hist.loss else None,
            times=list(hist.times), acc=list(hist.acc),
            loss=list(hist.loss),
            staleness_mean=float(np.mean(hist.staleness))
            if hist.staleness else 0.0,
            staleness_max=int(max(hist.staleness))
            if hist.staleness else 0,
            host_materializations=int(stats["host_materializations"]),
            params_finite=bool(finite),
            stats={k: stats[k] for k in _STAT_KEYS if k in stats},
            wall_s=wall)
        self._done[key] = trial
        self._journal_append(trial)
        if self.verbose:
            print(f"trial,{arm.group},{arm.name},{trial.status},"
                  f"{trial.final_acc:.3f},{trial.sim_time:.0f},"
                  f"{trial.rounds},{trial.stop_reason or ''}", flush=True)
        return trial

    def run_sweep(self, arms: Sequence[Arm]) -> List[Trial]:
        return [self.run_arm(a) for a in arms]

    def run_hillclimb(self, arms: Sequence[Arm],
                      budgets: Sequence[float], *,
                      eta: float = 2.0,
                      max_rounds: Optional[int] = None
                      ) -> List[List[Trial]]:
        """Successive halving: run every survivor at each rung budget,
        promote the top ``ceil(n/eta)`` by final accuracy to the next
        (larger) budget.  Returns the per-rung trial lists; the last
        rung's best trial is the sweep winner.  Every (arm, budget) pair
        is its own journal row, so a killed ladder resumes mid-rung."""
        survivors = list(arms)
        rungs: List[List[Trial]] = []
        for li, budget in enumerate(budgets):
            trials = self.run_sweep(rung_arms(survivors, budget, max_rounds))
            rungs.append(trials)
            if li + 1 < len(budgets):
                survivors = promote([(t.arm, t.score) for t in trials],
                                    eta=eta)
        return rungs
