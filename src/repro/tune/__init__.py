"""`repro.tune` — the self-stopping tuner subsystem.

Declarative sweeps (:class:`SweepSpec` → fingerprinted :class:`Arm`\\ s,
successive-halving ``hillclimb``), pure early-stop rules evaluated on the
live :class:`repro.fl.History` trace, a resumable journaled arm executor
(:class:`TuneRunner`) driving ``FLRun.run(on_eval=...)``, and the
fig2-style report.  See ``experiments/sweeps/joint_tune.py`` for the
end-to-end driver and ``experiments/README.md`` for the surface tour.
"""
from repro.tune.space import (Arm, SweepSpec, parse_schedule,  # noqa: F401
                              promote, rung_arms)
from repro.tune.stop import (AccPlateau, AnyOf, LossSpike,     # noqa: F401
                             MedianLoss, StopRule, default_rules,
                             rule_from_dict, rule_to_dict)
from repro.tune.runner import Trial, TuneRunner, trial_key     # noqa: F401
from repro.tune.report import (make_report, promote_winners,   # noqa: F401
                               to_markdown)
