"""Early-stop rules: pure predicates over the live run trace.

A :class:`StopRule` looks only at the trace recorded so far — the
``acc`` / ``loss`` series of a :class:`repro.fl.History` (any object with
those two list attributes works, which is what makes the rules unit-
testable against hand-built traces).  :meth:`StopRule.check` returns a
human-readable reason string when the rule fires and ``None`` otherwise;
rules never mutate the trace and hold no state, so re-checking a longer
trace is always consistent with having watched it grow.

The three families (the wandb-style convergence-watch idiom):

  * :class:`MedianLoss` — the running-median loss rule: fire when the
    latest eval loss is ``factor``× worse than the running median of the
    recent window.  Catches slow divergence and loss creep that a simple
    best-so-far test misses.
  * :class:`LossSpike` — the divergence abort: fire the moment the loss
    goes non-finite or jumps ``factor``× above the best loss seen.
  * :class:`AccPlateau` — patience on accuracy: fire when the best
    accuracy of the last ``patience`` evals fails to improve on the best
    before them by ``min_delta`` (a monotone improver with a real slope
    never trips it).

Rules compose with :class:`AnyOf` and serialize to/from plain dicts
(:func:`rule_to_dict` / :func:`rule_from_dict`) so a sweep's exact stop
configuration is journaled into every trial record it killed.

Losses are assumed non-negative (cross-entropy-like); the multiplicative
thresholds are meaningless for signed objectives.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Dict, Optional, Tuple


class StopRule:
    """Base: ``check(trace) -> reason-or-None``.  ``trace`` needs ``.acc``
    and ``.loss`` list attributes (a :class:`repro.fl.History` or any
    stand-in)."""

    kind = "base"

    def check(self, trace) -> Optional[str]:
        raise NotImplementedError

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self) if dataclasses.is_dataclass(self) \
            else {}
        d["kind"] = self.kind
        return d


@dataclasses.dataclass(frozen=True)
class MedianLoss(StopRule):
    """Fire when the latest loss exceeds ``factor`` × the running median
    of the previous ``window`` losses (after ``warmup`` evals — early
    noise must not kill an arm that has not settled yet)."""

    window: int = 8
    factor: float = 1.3
    warmup: int = 4
    kind = "median_loss"

    def check(self, trace) -> Optional[str]:
        loss = trace.loss
        if len(loss) <= max(self.warmup, 1):
            return None
        prev = loss[-(self.window + 1):-1]
        finite = [x for x in prev if math.isfinite(x)]
        if not finite:
            return None                  # LossSpike owns the NaN case
        med = statistics.median(finite)
        if math.isfinite(loss[-1]) and loss[-1] > self.factor * med:
            return (f"median_loss: loss {loss[-1]:.4g} > {self.factor}x "
                    f"running median {med:.4g}")
        return None


@dataclasses.dataclass(frozen=True)
class LossSpike(StopRule):
    """Fire on divergence: a non-finite loss, or a loss ``factor``× above
    the best (minimum) loss seen so far."""

    factor: float = 3.0
    warmup: int = 1
    kind = "loss_spike"

    def check(self, trace) -> Optional[str]:
        loss = trace.loss
        if not loss:
            return None
        if not math.isfinite(loss[-1]):
            return f"loss_spike: non-finite loss at eval {len(loss)}"
        if len(loss) <= self.warmup:
            return None
        best = min(x for x in loss[:-1] if math.isfinite(x)) \
            if any(math.isfinite(x) for x in loss[:-1]) else None
        if best is not None and best > 0 and loss[-1] > self.factor * best:
            return (f"loss_spike: loss {loss[-1]:.4g} > {self.factor}x "
                    f"best {best:.4g}")
        return None


@dataclasses.dataclass(frozen=True)
class AccPlateau(StopRule):
    """Fire when accuracy has plateaued: the best of the last ``patience``
    evals improves on the best before them by less than ``min_delta``."""

    patience: int = 5
    min_delta: float = 0.003
    kind = "acc_plateau"

    def check(self, trace) -> Optional[str]:
        acc = trace.acc
        if len(acc) <= self.patience:
            return None
        before = [x for x in acc[:-self.patience] if math.isfinite(x)]
        recent = [x for x in acc[-self.patience:] if math.isfinite(x)]
        if not before or not recent:
            return None
        if max(recent) < max(before) + self.min_delta:
            return (f"acc_plateau: best of last {self.patience} evals "
                    f"{max(recent):.4f} < prior best {max(before):.4f} "
                    f"+ {self.min_delta}")
        return None


@dataclasses.dataclass(frozen=True)
class AnyOf(StopRule):
    """First-match composition: fires with the first member's reason."""

    rules: Tuple[StopRule, ...] = ()
    kind = "any"

    def check(self, trace) -> Optional[str]:
        for rule in self.rules:
            reason = rule.check(trace)
            if reason is not None:
                return reason
        return None

    def to_dict(self) -> Dict:
        return {"kind": self.kind,
                "rules": [r.to_dict() for r in self.rules]}


_RULES = {cls.kind: cls for cls in (MedianLoss, LossSpike, AccPlateau)}


def rule_to_dict(rule: Optional[StopRule]) -> Optional[Dict]:
    return None if rule is None else rule.to_dict()


def rule_from_dict(d: Optional[Dict]) -> Optional[StopRule]:
    if d is None:
        return None
    d = dict(d)
    kind = d.pop("kind")
    if kind == "any":
        return AnyOf(tuple(rule_from_dict(r) for r in d["rules"]))
    try:
        cls = _RULES[kind]
    except KeyError:
        raise ValueError(f"unknown stop rule kind {kind!r}; "
                         f"have {sorted(_RULES) + ['any']}") from None
    return cls(**d)


def default_rules(*, window: int = 8, median_factor: float = 1.3,
                  spike_factor: float = 3.0, patience: int = 5,
                  min_delta: float = 0.003, warmup: int = 4) -> AnyOf:
    """The standard self-stopping bundle: divergence abort, running-median
    loss watch, accuracy-plateau patience."""
    return AnyOf((LossSpike(factor=spike_factor),
                  MedianLoss(window=window, factor=median_factor,
                             warmup=warmup),
                  AccPlateau(patience=patience, min_delta=min_delta)))
