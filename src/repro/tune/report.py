"""Fig2-style sweep report: accuracy at equal simulated time, staleness,
server rounds and host traffic per arm, grouped (typically per dataset ×
grid), with the winning configuration per group — emitted as JSON and as
a markdown table, and promotable into ``examples/`` as plain config
records a script can re-run.

Winner selection is deterministic: best final accuracy, ties broken by
less simulated time consumed (a stopped arm that matched the leader did
it cheaper), then arm name.  Arms whose accuracy is NaN (diverged, or
never evaluated) can never win.
"""
from __future__ import annotations

import json
import math
import os
from typing import Callable, Dict, List, Optional, Sequence

from repro.tune.runner import Trial

_COLS = ("arm", "status", "final_acc", "sim_time", "rounds",
         "staleness_mean", "staleness_max", "host_mat", "stop_reason")


def _row(t: Trial) -> Dict:
    return {
        "arm": t.arm.name,
        "strategy": t.arm.strategy,
        "strategy_kwargs": dict(t.arm.strategy_kwargs),
        "schedule": t.arm.schedule,
        "seed": t.arm.seed,
        "status": t.status + (" (resumed)" if t.resumed else ""),
        "stop_reason": t.stop_reason,
        "final_acc": t.final_acc,
        "final_loss": t.final_loss,
        "sim_time": t.sim_time,
        "budget": t.arm.budget,
        "rounds": t.rounds,
        "staleness_mean": t.staleness_mean,
        "staleness_max": t.staleness_max,
        "host_mat": t.host_materializations,
        "params_finite": t.params_finite,
        "wall_s": t.wall_s,
    }


def _winner_key(t: Trial):
    acc = t.final_acc
    finite = isinstance(acc, (int, float)) and math.isfinite(acc)
    return (-(acc if finite else float("-inf")), t.sim_time, t.arm.name)


def make_report(trials: Sequence[Trial], *,
                group: Optional[Callable[[Trial], str]] = None) -> Dict:
    """Group trials (default: by ``arm.group``) into table rows + a
    winner per group, plus sweep-level cost accounting: total simulated
    time consumed vs the total budget an exhaustive pass would have
    spent (``cost_fraction`` is the self-stopping saving)."""
    group = group or (lambda t: t.arm.group)
    groups: Dict[str, Dict] = {}
    for t in trials:
        groups.setdefault(group(t), {"trials": []})["trials"].append(t)
    out_groups = {}
    for gname, g in sorted(groups.items()):
        ts: List[Trial] = g["trials"]
        win = min(ts, key=_winner_key)
        budget_total = sum(t.arm.budget if t.arm.budget is not None
                           else t.sim_time for t in ts)
        spent = sum(t.sim_time for t in ts)
        out_groups[gname] = {
            "rows": [_row(t) for t in ts],
            "winner": _row(win),
            "n_arms": len(ts),
            "n_stopped": sum(1 for t in ts if t.status == "stopped"),
            "n_resumed": sum(1 for t in ts if t.resumed),
            "sim_time_spent": spent,
            "sim_time_budget": budget_total,
            "cost_fraction": spent / budget_total if budget_total else 1.0,
        }
    return {"groups": out_groups,
            "n_trials": len(trials),
            "n_stopped": sum(1 for t in trials if t.status == "stopped"),
            "n_resumed": sum(1 for t in trials if t.resumed)}


def to_markdown(report: Dict, title: str = "Sweep report") -> str:
    """Render the report as fig2-style markdown tables, one per group."""
    lines = [f"# {title}", ""]
    for gname, g in report["groups"].items():
        lines += [f"## {gname}", ""]
        lines.append(
            f"{g['n_arms']} arms, {g['n_stopped']} stopped early, "
            f"{g['n_resumed']} resumed from journal; simulated time spent "
            f"{g['sim_time_spent']:.0f}s of {g['sim_time_budget']:.0f}s "
            f"budget ({100 * g['cost_fraction']:.0f}%).")
        lines += ["", "| " + " | ".join(_COLS) + " |",
                  "|" + "---|" * len(_COLS)]
        for r in g["rows"]:
            win = " **(winner)**" if r["arm"] == g["winner"]["arm"] \
                and r["schedule"] == g["winner"]["schedule"] else ""
            lines.append(
                "| " + " | ".join([
                    r["arm"] + win, r["status"],
                    f"{r['final_acc']:.3f}",
                    f"{r['sim_time']:.0f}", str(r["rounds"]),
                    f"{r['staleness_mean']:.2f}",
                    str(r["staleness_max"]), str(r["host_mat"]),
                    (r["stop_reason"] or "—").split(":")[0],
                ]) + " |")
        lines.append("")
    return "\n".join(lines)


def promote_winners(report: Dict, path: str, *,
                    extra: Optional[Dict] = None) -> Dict:
    """Write the per-group winning configurations (strategy, kwargs,
    schedule, seed + scores) as JSON at ``path`` — the record
    ``examples/run_tuned.py`` replays."""
    winners = {g: {k: v for k, v in info["winner"].items()
                   if k in ("arm", "strategy", "strategy_kwargs",
                            "schedule", "seed", "final_acc", "sim_time",
                            "rounds")}
               for g, info in report["groups"].items()}
    blob = {"winners": winners, **(extra or {})}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)
    return blob
