"""Baseline local-update rules the paper compares against (§5) — the pure
per-client math behind the registry strategies of :mod:`repro.fl.api`.

FedAvg / FedAsync / Per-FedAvg / pFedMe reuse Algorithm 2's Options A/B/C
(``strategy("fedavg")`` etc. are option presets of ``PersAFLStrategy``).
FedProx and SCAFFOLD (Option I) need bespoke local steps, implemented here
with the same scanned-delta structure as ``repro.core.client`` and wrapped
by ``strategy("fedprox", mu=...)`` / ``strategy("scaffold")``.  Since PR 4
both run *through the cohort engine* — vmapped over the cohort axis with
SCAFFOLD's control variates threaded as a stacked client-state pytree —
rather than the old sequential per-client jit loop; these functions stay
jit-traceable with every non-pytree argument static-free for exactly that
reason.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.client import _current_w, _zeros_f32
from repro.core.maml import tree_norm
from repro.core.types import PersAFLConfig

Loss = Callable


def fedprox_update(pcfg: PersAFLConfig, loss_fn: Loss, params, batches,
                   mu: float = 0.1) -> Tuple:
    """FedProx [42]: local SGD on f_i(w) + μ/2 ‖w − w^t‖²."""
    def step(delta, batch_q):
        w = _current_w(params, delta)
        g = jax.grad(loss_fn)(w, batch_q)
        # prox term: ∇ μ/2‖w − w0‖² = μ(w − w0) = −μ·Δ
        g = jax.tree.map(lambda gg, d: gg + (-mu * d).astype(gg.dtype),
                         g, delta)
        delta = jax.tree.map(
            lambda d, gg: d + pcfg.eta * gg.astype(jnp.float32), delta, g)
        return delta, tree_norm(g)

    delta, gnorms = jax.lax.scan(step, _zeros_f32(params), batches)
    return delta, {"grad_norm_mean": jnp.mean(gnorms),
                   "delta_norm": tree_norm(delta)}


def scaffold_update(pcfg: PersAFLConfig, loss_fn: Loss, params, batches,
                    c_global, c_i) -> Tuple:
    """SCAFFOLD [34] (Option I) local update.

    w ← w − η (g − c_i + c);   c_i⁺ = ∇f_i(w^t) (fresh pass at the server
    model, the paper's more-stable Option I);  Δc = c_i⁺ − c_i.
    Returns (delta, new_c_i, metrics).
    """
    def step(delta, batch_q):
        w = _current_w(params, delta)
        g = jax.grad(loss_fn)(w, batch_q)
        g = jax.tree.map(
            lambda gg, ci, cg: gg + (cg - ci).astype(gg.dtype),
            g, c_i, c_global)
        delta = jax.tree.map(
            lambda d, gg: d + pcfg.eta * gg.astype(jnp.float32), delta, g)
        return delta, tree_norm(g)

    delta, gnorms = jax.lax.scan(step, _zeros_f32(params), batches)
    # Option I: c_i+ = grad at the *server* model on one more data pass
    first_batch = jax.tree.map(lambda x: x[0], batches)
    c_new = jax.tree.map(lambda g: g.astype(jnp.float32),
                         jax.grad(loss_fn)(params, first_batch))
    return delta, c_new, {"grad_norm_mean": jnp.mean(gnorms),
                          "delta_norm": tree_norm(delta)}
