"""Vectorized cohort execution engine.

The discrete-event simulators used to dispatch one jitted ``client_update``
per event — simulating n concurrent clients cost O(n) sequential device
calls.  This engine restores the data-parallelism the paper's setting has by
construction: between two server applies the global params are *frozen*, so
every client whose compute window falls in that interval sees the same
weights and their Q-step local updates are embarrassingly parallel.

Architecture (DESIGN.md §2 extension):

  * :class:`CohortEngine` compiles ONE cohort-mapped jitted kernel and
    reuses it for the whole run — ``jax.vmap`` over clients on TPU (SIMD
    batching), ``lax.map`` on CPU (dispatch amortization without XLA-CPU's
    poor batched-GEMM lowering); see ``cohort_impl``.  Cohorts are padded
    up to power-of-two buckets so the jit cache stays O(log max_cohort)
    instead of one compile per cohort size.
  * The stacked batch buffer is donated (``donate_argnums``) so XLA may
    reuse its pages for the per-client delta stack — the cohort call is a
    single device round-trip regardless of cohort size.
  * Simulators defer per-client compute: batches are recorded at
    download-completion time and materialized lazily in one cohort call
    right before the next server apply.  Every delta is therefore computed
    on exactly the params snapshot the sequential per-event path would have
    used — the vectorized path is a performance transform, not a semantics
    change (``tests/test_engine.py`` pins the equivalence for options
    A/B/C).

The per-event sequential path is kept behind ``vectorized=False`` as the
baseline the ``engine`` benchmark row measures against.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import client_update, split_batches_for_option
from repro.core.types import PersAFLConfig
from repro.kernels.fused_update.ops import donate_argnums


def _stack(batch_list: List):
    """Stack per-client batch pytrees along a new cohort axis.

    Host (numpy) leaves — the data pipeline's native output — are stacked
    host-side in one memcpy per leaf; device leaves fall back to jnp.stack.
    """
    if all(isinstance(leaf, np.ndarray)
           for leaf in jax.tree.leaves(batch_list[0])):
        return jax.tree.map(lambda *xs: np.stack(xs), *batch_list)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)


class CohortEngine:
    """Batched ``client_update`` over a cohort of clients.

    One engine instance owns the jit caches; simulators create it once per
    run so recompiles never land on the event loop's hot path.

    ``cohort_impl`` picks how the cohort axis is mapped inside the single
    jitted call:
      * ``"vmap"`` — SIMD batching over clients (default on TPU: the MXU
        eats the extra batch dim for free and the whole cohort is one
        kernel launch).
      * ``"map"``  — ``lax.map`` over clients (default on CPU: one dispatch
        amortized over the cohort, but per-client compute stays sequential
        — XLA-CPU lowers batched GEMMs poorly, so vmap can *lose* to
        per-event dispatch there).
    Both are the same math; ``"auto"`` selects by backend.
    """

    def __init__(self, pcfg: PersAFLConfig, loss_fn: Callable, *,
                 vectorized: bool = True, cohort_impl: str = "auto"):
        self.pcfg = pcfg
        self.loss_fn = loss_fn
        self.vectorized = vectorized
        if cohort_impl == "auto":
            cohort_impl = "vmap" if jax.default_backend() == "tpu" else "map"
        self.cohort_impl = cohort_impl
        self.stats: Dict[str, int] = {"cohort_calls": 0, "clients": 0,
                                      "max_cohort": 0}

        def _one(params, batches_3q):
            batches = split_batches_for_option(pcfg.option, batches_3q)
            # metrics are dropped so XLA dead-code-eliminates the per-step
            # norm reductions — schedulers only consume the delta
            delta, _ = client_update(pcfg, loss_fn, params, batches)
            return delta

        self._jit_one = jax.jit(_one)
        donate = donate_argnums(1)
        if cohort_impl == "vmap":
            cohort_fn = lambda params, stacked: jax.vmap(  # noqa: E731
                lambda b: _one(params, b))(stacked)
        elif cohort_impl == "map":
            cohort_fn = lambda params, stacked: jax.lax.map(  # noqa: E731
                lambda b: _one(params, b), stacked)
        else:
            raise ValueError(f"unknown cohort_impl {cohort_impl!r}")
        self._jit_cohort = jax.jit(cohort_fn, donate_argnums=donate)

    @staticmethod
    def _bucket(k: int) -> int:
        return 1 << max(k - 1, 0).bit_length()

    def _stacked_call(self, params, batch_list: List):
        """Pad to the bucket size, record stats, run the jitted cohort."""
        k = len(batch_list)
        self.stats["cohort_calls"] += 1
        self.stats["clients"] += k
        self.stats["max_cohort"] = max(self.stats["max_cohort"], k)
        padded = list(batch_list) + [batch_list[-1]] * (self._bucket(k) - k)
        return self._jit_cohort(params, _stack(padded))

    def update_cohort(self, params, batch_list: List) -> List:
        """Run ``client_update`` for every client in the cohort.

        ``batch_list``: one 3Q-leading-dim batch pytree per client (the raw
        ``sample_batches`` output).  Returns the per-client delta pytrees in
        the same order.  All clients are computed against the same
        ``params`` — the caller guarantees no server apply happened inside
        the cohort's window.
        """
        k = len(batch_list)
        if k == 0:
            return []
        if not self.vectorized:
            self.stats["cohort_calls"] += 1
            self.stats["clients"] += k
            self.stats["max_cohort"] = max(self.stats["max_cohort"], k)
            return [self._jit_one(params, b) for b in batch_list]
        deltas = self._stacked_call(params, batch_list)
        # one device->host transfer, then k free numpy views: unstacking on
        # device would cost k×leaves slice dispatches — more than the
        # cohort call itself for small models.  (Keeping applies entirely
        # on-device from the stacked buffer is the multi-device follow-up —
        # see ROADMAP open items.)
        host = jax.device_get(deltas)
        return [jax.tree.map(lambda x: x[i], host) for i in range(k)]

    def update_cohort_mean(self, params, batch_list: List):
        """Cohort deltas reduced to their mean (sync FedAvg-family rounds).

        Padding clients are masked out of the reduction.
        """
        k = len(batch_list)
        if k == 0:
            raise ValueError("cohort mean over an empty batch_list")
        if not self.vectorized:
            deltas = self.update_cohort(params, batch_list)
            return jax.tree.map(lambda *xs: sum(xs) / k, *deltas)
        deltas = self._stacked_call(params, batch_list)
        return jax.tree.map(lambda x: jnp.mean(x[:k], axis=0), deltas)
