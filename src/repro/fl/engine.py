"""Vectorized cohort execution engine with an on-device DeltaBank.

The discrete-event simulators used to dispatch one jitted ``client_update``
per event — simulating n concurrent clients cost O(n) sequential device
calls.  This engine restores the data-parallelism the paper's setting has by
construction: between two server applies the global params are *frozen*, so
every client whose compute window falls in that interval sees the same
weights and their Q-step local updates are embarrassingly parallel.

Architecture (DESIGN.md §2 extension):

  * :class:`CohortEngine` compiles ONE cohort-mapped jitted kernel and
    reuses it for the whole run — ``jax.vmap`` over clients on TPU (SIMD
    batching), ``lax.map`` on CPU (dispatch amortization without XLA-CPU's
    poor batched-GEMM lowering), or ``shard_map`` splitting the cohort axis
    over every addressable device; see ``cohort_impl``.  Cohorts are padded
    up to power-of-two buckets so the jit cache stays O(log max_cohort)
    instead of one compile per cohort size.
  * The stacked batch buffer is donated (``donate_argnums``) so XLA may
    reuse its pages for the per-client delta stack — the cohort call is a
    single device round-trip regardless of cohort size.
  * Simulators defer per-client compute: batches are recorded at
    download-completion time and materialized lazily in one cohort call
    right before the next server apply.  Every delta is therefore computed
    on exactly the params snapshot the sequential per-event path would have
    used — the vectorized path is a performance transform, not a semantics
    change (``tests/test_engine.py`` pins the equivalence for options
    A/B/C).

DeltaBank contract:

  * ``update_cohort`` returns a :class:`DeltaBank` — a handle to the
    stacked ``[bucket, ...]`` per-client delta buffer that STAYS ON DEVICE.
    The bank owns the buffer; the engine never touches it again after
    returning it, and the caller keeps it alive for exactly as long as any
    of its rows is still unapplied (the buffered scheduler holds banks
    across flush windows for in-flight clients).
  * Bulk consumers (buffered/sync applies) read ``bank.stacked`` and reduce
    it on device through ``kernels/fused_update.apply_rows_tree`` with a
    per-row weight vector — β/M, staleness damping and padding masks are
    all rows of one ``[bucket]`` array, so no per-client delta ever crosses
    to the host (``stats["host_materializations"]`` counts the banks that
    did; a buffered run keeps it at 0).
  * Row consumers (the paper-faithful immediate apply) call ``bank.row(i)``
    /iterate the bank: the FIRST access performs one device→host transfer
    of the whole stack, after which every row is a free numpy view — the
    same single round-trip the pre-bank engine paid, now lazy.
  * In ``cohort_impl="shard_map"`` the buffer is sharded over the cohort
    mesh axis; ``row()`` gathers (host materialization), while
    ``apply_rows_tree``/``update_cohort_mean`` reduce it on device.  On
    the 2-D ``("cohort", "model")`` mesh the bank's model dims are
    additionally split along "model" (an explicit post-cohort reshard to
    ``P("cohort", *param_spec)`` per leaf, derived from the params'
    shardings; the cohort compute itself runs model-replicated — see
    ``repro.sharding.ctx``), and per-bank gathers (``client_state``,
    ``stacked``) stay sharded — gather-not-transfer on both axes.

Strategy contract (PR 4, ``repro.fl.api``):

  * The local update rule is pluggable: pass a bound
    :class:`repro.fl.api.Strategy` and the engine cohort-maps
    ``strategy.local_update(params, batches, cstate)`` instead of the
    built-in Algorithm-2 ``client_update``.  Stateful strategies (SCAFFOLD
    control variates) thread a *stacked client-state pytree* through the
    same vmap/lax.map/shard_map machinery: ``update_cohort(...,
    cstate_list=...)`` stacks the per-client states along the cohort axis
    and the returned bank carries the updated stack
    (:meth:`DeltaBank.client_state`).  FedProx/SCAFFOLD are thereby
    first-class cohort-engine citizens — their deltas land in the
    DeltaBank like everyone else's.  (The pre-PR-4 ``client_fn=``
    override was removed in PR 10: wrap the rule in a Strategy.)
  * A strategy with ``personal_subset`` set returns deltas in the pruned
    subset structure (``repro.core.subset``): the bank's stacked buffer —
    and everything downstream of it (ring rows, head cache, wire frames) —
    carries only the personal leaves.  The engine is structure-agnostic:
    vmap/lax.map stack whatever the rule returns, and the shard_map path
    uses pytree-prefix out_specs for the same reason.

The per-event sequential path is kept behind ``vectorized=False`` as the
baseline the ``engine`` benchmark row measures against.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import client_update, split_batches_for_option
from repro.core.types import PersAFLConfig
from repro.kernels.fused_update.ops import donate_argnums
from repro.sharding.ctx import (active_mesh, cohort_axis_size, cohort_mesh,
                                shard_map_compat)


def _stack(batch_list: List):
    """Stack per-client batch pytrees along a new cohort axis.

    Host (numpy) leaves — the data pipeline's native output — are stacked
    host-side in one memcpy per leaf; device leaves fall back to jnp.stack.
    """
    if all(isinstance(leaf, np.ndarray)
           for leaf in jax.tree.leaves(batch_list[0])):
        return jax.tree.map(lambda *xs: np.stack(xs), *batch_list)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)


class DeltaBank:
    """Handle to a stacked ``[capacity, ...]`` per-client delta buffer.

    ``stacked`` is the on-device buffer (rows ≥ ``k`` are bucket padding);
    rows cross to the host only through :meth:`row` — one transfer of the
    whole stack on first access, numpy views afterwards.  Iterating yields
    the ``k`` real rows in cohort order.
    """

    def __init__(self, stacked=None, k: int = 0,
                 stats: Optional[Dict] = None, rows: Optional[List] = None,
                 cstates=None, cstate_rows: Optional[List] = None):
        self._stacked = stacked
        self._rows = rows          # per-event path: one delta tree per row
        self.k = k if rows is None else len(rows)
        self._stats = stats if stats is not None else {}
        self._host = None
        # stateful-strategy runs: the updated per-client states, stacked
        # along the same cohort axis (or one tree per row, per-event path)
        self._cstates = cstates
        self._cstate_rows = cstate_rows

    @property
    def capacity(self) -> int:
        if self._rows is not None:
            return self.k
        tree = self._stacked if self._stacked is not None else self._host
        return jax.tree.leaves(tree)[0].shape[0]

    @property
    def stacked(self):
        """The ``[capacity, ...]`` device buffer (stacks lazily when the
        bank was built from per-event row deltas; re-uploads if host
        materialization already released the device copy)."""
        if self._stacked is None:
            if self._rows is not None:
                self._stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                             *self._rows)
            else:
                self._stacked = jax.device_put(self._host)
        return self._stacked

    def row(self, i: int):
        """Materialize row ``i`` on the host (lazy, whole-stack-at-once)."""
        if self._rows is not None:
            return self._rows[i]
        if self._host is None:
            self._stats["host_materializations"] = \
                self._stats.get("host_materializations", 0) + 1
            self._host = jax.device_get(self._stacked)
            # release the device buffer — rows serve from host views now,
            # and holding both copies would double delta residency exactly
            # where the bank was meant to shrink it
            self._stacked = None
        return jax.tree.map(lambda x: x[i], self._host)

    def client_state(self, i: int):
        """Row ``i``'s updated client state (stateful strategies only) — a
        lazy device-side gather from the stacked state buffer; never a host
        materialization."""
        if self._cstate_rows is not None:
            return self._cstate_rows[i]
        if self._cstates is None:
            raise ValueError("bank carries no client states "
                             "(stateless strategy)")
        return jax.tree.map(lambda x: x[i], self._cstates)

    def __len__(self) -> int:
        return self.k

    def __getitem__(self, i: int):
        if not -self.k <= i < self.k:
            raise IndexError(i)
        return self.row(i % self.k)

    def __iter__(self):
        return (self.row(i) for i in range(self.k))


class CohortEngine:
    """Batched ``client_update`` over a cohort of clients.

    One engine instance owns the jit caches; simulators create it once per
    run so recompiles never land on the event loop's hot path.

    ``cohort_impl`` picks how the cohort axis is mapped inside the single
    jitted call:
      * ``"vmap"`` — SIMD batching over clients (default on TPU: the MXU
        eats the extra batch dim for free and the whole cohort is one
        kernel launch).
      * ``"map"``  — ``lax.map`` over clients (default on CPU: one dispatch
        amortized over the cohort, but per-client compute stays sequential
        — XLA-CPU lowers batched GEMMs poorly, so vmap can *lose* to
        per-event dispatch there).
      * ``"shard_map"`` — the cohort axis is split over the mesh's
        "cohort" axis (8-way forced-host-device CPU and TPU pods alike);
        each cohort slice lax.maps its local rows, and the delta buffer
        comes back sharded over the mesh — it never gathers unless a row
        is materialized.  Buckets round up to a cohort-slice-count
        multiple.
    All are the same math; ``"auto"`` selects vmap/map by backend.

    ``mesh`` picks the layout for the shard_map path: the 1-D
    ``("cohort",)`` mesh (default), or a 2-D ``("cohort", "model")`` mesh
    from :func:`repro.sharding.ctx.cohort_model_mesh` — the shard_map
    body stays Manual over "cohort" ONLY (the in/out ``P("cohort")``
    pytree prefixes describe just the manual axis), while the "model"
    axis is left to the Auto partitioner: params constrained by
    ``param_shardings`` (a params-shaped pytree of ``NamedSharding``s,
    e.g. from :func:`repro.sharding.rules.param_shardings`) propagate
    their model-axis placement through the per-row update, so the bank's
    rows come back split along BOTH axes.  The masked cohort mean is one
    ``psum("cohort")`` per leaf and never crosses "model" — a
    cross-model reduction would re-reduce within each row.  When no
    ``mesh`` is passed, the ambient :func:`repro.sharding.ctx.use_mesh`
    context (if any) is consulted before the memoized 1-D default.
    """

    def __init__(self, pcfg: PersAFLConfig, loss_fn: Callable, *,
                 vectorized: bool = True, cohort_impl: str = "auto",
                 client_fn=None, strategy=None, mesh=None,
                 param_shardings=None):
        if client_fn is not None:
            raise TypeError(
                "CohortEngine(client_fn=...) was removed in PR 10 (it was "
                "deprecated since PR 4): wrap the update rule in a "
                "repro.fl.api.Strategy and pass strategy=... — e.g. "
                "strategy('personalize', mode='C') for the serving "
                "override it used to spell.")
        self.pcfg = pcfg
        self.loss_fn = loss_fn
        self.vectorized = vectorized
        if cohort_impl == "auto":
            cohort_impl = "vmap" if jax.default_backend() == "tpu" else "map"
        self.cohort_impl = cohort_impl
        self.stats: Dict[str, int] = {"cohort_calls": 0, "clients": 0,
                                      "max_cohort": 0, "padding_waste": 0,
                                      "host_materializations": 0}
        # window-boundary hooks: every bank this engine produces is handed
        # to each registered callback before update_cohort returns — the
        # handoff point the serving ring uses to retain banks (and their
        # device residency) across flush windows without the scheduler
        # knowing the ring exists.
        self._bank_hooks: List[Callable[[DeltaBank], None]] = []

        self.strategy = strategy
        self.stateful = bool(strategy is not None
                             and getattr(strategy, "stateful", False))
        if strategy is not None:
            def _one(params, batches):
                # metrics are dropped so XLA dead-code-eliminates the
                # per-step norm reductions — schedulers only consume the
                # delta
                delta, _, _ = strategy.local_update(params, batches, None)
                return delta

            def _one_s(params, batches, cstate, shared):
                # shared state (SCAFFOLD's c_global) is a separate
                # REPLICATED input — one device copy per cohort call, not
                # one per cohort row — recombined with the client's state
                # row inside the traced body
                delta, new_cstate, _ = strategy.local_update(
                    params, batches,
                    strategy.assemble_state(cstate, shared))
                return delta, new_cstate
        else:
            def _one(params, batches_3q):
                batches = split_batches_for_option(pcfg.option, batches_3q)
                delta, _ = client_update(pcfg, loss_fn, params, batches)
                return delta
            _one_s = None

        self._jit_one = jax.jit(_one)
        self._jit_one_s = jax.jit(_one_s) if self.stateful else None
        self._ndev = 1
        self._jit_cohort_sum = None
        self._jit_cohort_s = None
        donate = donate_argnums(1)
        if cohort_impl == "vmap":
            cohort_fn = lambda params, stacked: jax.vmap(  # noqa: E731
                lambda b: _one(params, b))(stacked)
            cohort_s_fn = lambda params, stacked, cstates, shared: \
                jax.vmap(lambda b, c: _one_s(params, b, c,
                                             shared))(stacked, cstates)
        elif cohort_impl == "map":
            cohort_fn = lambda params, stacked: jax.lax.map(  # noqa: E731
                lambda b: _one(params, b), stacked)
            cohort_s_fn = lambda params, stacked, cstates, shared: \
                jax.lax.map(lambda bc: _one_s(params, bc[0], bc[1], shared),
                            (stacked, cstates))
        elif cohort_impl == "shard_map":
            from jax.sharding import NamedSharding, PartitionSpec as P
            self._mesh = mesh if mesh is not None \
                else (active_mesh() or cohort_mesh())
            if "cohort" not in self._mesh.axis_names:
                raise ValueError(
                    f"cohort_impl='shard_map' needs a mesh with a 'cohort' "
                    f"axis, got axes {self._mesh.axis_names}; build one "
                    f"with repro.sharding.ctx.cohort_model_mesh()")
            # _ndev is the COHORT-AXIS size, not the device count: it
            # drives bucket rounding and the batcher's user→cohort-slice
            # keying, and on a ("cohort", "model") mesh each cohort slice
            # is a model-parallel device group
            self._ndev = cohort_axis_size(self._mesh)
            self._param_shardings = param_shardings

            # the shard_map below is Manual over EVERY mesh axis.  Cohort
            # rows split over "cohort"; params enter replicated (P() in-
            # spec) so each row's update is full-size local math — no
            # cross-"model" collective ever runs inside a grad, whose
            # reductions would otherwise reassociate with the model-axis
            # size and break bit-parity across mesh layouts.  (A partially-
            # Auto model axis would shard the compute too, but jax 0.4.x
            # hard-crashes XLA on any scan under subgroup-manual spmd —
            # and real archs scan everywhere.)  The model axis shards
            # STORAGE: _bank_constrain re-shards the delta stack on the
            # way out, and the server device_puts params/snapshots.
            _all_axes = tuple(self._mesh.axis_names)

            def _gather(params):
                # explicit replicate of model-sharded params before the
                # Manual region (device-to-device all-gather, one per
                # cohort call, never a host materialization); also keeps
                # shard_map from seeing an input whose committed sharding
                # disagrees with its P() in-spec
                if param_shardings is None:
                    return params
                repl = NamedSharding(self._mesh, P())
                return jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(x, repl),
                    params)

            def _bank_constrain(stack):
                # re-shard the delta stack for storage: each param leaf's
                # model-axis spec, with the cohort axis prepended for the
                # row dim — P(None, "model") params make P("cohort", None,
                # "model") bank rows.  Pure placement (each device keeps
                # its slice of rows it already holds replicated): bits
                # never change, so parity with the 1-D path survives.
                # Subset-pruned delta trees don't match the full-params
                # sharding tree — they stay cohort-sharded only.
                if param_shardings is None:
                    return stack
                try:
                    sh = jax.tree.map(
                        lambda s: NamedSharding(
                            self._mesh, P("cohort", *s.spec)),
                        param_shardings)
                    return jax.tree.map(
                        jax.lax.with_sharding_constraint, stack, sh)
                except ValueError:
                    return stack

            def _shard_body(params, stacked):
                return jax.lax.map(lambda b: _one(params, b), stacked)

            def cohort_fn(params, stacked):
                # out_specs is a pytree PREFIX: a bare P("cohort") covers
                # whatever structure the strategy's delta takes — full
                # params-shaped or a pruned personal_subset tree (which a
                # params-shaped spec tree could not describe).  Only the
                # manual "cohort" axis appears in the specs: the "model"
                # axis (if the mesh has one) stays Auto.
                out = shard_map_compat(
                    _shard_body, mesh=self._mesh,
                    in_specs=(jax.tree.map(lambda _: P(), params),
                              jax.tree.map(lambda _: P("cohort"), stacked)),
                    out_specs=P("cohort"),
                    manual_axes=_all_axes)(_gather(params), stacked)
                return _bank_constrain(out)

            def _shard_body_s(params, stacked, cstates, shared):
                return jax.lax.map(
                    lambda bc: _one_s(params, bc[0], bc[1], shared),
                    (stacked, cstates))

            def cohort_s_fn(params, stacked, cstates, shared):
                # pytree-prefix specs: every leaf of the stacked batch /
                # state buffers is split on the cohort axis, params and the
                # shared state replicated; outputs (delta stack, cstate
                # stack) come back cohort-sharded
                delta, cs = shard_map_compat(
                    _shard_body_s, mesh=self._mesh,
                    in_specs=(P(), P("cohort"), P("cohort"), P()),
                    out_specs=(P("cohort"), P("cohort")),
                    manual_axes=_all_axes)(_gather(params), stacked,
                                           cstates, shared)
                return _bank_constrain(delta), cs

            def _sum_body(params, stacked, mask):
                deltas = jax.lax.map(lambda b: _one(params, b), stacked)
                local = jax.tree.map(
                    lambda d: jnp.tensordot(mask, d.astype(jnp.float32),
                                            axes=(0, 0)), deltas)
                # the whole cohort reduction is this ONE psum per leaf,
                # over "cohort" ONLY — the model axis (Auto) already holds
                # every row replicated, so a psum crossing "model" would
                # multiply the sum by the model-axis size
                return jax.tree.map(lambda x: jax.lax.psum(x, "cohort"),
                                    local)

            def sum_fn(params, stacked, mask):
                return shard_map_compat(
                    _sum_body, mesh=self._mesh,
                    in_specs=(jax.tree.map(lambda _: P(), params),
                              jax.tree.map(lambda _: P("cohort"), stacked),
                              P("cohort")),
                    out_specs=jax.tree.map(lambda _: P(), params),
                    manual_axes=_all_axes)(_gather(params), stacked,
                                           mask)

            self._jit_cohort_sum = jax.jit(sum_fn,
                                           donate_argnums=donate)
        else:
            raise ValueError(f"unknown cohort_impl {cohort_impl!r}")
        self._jit_cohort = jax.jit(cohort_fn, donate_argnums=donate)
        if self.stateful:
            # the stacked batch buffer is still donated; the stacked
            # cstate input is NOT — its rows alias the caller's per-client
            # state trees only through a fresh stack, but post_round hooks
            # may still read the old trees
            self._jit_cohort_s = jax.jit(cohort_s_fn, donate_argnums=donate)

    def add_bank_hook(self, fn: Callable[["DeltaBank"], None]) -> None:
        """Register a bank-handoff callback (serving ring retention, stats
        scrapers).  Called once per ``update_cohort`` with the new bank."""
        self._bank_hooks.append(fn)

    def _emit(self, bank: "DeltaBank") -> "DeltaBank":
        for hook in self._bank_hooks:
            hook(bank)
        return bank

    def _bucket(self, k: int) -> int:
        """Pow2 bucket, rounded up to a cohort-slice-count multiple when
        the cohort axis is sharded (every cohort slice gets equal rows; on
        the 2-D mesh a slice is a whole model-parallel device group, so a
        2×4 mesh rounds to multiples of 2, not 8)."""
        pow2 = 1 << max(k - 1, 0).bit_length()
        if self._ndev > 1:
            per_dev = -(-k // self._ndev)
            return self._ndev * (1 << max(per_dev - 1, 0).bit_length())
        return pow2

    def _pad_stack(self, batch_list: List):
        """Pad to the bucket size, record stats, stack host-side."""
        k = len(batch_list)
        bucket = self._bucket(k)
        self.stats["cohort_calls"] += 1
        self.stats["clients"] += k
        self.stats["max_cohort"] = max(self.stats["max_cohort"], k)
        self.stats["padding_waste"] += bucket - k
        padded = list(batch_list) + [batch_list[-1]] * (bucket - k)
        return _stack(padded), k, bucket

    def update_cohort(self, params, batch_list: List,
                      cstate_list: Optional[List] = None) -> DeltaBank:
        """Run the local update rule for every client in the cohort.

        ``batch_list``: one 3Q-leading-dim batch pytree per client (the raw
        ``sample_batches`` output).  Returns a :class:`DeltaBank` over the
        per-client deltas in the same order — the stacked buffer stays on
        device; iterate / ``row(i)`` for host materialization.  All clients
        are computed against the same ``params`` — the caller guarantees no
        server apply happened inside the cohort's window.

        Stateful strategies pass ``cstate_list`` — one dispatch-ready
        client-state pytree per client, stacked along the cohort axis and
        threaded through the same vmap/map/shard_map call; updated states
        come back on the bank (:meth:`DeltaBank.client_state`).
        """
        if self.stateful != (cstate_list is not None):
            raise ValueError("cstate_list must be given exactly when the "
                             "engine's strategy is stateful")
        k = len(batch_list)
        if k == 0:
            return self._emit(DeltaBank(rows=[], stats=self.stats))
        if not self.vectorized:
            self.stats["cohort_calls"] += 1
            self.stats["clients"] += k
            self.stats["max_cohort"] = max(self.stats["max_cohort"], k)
            if cstate_list is not None:
                shared = self.strategy.shared_state()
                outs = [self._jit_one_s(params, b, c, shared)
                        for b, c in zip(batch_list, cstate_list)]
                return self._emit(DeltaBank(
                    rows=[d for d, _ in outs], stats=self.stats,
                    cstate_rows=[c for _, c in outs]))
            return self._emit(DeltaBank(rows=[self._jit_one(params, b)
                                              for b in batch_list],
                                        stats=self.stats))
        if cstate_list is not None:
            stacked, k, bucket = self._pad_stack(batch_list)
            padded_cs = list(cstate_list) + \
                [cstate_list[-1]] * (bucket - len(cstate_list))
            cstacked = jax.tree.map(lambda *xs: jnp.stack(xs), *padded_cs)
            deltas, new_cs = self._jit_cohort_s(
                params, stacked, cstacked, self.strategy.shared_state())
            return self._emit(DeltaBank(stacked=deltas, k=k,
                                        stats=self.stats, cstates=new_cs))
        stacked, k, _ = self._pad_stack(batch_list)
        return self._emit(DeltaBank(stacked=self._jit_cohort(params,
                                                             stacked),
                                    k=k, stats=self.stats))

    def update_cohort_mean(self, params, batch_list: List):
        """Cohort deltas reduced to their mean (sync FedAvg-family rounds).

        Padding clients are masked out of the reduction; in shard_map mode
        the mask-weighted sum happens inside the sharded region and the
        cross-device reduction is a single psum per leaf.
        """
        k = len(batch_list)
        if k == 0:
            raise ValueError("cohort mean over an empty batch_list")
        if not self.vectorized:
            deltas = list(self.update_cohort(params, batch_list))
            return jax.tree.map(lambda *xs: sum(xs) / k, *deltas)
        if self._jit_cohort_sum is not None:
            stacked, k, bucket = self._pad_stack(batch_list)
            mask = np.zeros(bucket, np.float32)
            mask[:k] = 1.0 / k
            return self._jit_cohort_sum(params, stacked, jnp.asarray(mask))
        stacked, k, _ = self._pad_stack(batch_list)
        deltas = self._jit_cohort(params, stacked)
        return jax.tree.map(lambda x: jnp.mean(x[:k], axis=0), deltas)
