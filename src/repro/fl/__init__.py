from repro.fl.delays import DelayModel                       # noqa: F401
from repro.fl.engine import CohortEngine, DeltaBank           # noqa: F401
from repro.fl.simulator import (AsyncSimulator,               # noqa: F401
                                BufferedAsyncSimulator, History,
                                SyncSimulator)
from repro.fl.evaluate import make_personalized_eval          # noqa: F401
