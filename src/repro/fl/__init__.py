from repro.fl.delays import DelayModel                       # noqa: F401
from repro.fl.simulator import AsyncSimulator, SyncSimulator, History  # noqa: F401
from repro.fl.evaluate import make_personalized_eval          # noqa: F401
