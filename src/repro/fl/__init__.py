from repro.fl.delays import DelayModel                       # noqa: F401
from repro.fl.engine import CohortEngine, DeltaBank           # noqa: F401
from repro.fl.api import (ApplyPolicy, FLRun, History,        # noqa: F401
                          Strategy, buffered, immediate, register_strategy,
                          strategy, strategy_names, sync_barrier)
from repro.fl.simulator import (AsyncSimulator,               # noqa: F401
                                BufferedAsyncSimulator, SyncSimulator)
from repro.fl.evaluate import make_personalized_eval          # noqa: F401
from repro.fl.scenario import (Adversarial, ChurnModel,       # noqa: F401
                               DeviceScheduler, Diurnal, EventStream,
                               ScenarioSpec, Tier)
