from repro.fl.delays import DelayModel                       # noqa: F401
from repro.fl.engine import CohortEngine, DeltaBank           # noqa: F401
from repro.fl.api import (ApplyPolicy, FLRun, History,        # noqa: F401
                          Strategy, buffered, immediate, register_strategy,
                          strategy, strategy_names, sync_barrier)
from repro.fl.evaluate import make_personalized_eval          # noqa: F401
from repro.fl.scenario import (Adversarial, ChurnModel,       # noqa: F401
                               DeviceScheduler, Diurnal, EventStream,
                               ScenarioSpec, Tier)


def __getattr__(name: str):
    # the removed PR-4 simulator shims: defer to repro.fl.simulator's
    # ImportError breadcrumb (it names the FLRun spelling to migrate to)
    if name in ("AsyncSimulator", "BufferedAsyncSimulator",
                "SyncSimulator"):
        from repro.fl import simulator
        return getattr(simulator, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
