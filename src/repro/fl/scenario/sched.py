"""Scalable event scheduling: vectorized event streams + the device
scheduler.

``FLRun._run_events`` used to be a Python ``heapq`` over per-client
events — fine at the paper's 10^2 clients, a hard wall at the ROADMAP's
10^6.  This module supplies the two scalable backends, both driven by the
same pure counter-based hash streams as the heap
(:mod:`repro.fl.delays`), with a documented total event order shared by
every path:

    **(time, client_id, kind)** with ``KIND_DOWN(0) < KIND_UP(1)``

(the old insertion-``seq`` tie-break is gone — it was not preserved
across scheduler backends).

Two layers:

  * :class:`EventStream` — the host-vectorized float64 twin of the heap:
    delays for a whole chunk of cycles are drawn as ``[n_clients]``
    arrays and merged by ``np.lexsort`` on the exact (time, client, kind)
    key; per-client times accumulate through the *same* float64
    additions, in the same order, as the heap's scalar arithmetic, so
    the emitted event sequence is **bit-equal** to the heap oracle
    (pinned in ``tests/test_scenario.py``).  This is what
    ``FLRun(scheduler="device")`` replays — the simulation semantics
    (policies, cohort calls, applies) are byte-identical, only the
    scheduling data structure changes.
  * :class:`DeviceScheduler` — the device-resident cohort former for the
    10^5–10^6-client regime: per-client next-event times and cycle
    counters live as ``[n]`` f32/i32 device arrays, one jitted chunked
    ``lax.scan`` advances up to ``cycles_per_window`` cycles per client
    and forms the window's cohort (first ``cohort_cap`` completions by
    arrival time, pow2-capped, via ``top_k``) — per window, the host
    sees only the ``[cohort_cap]`` id vector and a handful of scalar
    counters.  Wall-clock grows sub-linearly in n (the ``scale`` bench
    row gates this).  Uses float32 on device; the float64
    :class:`EventStream` is its cross-checked host oracle (hash streams
    are bit-identical by construction, realized times agree to f32
    tolerance).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.fl.delays import (TAG_DOWN, TAG_DROP, TAG_UP, hash_u01)

KIND_DOWN = 0   # a client's download completed (it starts local compute)
KIND_UP = 1     # a client's upload landed at the server

_TWO_PI = 2.0 * np.pi


class EventStream:
    """Host-vectorized generator of the heap's exact event sequence.

    Yields ``(t, client, kind, dropped, t_up)`` tuples in (time, client,
    kind) order, indefinitely — the consumer decides when to stop.  For a
    ``KIND_DOWN`` event ``t_up`` is the client's upload-completion time
    (the consumer's busy-interval bookkeeping); ``dropped`` marks a
    mid-round dropout cycle: no ``KIND_UP`` event will follow and the
    client's next download starts at ``t_up`` (the would-be upload
    duration is spent offline — realized timelines are identical whether
    or not a cycle drops, which keeps every scheduler backend aligned).
    """

    def __init__(self, model, *, chunk: int = 4):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.model = model
        self.chunk = chunk

    def events(self):
        model = self.model
        n = model.n_clients
        ids = np.arange(n)
        t = np.zeros(n, np.float64)       # next download start per client
        k = 0                             # cycle counter (lockstep chunks)
        empty_f = np.empty(0, np.float64)
        empty_i = np.empty(0, np.int64)
        empty_b = np.empty(0, bool)
        c_t, c_i, c_k = empty_f, empty_i, empty_i      # carried events
        c_d, c_u = empty_b, empty_f
        zeros_kind = np.zeros(n, np.int64)
        ones_kind = np.ones(n, np.int64)
        false_n = np.zeros(n, bool)
        while True:
            ts: List[np.ndarray] = [c_t]
            cs: List[np.ndarray] = [c_i]
            ks: List[np.ndarray] = [c_k]
            ds: List[np.ndarray] = [c_d]
            us: List[np.ndarray] = [c_u]
            for _ in range(self.chunk):
                dl = np.asarray(model.download_delay(ids, k, t), np.float64)
                t_arr = t + dl
                ul = np.asarray(model.upload_delay(ids, k, t_arr),
                                np.float64)
                t_up = t_arr + ul
                drop = np.asarray(model.drops_at(ids, k), bool)
                ts.append(t_arr)
                cs.append(ids)
                ks.append(zeros_kind)
                ds.append(drop)
                us.append(t_up)
                nd = ~drop
                ts.append(t_up[nd])
                cs.append(ids[nd])
                ks.append(ones_kind[nd])
                ds.append(false_n[nd])
                us.append(t_up[nd])
                t = t_up
                k += 1
            # every not-yet-generated event starts at some client's next
            # download start, so it lies strictly past min(t) (delays > 0):
            # events below that horizon are final and safe to emit sorted
            horizon = t.min()
            a_t = np.concatenate(ts)
            a_i = np.concatenate(cs)
            a_k = np.concatenate(ks)
            a_d = np.concatenate(ds)
            a_u = np.concatenate(us)
            emit = a_t < horizon
            order = np.lexsort((a_k[emit], a_i[emit], a_t[emit]))
            e_t, e_i = a_t[emit][order], a_i[emit][order]
            e_k, e_d = a_k[emit][order], a_d[emit][order]
            e_u = a_u[emit][order]
            for j in range(len(e_t)):
                yield (float(e_t[j]), int(e_i[j]), int(e_k[j]),
                       bool(e_d[j]), float(e_u[j]))
            hold = ~emit
            c_t, c_i, c_k = a_t[hold], a_i[hold], a_k[hold]
            c_d, c_u = a_d[hold], a_u[hold]


class DeviceScheduler:
    """Device-resident window scheduler for 10^5–10^6 simulated clients.

    State (``[n]`` device arrays): each client's next-download-start time
    (f32) and cycle counter (i32).  :meth:`next_window` runs ONE jitted
    call — a chunked ``lax.scan`` advancing up to ``cycles_per_window``
    communication cycles per client, windowed by segment: a cycle
    advances iff its upload would complete inside the window, so cycles
    spanning the boundary are *recomputed idempotently* next window (all
    draws are pure hashes of (seed, client, cycle)).  The window's cohort
    is the first ``cohort_cap`` non-dropped completions by arrival time
    (``top_k``; the cap is rounded up to a power of two, matching the
    engine's bucketing).  Host traffic per window: the ``[cohort_cap]``
    id/validity/arrival vectors and a few scalar counters — never a
    per-client or per-delta array, so ``host_materializations`` stays 0
    end-to-end when the cohort's bank rows are consumed on device.

    Counters that would silently cap coverage are reported instead:
    ``overflow_arrivals`` (completions beyond ``cohort_cap``) and
    ``saturated_clients`` (clients that could have completed yet another
    cycle in-window when the ``cycles_per_window`` scan budget ran out —
    their backlog slides to the next window).
    """

    def __init__(self, model, *, window_len: float, cohort_cap: int = 256,
                 cycles_per_window: int = 8, window_log_cap: int = 1024):
        import jax
        import jax.numpy as jnp
        if window_len <= 0:
            raise ValueError("window_len must be > 0")
        n = int(model.n_clients)
        self.model = model
        self.n_clients = n
        self.window_len = float(window_len)
        self.cohort_cap = 1 << max(int(cohort_cap) - 1, 0).bit_length()
        self.cycles_per_window = int(cycles_per_window)
        self.window = 0
        self.stats = {"windows": 0, "arrivals": 0, "dropouts": 0,
                      "cohort_fill_sum": 0, "cohort_fill_max": 0,
                      "overflow_arrivals": 0, "saturated_clients": 0}
        self.window_log: List[dict] = []
        self._window_log_cap = int(window_log_cap)

        seed = int(model.seed)
        j0, j1 = (float(model.jitter[0]), float(model.jitter[1]))
        scale = float(model.scale)
        dropout = float(getattr(model, "dropout", 0.0))
        mean_down = jnp.asarray(model.mean_down, jnp.float32)
        mean_up = jnp.asarray(model.mean_down * model.up_factor,
                              jnp.float32)
        mult = getattr(model, "tier_mult", None)
        mult = jnp.asarray(mult if mult is not None else np.ones(n),
                           jnp.float32)
        diurnal = getattr(model, "diurnal", None)
        if diurnal is not None:
            phase = jnp.asarray(model.phase, jnp.float32)
            period = jnp.float32(diurnal.period)
            floor = jnp.float32(diurnal.floor)

            def avail(tt):
                ph = jnp.float32(_TWO_PI) * (tt / period + phase)
                return floor + (1.0 - floor) * 0.5 * (1.0 + jnp.sin(ph))
        else:
            def avail(tt):
                return jnp.float32(1.0)

        ids = jnp.arange(n, dtype=jnp.uint32)
        jw = jnp.float32(j1 - j0)
        j0f = jnp.float32(j0)
        scf = jnp.float32(scale)
        cap = self.cohort_cap
        cycles = self.cycles_per_window

        def cycle_times(t, k):
            u_d = hash_u01(seed, ids, k, TAG_DOWN, jnp)
            dl = scf * mean_down * (j0f + jw * u_d) * (mult / avail(t))
            t_arr = t + dl
            u_u = hash_u01(seed, ids, k, TAG_UP, jnp)
            ul = scf * mean_up * (j0f + jw * u_u) * (mult / avail(t_arr))
            return t_arr + ul

        def step(t, k, w_end):
            inf = jnp.float32(jnp.inf)

            def one_cycle(carry, _):
                t, k, arr, drops = carry
                t_up = cycle_times(t, k)
                if dropout > 0.0:
                    drop = hash_u01(seed, ids, k, TAG_DROP, jnp) < dropout
                else:
                    drop = jnp.zeros(n, bool)
                adv = t_up < w_end
                first = adv & (~drop) & (arr == inf)
                arr = jnp.where(first, t_up, arr)
                drops = drops + jnp.sum((adv & drop).astype(jnp.int32))
                t = jnp.where(adv, t_up, t)
                k = jnp.where(adv, k + 1, k)
                return (t, k, arr, drops), None

            arr0 = jnp.full(n, inf, jnp.float32)
            (t, k, arr, drops), _ = jax.lax.scan(
                one_cycle, (t, k, arr0, jnp.int32(0)), None, length=cycles)
            # scan-budget saturation probe (pure; state unchanged)
            saturated = jnp.sum((cycle_times(t, k) < w_end)
                                .astype(jnp.int32))
            arrivals = jnp.sum((arr < inf).astype(jnp.int32))
            neg, idx = jax.lax.top_k(-arr, cap)
            cohort_times = -neg
            valid = jnp.isfinite(cohort_times)
            fill = jnp.sum(valid.astype(jnp.int32))
            return (t, k, idx.astype(jnp.int32), valid, cohort_times,
                    fill, arrivals, drops, saturated)

        self._step = jax.jit(step)
        self._t = jnp.zeros(n, jnp.float32)
        self._k = jnp.zeros(n, jnp.int32)

    @classmethod
    def from_spec(cls, spec, **kw) -> "DeviceScheduler":
        return cls(spec.build(), **kw)

    def next_window(self) -> Tuple[np.ndarray, np.ndarray]:
        """Advance one window; -> (cohort client ids, arrival times),
        both ``[fill]`` numpy arrays in arrival order."""
        import jax.numpy as jnp
        w_end = jnp.float32(self.window_len * (self.window + 1))
        (self._t, self._k, idx, valid, ctimes, fill, arrivals, drops,
         saturated) = self._step(self._t, self._k, w_end)
        idx = np.asarray(idx)
        valid = np.asarray(valid)
        ctimes = np.asarray(ctimes)
        fill = int(fill)
        arrivals = int(arrivals)
        drops = int(drops)
        saturated = int(saturated)
        self.window += 1
        st = self.stats
        st["windows"] += 1
        st["arrivals"] += arrivals
        st["dropouts"] += drops
        st["cohort_fill_sum"] += fill
        st["cohort_fill_max"] = max(st["cohort_fill_max"], fill)
        st["overflow_arrivals"] += max(arrivals - fill, 0)
        st["saturated_clients"] += saturated
        if len(self.window_log) < self._window_log_cap:
            self.window_log.append({
                "window": self.window, "fill": fill,
                "arrivals": arrivals, "dropouts": drops,
                "overflow": max(arrivals - fill, 0),
                "saturated": saturated})
        order = np.argsort(ctimes[valid], kind="stable")
        return idx[valid][order], ctimes[valid][order]
