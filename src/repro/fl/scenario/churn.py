"""Trace-driven churn: the :class:`ChurnModel` delay/behavior model.

Extends :class:`repro.fl.DelayModel` with the traffic shapes the paper's
staleness claim actually meets at scale — all derived from the same pure
counter-based hash streams (:func:`repro.fl.delays.hash_u01`), so the
per-event heap, the vectorized host :class:`EventStream` and the
device-resident :class:`DeviceScheduler` all see identical behavior:

  * **speed tiers** — each client is hash-assigned a device-class tier
    (:class:`repro.fl.scenario.Tier`); its delays scale by the tier's
    ``speed`` multiplier;
  * **diurnal availability** — a per-client-phased sinusoid
    (:class:`repro.fl.scenario.Diurnal`); delays divide by availability,
    so a client deep in its night completes rounds slowly instead of
    disappearing (availability never hits zero: ``floor`` > 0);
  * **mid-round dropout** — with probability ``dropout`` a cycle's client
    vanishes *after* its download completes but *before* its upload: no
    delta is computed and no upload event fires, but the client stays
    offline for the would-be upload duration before its next download
    (keeps realized timelines identical across scheduler backends);
  * **adversarial clients** — a hash-chosen ``frac`` of clients corrupt
    every delta they upload (scaled / sign-flipped / NaN, per
    :class:`repro.fl.scenario.Adversarial`); the corruption itself is
    applied on-device to bank rows (``repro.core.server.scale_rows``) and
    defended by the robust admission variants
    (``repro.core.robust_admission_weights``).

Build one declaratively: ``ScenarioSpec(...).build()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.fl.delays import (DelayModel, TAG_ADV, TAG_DROP, TAG_PHASE,
                             TAG_TIER, hash_u01)
from repro.fl.scenario.spec import (Adversarial, Diurnal, ScenarioSpec,
                                    Tier)

_TWO_PI = 2.0 * np.pi


@dataclasses.dataclass
class ChurnModel(DelayModel):
    """Trace-driven :class:`DelayModel`: tiers × diurnal × dropout ×
    adversaries.  Same pure/stateful surface as the base class — the
    schedulers need no churn-specific code paths beyond reading
    :meth:`drops_at` and :meth:`corruption_factors`."""

    tiers: Tuple[Tier, ...] = (Tier("uniform", 1.0, 1.0),)
    diurnal: Optional[Diurnal] = None
    dropout: float = 0.0
    adversarial: Optional[Adversarial] = None

    def __post_init__(self):
        super().__post_init__()
        ids = np.arange(self.n_clients)
        frac = np.array([t.frac for t in self.tiers], np.float64)
        cum = np.cumsum(frac / frac.sum())
        u = hash_u01(self.seed, ids, 0, TAG_TIER)
        self.tier_index = np.minimum(
            np.searchsorted(cum, u, side="right"), len(self.tiers) - 1)
        speeds = np.array([t.speed for t in self.tiers], np.float64)
        self.tier_mult = speeds[self.tier_index]
        self.phase = hash_u01(self.seed, ids, 0, TAG_PHASE)
        adv = self.adversarial
        if adv is not None and adv.frac > 0.0:
            mask = hash_u01(self.seed, ids, 0, TAG_ADV) < adv.frac
            kind_idx = np.minimum(
                (hash_u01(self.seed, ids, 1, TAG_ADV)
                 * len(adv.kinds)).astype(np.int64), len(adv.kinds) - 1)
            fac = np.ones(self.n_clients, np.float64)
            for j, kind in enumerate(adv.kinds):
                val = {"scale": adv.magnitude,
                       "sign_flip": -adv.magnitude,
                       "nan": np.nan}[kind]
                fac = np.where(mask & (kind_idx == j), val, fac)
            self._adv_factor = fac.astype(np.float32)
            self.adversary_ids = ids[mask]
        else:
            self._adv_factor = None
            self.adversary_ids = np.empty(0, np.int64)

    @staticmethod
    def from_spec(spec: ScenarioSpec) -> "ChurnModel":
        return spec.build()

    # -- behavior hooks (pure, vectorized; see DelayModel) -----------------

    def availability(self, i, t):
        """Availability ∈ [floor, 1] of client(s) ``i`` at time(s) ``t``
        (1.0 without a diurnal curve); delays divide by it."""
        if self.diurnal is None:
            return 1.0
        d = self.diurnal
        ph = _TWO_PI * (np.asarray(t, np.float64) / d.period
                        + self.phase[i])
        return d.floor + (1.0 - d.floor) * 0.5 * (1.0 + np.sin(ph))

    def _speed(self, i, t):
        return self.tier_mult[i] / self.availability(i, t)

    def drops_at(self, i, k):
        if self.dropout <= 0.0:
            return super().drops_at(i, k)
        return hash_u01(self.seed, i, k, TAG_DROP) < self.dropout

    def corruption_factors(self, ids):
        """Per-client delta corruption factor for ``ids`` (f32; 1.0 for
        honest clients, ±magnitude / NaN for adversaries), or None when
        the scenario has no adversarial population."""
        if self._adv_factor is None:
            return None
        return self._adv_factor[np.asarray(ids, np.int64)]
