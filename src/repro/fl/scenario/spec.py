"""Declarative scenario specification (JSON-serializable).

A :class:`ScenarioSpec` is the single declarative knob for trace-driven
client behavior: device-class speed tiers, a diurnal availability curve,
mid-round dropout, and adversarial clients — everything the
:class:`repro.fl.scenario.ChurnModel` needs, as plain data.  Specs
round-trip through JSON (``to_json`` / ``from_json``) so a churn sweep's
exact traffic shape can be committed next to its results and replayed
bit-for-bit (all client behavior is a pure hash of ``(seed, client,
counter, tag)`` — see :mod:`repro.fl.delays`).

Example::

    spec = ScenarioSpec(
        n_clients=100_000, seed=0,
        tiers=(Tier("flagship", frac=0.2, speed=0.5),
               Tier("mid", frac=0.5, speed=1.0),
               Tier("budget", frac=0.3, speed=2.5)),
        diurnal=Diurnal(period=86_400.0, floor=0.25),
        dropout=0.05,
        adversarial=Adversarial(frac=0.05, kinds=("scale", "sign_flip"),
                                magnitude=50.0))
    model = spec.build()                       # -> ChurnModel
    open("spec.json", "w").write(spec.to_json())
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

ADVERSARY_KINDS = ("scale", "sign_flip", "nan")


@dataclasses.dataclass(frozen=True)
class Tier:
    """One device-class speed tier: ``frac`` of the population (fractions
    are normalized over the tier list) runs at ``speed``× the nominal
    delay (2.0 = twice as slow, 0.5 = twice as fast)."""
    name: str
    frac: float
    speed: float


@dataclasses.dataclass(frozen=True)
class Diurnal:
    """Sinusoidal availability curve with per-client phase: availability
    at time t is ``floor + (1-floor) * 0.5 * (1 + sin(2π(t/period +
    phase_i)))`` ∈ [floor, 1]; realized delays divide by it (an offline-ish
    client's round stretches instead of vanishing)."""
    period: float = 86_400.0
    floor: float = 0.25


@dataclasses.dataclass(frozen=True)
class Adversarial:
    """Adversarial population: ``frac`` of clients corrupt every delta
    they upload.  Each adversary is hash-assigned one kind from ``kinds``:
    ``"scale"`` multiplies the delta by ``magnitude``, ``"sign_flip"`` by
    ``-magnitude``, ``"nan"`` poisons it with NaNs."""
    frac: float = 0.0
    kinds: Tuple[str, ...] = ("scale", "sign_flip")
    magnitude: float = 50.0


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Full scenario: paper-§5 delay statistics + churn + adversaries."""
    n_clients: int
    seed: int = 0
    tiers: Tuple[Tier, ...] = (Tier("uniform", 1.0, 1.0),)
    diurnal: Optional[Diurnal] = None
    dropout: float = 0.0
    adversarial: Optional[Adversarial] = None
    down_range: Tuple[float, float] = (1.0, 3.0)
    up_factor_range: Tuple[float, float] = (4.0, 6.0)
    jitter: Tuple[float, float] = (0.5, 1.5)
    scale: float = 1.0

    def __post_init__(self):
        if self.n_clients < 1:
            raise ValueError("need at least one client")
        if not self.tiers:
            raise ValueError("need at least one tier")
        if sum(t.frac for t in self.tiers) <= 0:
            raise ValueError("tier fractions must sum to > 0")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), "
                             f"got {self.dropout}")
        if self.diurnal is not None \
                and not 0.0 < self.diurnal.floor <= 1.0:
            raise ValueError(f"diurnal floor must be in (0, 1], "
                             f"got {self.diurnal.floor}")
        if self.adversarial is not None:
            adv = self.adversarial
            if not 0.0 <= adv.frac < 1.0:
                raise ValueError(f"adversarial frac must be in [0, 1), "
                                 f"got {adv.frac}")
            bad = [k for k in adv.kinds if k not in ADVERSARY_KINDS]
            if bad or not adv.kinds:
                raise ValueError(f"adversary kinds must be non-empty, "
                                 f"from {ADVERSARY_KINDS}; got {adv.kinds}")

    # -- JSON round-trip ---------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    @staticmethod
    def from_json(s: str) -> "ScenarioSpec":
        d = json.loads(s)
        d["tiers"] = tuple(Tier(**t) for t in d.get("tiers", []))
        if d.get("diurnal") is not None:
            d["diurnal"] = Diurnal(**d["diurnal"])
        if d.get("adversarial") is not None:
            a = dict(d["adversarial"])
            a["kinds"] = tuple(a.get("kinds", ()))
            d["adversarial"] = Adversarial(**a)
        for key in ("down_range", "up_factor_range", "jitter"):
            d[key] = tuple(d[key])
        return ScenarioSpec(**d)

    # -- model construction ------------------------------------------------

    def build(self):
        """-> the :class:`repro.fl.scenario.ChurnModel` this spec
        describes (a drop-in :class:`repro.fl.DelayModel`)."""
        from repro.fl.scenario.churn import ChurnModel
        return ChurnModel(
            n_clients=self.n_clients, seed=self.seed,
            down_range=self.down_range,
            up_factor_range=self.up_factor_range,
            jitter=self.jitter, scale=self.scale,
            tiers=self.tiers, diurnal=self.diurnal,
            dropout=self.dropout, adversarial=self.adversarial)
