"""Million-client scenario engine: device-resident event scheduling,
trace-driven churn, and the declarative :class:`ScenarioSpec`.

Three layers (see each module's docstring):

  * :mod:`repro.fl.scenario.spec` — :class:`ScenarioSpec` /
    :class:`Tier` / :class:`Diurnal` / :class:`Adversarial`: the
    JSON-round-tripping declarative description of a traffic shape;
  * :mod:`repro.fl.scenario.churn` — :class:`ChurnModel`, the
    trace-driven :class:`repro.fl.DelayModel` (speed tiers, diurnal
    availability, mid-round dropout, adversarial clients) built from a
    spec;
  * :mod:`repro.fl.scenario.sched` — :class:`EventStream` (the
    host-vectorized float64 twin of FLRun's heap, bit-equal event order)
    and :class:`DeviceScheduler` (the chunked-``lax.scan`` cohort former
    for the 10^5–10^6-client regime; the ``scale`` bench row).

Robust admission against the adversarial rows lives in
:mod:`repro.core.server` (``robust_admission_weights`` /
``bank_row_norms`` / ``mask_rows`` / ``scale_rows``) and is consumed by
``buffered(m, robust=...)`` and ``DeltaRing(robust=...)``.
"""
from repro.fl.scenario.spec import (Adversarial, Diurnal,  # noqa: F401
                                    ScenarioSpec, Tier)
from repro.fl.scenario.churn import ChurnModel             # noqa: F401
from repro.fl.scenario.sched import (DeviceScheduler,      # noqa: F401
                                     EventStream, KIND_DOWN, KIND_UP)
