"""Client communication-delay models (paper §5).

Per client: a mean download delay, and an upload delay 4–6× larger on
average; each round's realized delay is the mean scaled by uniform noise.
Local compute time is negligible relative to communication (paper §5
assumption).  ``scale`` inflates all delays (the staleness-sweep benchmark
turns this knob).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class DelayModel:
    n_clients: int
    seed: int = 0
    down_range: Tuple[float, float] = (1.0, 3.0)
    up_factor_range: Tuple[float, float] = (4.0, 6.0)
    jitter: Tuple[float, float] = (0.5, 1.5)
    scale: float = 1.0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        self.mean_down = rng.uniform(*self.down_range, size=self.n_clients)
        self.up_factor = rng.uniform(*self.up_factor_range,
                                     size=self.n_clients)
        self._rng = np.random.RandomState(self.seed + 1)

    def sample_download(self, i: int) -> float:
        return float(self.scale * self.mean_down[i]
                     * self._rng.uniform(*self.jitter))

    def sample_upload(self, i: int) -> float:
        return float(self.scale * self.mean_down[i] * self.up_factor[i]
                     * self._rng.uniform(*self.jitter))
