"""Client communication-delay models (paper §5) on counter-based streams.

Per client: a mean download delay, and an upload delay 4–6× larger on
average; each round's realized delay is the mean scaled by uniform jitter.
Local compute time is negligible relative to communication (paper §5
assumption).  ``scale`` inflates all delays (the staleness-sweep benchmark
turns this knob).

Every random property here is a *pure function* ``hash01(seed, client,
counter, tag)`` of a counter-based 32-bit hash — there is no shared
sequential RNG stream.  Two consequences the schedulers rely on:

  * **client independence** — client *i*'s delay sequence depends only on
    (seed, i), never on ``n_clients`` or on the order other clients'
    events fire.  The old implementation drew jitter from one shared
    ``np.random.RandomState``, so adding a single client perturbed every
    other client's realized delays (regression pinned in
    ``tests/test_scenario.py::test_delay_stream_invariant_to_n_clients``);
  * **vectorizability** — the pure twins :meth:`download_delay` /
    :meth:`upload_delay` / :meth:`drops_at` accept arrays of clients and
    cycle counters, so the device-resident scheduler
    (:mod:`repro.fl.scenario.sched`) can evaluate a whole population's
    cycle *k* in one shot and land bit-equal with the per-event heap,
    which consumes the same functions through the stateful
    :meth:`sample_download` / :meth:`sample_upload` wrappers.

Realistic traffic shapes — diurnal availability, device-class speed
tiers, mid-round dropout, adversarial clients — live in
:class:`repro.fl.scenario.ChurnModel`, a subclass that overrides the
``_speed`` / ``drops_at`` / ``corruption_factors`` hooks and is built
declaratively from a JSON-serializable
:class:`repro.fl.scenario.ScenarioSpec`.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

# stream tags: independent hash sub-streams per random property
TAG_DOWN = 1      # per-cycle download jitter
TAG_UP = 2        # per-cycle upload jitter
TAG_MEAN = 3      # per-client mean download delay
TAG_FACTOR = 4    # per-client upload factor
TAG_DROP = 5      # per-cycle mid-round dropout coin
TAG_TIER = 6      # per-client device-class tier (ChurnModel)
TAG_PHASE = 7     # per-client diurnal phase (ChurnModel)
TAG_ADV = 8       # per-client adversary assignment (ChurnModel)


def _mix32(x, xp):
    """32-bit finalizer (murmur3-style avalanche); works for numpy uint32
    arrays and jnp uint32 tracers alike."""
    x = x ^ (x >> xp.uint32(16))
    x = (x * xp.uint32(0x7FEB352D)).astype(xp.uint32)
    x = x ^ (x >> xp.uint32(15))
    x = (x * xp.uint32(0x846CA68B)).astype(xp.uint32)
    x = x ^ (x >> xp.uint32(16))
    return x


def hash_u32(seed, client, counter, tag, xp=np):
    """Counter-based uint32 hash of (seed, client, counter, tag).

    ``client``/``counter`` may be scalars or arrays (broadcast); ``xp`` is
    ``numpy`` (host schedulers) or ``jax.numpy`` (device scheduler) — the
    two backends produce identical bits for identical inputs.
    """
    if xp is np:
        # >=1-d arrays: numpy integer *scalars* warn on uint32 wraparound,
        # arrays wrap silently (which is what a hash wants)
        client = np.atleast_1d(np.asarray(client)).astype(np.uint32)
        counter = np.atleast_1d(np.asarray(counter)).astype(np.uint32)
    else:
        client = xp.asarray(client).astype(xp.uint32)
        counter = xp.asarray(counter).astype(xp.uint32)
    s = xp.uint32((int(seed) * 2654435761 + 0x632BE59B) & 0xFFFFFFFF)
    tg = xp.uint32((int(tag) * 0x9E3779B9 + 1) & 0xFFFFFFFF)
    h = _mix32((client * xp.uint32(0x85EBCA77)).astype(xp.uint32) ^ s, xp)
    h = _mix32(h ^ (counter * xp.uint32(0xC2B2AE3D)).astype(xp.uint32), xp)
    h = _mix32(h ^ tg, xp)
    return h


def hash_u01(seed, client, counter, tag, xp=np):
    """Uniform [0, 1) from the top 24 bits of :func:`hash_u32`.

    24 bits are exactly representable in BOTH float64 (host path) and
    float32 (device path), so the two backends agree on the u01 value
    bit-for-bit before any downstream arithmetic.
    """
    h = hash_u32(seed, client, counter, tag, xp) >> xp.uint32(8)
    if xp is np:
        return h.astype(np.float64) * (2.0 ** -24)
    return h.astype(xp.float32) * xp.float32(2.0 ** -24)


@dataclasses.dataclass
class DelayModel:
    """Paper §5 delay statistics on independent per-client hash streams.

    Pure surface (shared by the heap scheduler, the vectorized
    :class:`repro.fl.scenario.EventStream` and the tests' oracles):

      * ``download_delay(i, k, t)`` / ``upload_delay(i, k, t)`` — client
        *i*'s cycle-*k* delay, starting at simulated time *t* (ignored by
        the base model; :class:`ChurnModel` uses it for diurnal
        availability).  ``i``/``k``/``t`` broadcast.
      * ``drops_at(i, k)`` — mid-round dropout coin (always False here).

    Stateful wrappers ``sample_download`` / ``sample_upload`` / ``drops``
    advance an internal per-client cycle counter and return scalars — the
    per-event heap consumes these, and because each client's cycles are
    strictly sequential the counter always equals the cycle index, making
    the heap and the vectorized paths draw identical values.
    """

    n_clients: int
    seed: int = 0
    down_range: Tuple[float, float] = (1.0, 3.0)
    up_factor_range: Tuple[float, float] = (4.0, 6.0)
    jitter: Tuple[float, float] = (0.5, 1.5)
    scale: float = 1.0

    def __post_init__(self):
        ids = np.arange(self.n_clients)
        lo, hi = self.down_range
        self.mean_down = lo + (hi - lo) * hash_u01(self.seed, ids, 0,
                                                   TAG_MEAN)
        lo, hi = self.up_factor_range
        self.up_factor = lo + (hi - lo) * hash_u01(self.seed, ids, 0,
                                                   TAG_FACTOR)
        # stateful per-client cycle counters (heap scheduler surface)
        self._kd = np.zeros(self.n_clients, np.int64)
        self._ku = np.zeros(self.n_clients, np.int64)
        self._kdrop = np.zeros(self.n_clients, np.int64)

    # -- pure, vectorizable surface ----------------------------------------

    def _jitter_u(self, i, k, tag):
        j0, j1 = self.jitter
        return j0 + (j1 - j0) * hash_u01(self.seed, i, k, tag)

    def _speed(self, i, t):
        """Delay multiplier at simulated time ``t`` (1 = nominal).
        ChurnModel overrides with tier × 1/availability."""
        return 1.0

    def download_delay(self, i, k, t=0.0):
        return (self.scale * self.mean_down[i]
                * self._jitter_u(i, k, TAG_DOWN) * self._speed(i, t))

    def upload_delay(self, i, k, t=0.0):
        return (self.scale * self.mean_down[i] * self.up_factor[i]
                * self._jitter_u(i, k, TAG_UP) * self._speed(i, t))

    def drops_at(self, i, k):
        """Mid-round dropout coin for client i's cycle k (vectorized)."""
        shape = np.broadcast(np.atleast_1d(i), np.atleast_1d(k)).shape
        return np.zeros(shape, bool)

    def corruption_factors(self, ids):
        """Per-client delta corruption factors (None = all honest)."""
        return None

    # -- stateful per-event surface ----------------------------------------

    def sample_download(self, i: int, t: float = 0.0) -> float:
        k = int(self._kd[i])
        self._kd[i] = k + 1
        return float(np.asarray(self.download_delay(i, k, t)).item(0))

    def sample_upload(self, i: int, t: float = 0.0) -> float:
        k = int(self._ku[i])
        self._ku[i] = k + 1
        return float(np.asarray(self.upload_delay(i, k, t)).item(0))

    def drops(self, i: int) -> bool:
        k = int(self._kdrop[i])
        self._kdrop[i] = k + 1
        return bool(self.drops_at(i, k).any())
