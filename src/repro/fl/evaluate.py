"""Personalized evaluation (paper §5): every method is scored by test
accuracy *after the same local fine-tuning budget* on each client's own
data, then averaged over clients.

``personal_subset`` restricts the fine-tune to the personal leaves
(partial-model personalization): backbone leaves keep the global values,
so the score measures exactly what a head-only serving deployment can
deliver."""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.subset import SubsetSpec
from repro.data.federated import ClientData, eval_batch


def make_personalized_eval(loss_fn: Callable, acc_fn: Callable,
                           clients: List[ClientData], *, ft_steps: int = 1,
                           ft_lr: float = 0.01, batch_size: int = 32,
                           eval_size: int = 64, seed: int = 0,
                           personal_subset=None,
                           with_loss: bool = False) -> Callable:
    """Returns eval(params) -> mean personalized test accuracy.

    All shapes are fixed (batched fine-tune across clients via vmap) so the
    whole evaluation is two jitted calls regardless of client count.
    With ``personal_subset`` (any SubsetSpec spelling) only the personal
    leaves take fine-tune steps — the masked update is a trace-time Python
    branch per leaf, so the jit cost is identical.

    ``with_loss=True`` returns ``{"acc": ..., "loss": ...}`` instead of a
    bare float — the mean personalized *test loss* rides the same two
    jitted calls, and :class:`repro.fl.FLRun` records it in
    ``History.loss`` (the series the :mod:`repro.tune` early-stop rules
    watch).  The default stays the scalar contract existing callers and
    pinned sweep numbers rely on.

    The returned ``evaluate`` is a pure function of ``params``: the
    fine-tune batches are drawn fresh from ``seed`` on every call, so
    one eval_fn can be shared across many runs (the tuner's grids) with
    no cross-run order dependence.
    """
    n = len(clients)
    spec = SubsetSpec.resolve(personal_subset)
    test = jax.tree.map(lambda *xs: np.stack(xs),
                        *[eval_batch(c, eval_size, seed) for c in clients])

    def _personalize_and_score(params, ft_batches, test_b):
        mask = spec.mask(params) if spec is not None \
            else jax.tree.map(lambda _: True, params)
        p_i = params
        for s in range(ft_steps):
            b = jax.tree.map(lambda x: x[s], ft_batches)
            g = jax.grad(loss_fn)(p_i, b)
            p_i = jax.tree.map(
                lambda p, gg, m: (p.astype(jnp.float32)
                                  - ft_lr * gg.astype(jnp.float32))
                .astype(p.dtype) if m else p, p_i, g, mask)
        if with_loss:
            return acc_fn(p_i, test_b), loss_fn(p_i, test_b)
        return acc_fn(p_i, test_b)

    _batched = jax.jit(jax.vmap(_personalize_and_score, in_axes=(None, 0, 0)))

    def evaluate(params):
        if spec is not None:
            spec.validate(params)   # typo'd subsets fail loudly, not as
            #                         an accidental zero-step fine-tune
        # the fine-tune probe is deterministic: the same batches on every
        # call, so evaluate(params) is a pure function of params.  (It
        # used to advance a closure RNG per call, which made a shared
        # eval_fn order-dependent — two identical FLRuns scored
        # differently depending on how many evals ran before them, and
        # paired tuner trials could disagree on their common prefix.)
        rng = np.random.RandomState(seed)
        per_client = []
        for c in clients:
            idx = rng.randint(0, c.n_train, (ft_steps, batch_size))
            per_client.append({"images": c.train_x[idx],
                               "labels": c.train_y[idx]})
        ft = jax.tree.map(lambda *xs: np.stack(xs), *per_client)
        out = _batched(params, ft, test)
        if with_loss:
            acc_v, loss_v = out
            return {"acc": float(np.mean(np.asarray(acc_v))),
                    "loss": float(np.mean(np.asarray(loss_v)))}
        return float(np.mean(np.asarray(out)))

    return evaluate
