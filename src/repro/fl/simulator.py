"""DEPRECATED simulator class names — thin shims over :mod:`repro.fl.api`.

PR 4 collapsed the three discrete-event simulators into the single
:class:`repro.fl.api.FLRun` event-loop core: a registry
:class:`~repro.fl.api.Strategy` (the local update rule — Options A/B/C,
FedProx, SCAFFOLD, …) composed with an :class:`~repro.fl.api.ApplyPolicy`
(the server schedule — ``immediate()`` / ``buffered(M)`` /
``sync_barrier(m)``).  The names below survive one release for pre-PR-4
call sites and emit :class:`DeprecationWarning` on construction; each is a
*subclass* of FLRun, so every attribute (``state``, ``engine``, ``rng``,
``delays``, ``final_stats``) and the History contract behave identically.

Migration map::

    AsyncSimulator(...)                    -> FLRun(..., schedule=immediate())
    BufferedAsyncSimulator(..., buffer_size=M)
                                           -> FLRun(..., schedule=buffered(M))
    SyncSimulator(..., algo="fedprox", clients_per_round=m, fedprox_mu=mu)
                                           -> FLRun(..., strategy=strategy(
                                                  "fedprox", mu=mu),
                                                  schedule=sync_barrier(m))

FedProx and SCAFFOLD no longer take a sequential per-client jit loop: as
registry strategies they run through the cohort engine (stacked client
state, deltas in the on-device DeltaBank) like every other rule.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.fl.api import (FLRun, History, buffered,  # noqa: F401
                          immediate, strategy, sync_barrier)


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.fl.simulator.{old} is deprecated and will be removed next "
        f"release; use {new}", DeprecationWarning, stacklevel=3)


class AsyncSimulator(FLRun):
    """DEPRECATED shim: PersA-FL / FedAsync immediate-apply runner.

    Use ``FLRun(strategy="persafl", schedule=immediate(), ...)``.
    """

    def __init__(self, *, clients, loss_fn, init_params, pcfg, delays,
                 batch_size: int = 32, seed: int = 0,
                 vectorized: bool = True):
        _deprecated("AsyncSimulator",
                    "repro.fl.api.FLRun(strategy='persafl', "
                    "schedule=immediate())")
        super().__init__(clients=clients, loss_fn=loss_fn,
                         init_params=init_params, pcfg=pcfg, delays=delays,
                         strategy="persafl", schedule=immediate(),
                         batch_size=batch_size, seed=seed,
                         vectorized=vectorized)

    def run(self, *, max_server_rounds: int, **kw) -> History:
        return super().run(max_rounds=max_server_rounds, **kw)


class BufferedAsyncSimulator(FLRun):
    """DEPRECATED shim: FedBuff-style buffered asynchronous scheduler.

    Use ``FLRun(strategy="persafl", schedule=buffered(M), ...)``.
    """

    def __init__(self, *, clients, loss_fn, init_params, pcfg, delays,
                 buffer_size: Optional[int] = None, batch_size: int = 32,
                 seed: int = 0, vectorized: bool = True):
        _deprecated("BufferedAsyncSimulator",
                    "repro.fl.api.FLRun(strategy='persafl', "
                    "schedule=buffered(M))")
        super().__init__(clients=clients, loss_fn=loss_fn,
                         init_params=init_params, pcfg=pcfg, delays=delays,
                         strategy="persafl", schedule=buffered(buffer_size),
                         batch_size=batch_size, seed=seed,
                         vectorized=vectorized)

    @property
    def buffer_size(self) -> int:
        m = getattr(self.schedule, "m_effective", self.schedule.m)
        return m if m is not None else max(int(self.pcfg.buffer_size), 1)

    def run(self, *, max_server_rounds: int, **kw) -> History:
        return super().run(max_rounds=max_server_rounds, **kw)


#: legacy ``algo`` string -> registry strategy spec
_SYNC_ALGOS = ("fedavg", "perfedavg", "pfedme", "fedprox", "scaffold")


class SyncSimulator(FLRun):
    """DEPRECATED shim: synchronous FedAvg-family rounds.

    Use ``FLRun(strategy=strategy(algo, ...), schedule=sync_barrier(m))``.
    """

    def __init__(self, *, clients, loss_fn, init_params, pcfg, delays,
                 algo: str = "fedavg", clients_per_round: int = 10,
                 batch_size: int = 32, seed: int = 0,
                 fedprox_mu: float = 0.1, vectorized: bool = True):
        if algo not in _SYNC_ALGOS:
            raise KeyError(algo)
        _deprecated("SyncSimulator",
                    f"repro.fl.api.FLRun(strategy=strategy({algo!r}), "
                    f"schedule=sync_barrier(m))")
        self.algo = algo
        strat = strategy("fedprox", mu=fedprox_mu) if algo == "fedprox" \
            else strategy(algo)
        super().__init__(clients=clients, loss_fn=loss_fn,
                         init_params=init_params, pcfg=pcfg, delays=delays,
                         strategy=strat,
                         schedule=sync_barrier(clients_per_round),
                         batch_size=batch_size, seed=seed,
                         vectorized=vectorized)
        self.m = clients_per_round

    def run(self, *, max_rounds: int, **kw) -> History:
        return super().run(max_rounds=max_rounds, **kw)
