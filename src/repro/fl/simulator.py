"""Discrete-event federated-learning simulator.

Reproduces the paper's §5 communication setup: n clients with random
upload/download delays (upload 4–6× download), communication time dominating
local compute.  The simulator drives the *same jitted client/server step
functions* as the production launcher — only event ordering is simulated
(DESIGN.md §2).

Two schedulers:
  * :class:`AsyncSimulator` — Algorithm 1: the server applies each client's
    Δ the moment it arrives; staleness τ is measured per update.
  * :class:`SyncSimulator`  — FedAvg-family rounds: sample m clients, wait
    for the slowest, apply the averaged Δ (supports FedAvg / Per-FedAvg /
    pFedMe / FedProx / SCAFFOLD via ``algo``).

Both record the active-client ratio over time (paper Figure 2a) and
accuracy-vs-simulated-time via a pluggable eval callback.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PersAFLConfig, apply_update, client_update,
                        init_server_state, split_batches_for_option)
from repro.core.server import staleness_stats
from repro.data.federated import ClientData, sample_batches
from repro.fl.algorithms import fedprox_update, scaffold_update
from repro.fl.delays import DelayModel


@dataclasses.dataclass
class History:
    times: List[float] = dataclasses.field(default_factory=list)
    rounds: List[int] = dataclasses.field(default_factory=list)
    acc: List[float] = dataclasses.field(default_factory=list)
    active_times: List[float] = dataclasses.field(default_factory=list)
    active_ratio: List[float] = dataclasses.field(default_factory=list)
    staleness: List[int] = dataclasses.field(default_factory=list)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class AsyncSimulator:
    """PersA-FL / FedAsync event-driven runner (Algorithms 1 & 2)."""

    def __init__(self, *, clients: List[ClientData], loss_fn: Callable,
                 init_params, pcfg: PersAFLConfig, delays: DelayModel,
                 batch_size: int = 32, seed: int = 0):
        self.clients = clients
        self.pcfg = pcfg
        self.delays = delays
        self.batch_size = batch_size
        self.rng = np.random.RandomState(seed)
        self.loss_fn = loss_fn
        self.state = init_server_state(init_params)

        def _update(params, batches_3q):
            batches = split_batches_for_option(pcfg.option, batches_3q)
            return client_update(pcfg, loss_fn, params, batches)

        self._jit_update = jax.jit(_update)

    def _sample(self, i: int):
        return sample_batches(self.clients[i], self.rng,
                              3 * self.pcfg.q_local, self.batch_size)

    def run(self, *, max_server_rounds: int, eval_every: int = 50,
            eval_fn: Optional[Callable] = None,
            record_active_every: float = 5.0) -> History:
        hist = History()
        n = len(self.clients)
        heap: List = []
        seq = 0
        # phase[i]: ("down"|"up", finish_time); download requests start at t=0
        for i in range(n):
            t_done = self.delays.sample_download(i)
            heapq.heappush(heap, (t_done, seq, "down_done", i, None))
            seq += 1
        now = 0.0
        next_active_t = 0.0
        busy_up = {i: None for i in range(n)}  # upload finish times

        while self.state["t"] < max_server_rounds and heap:
            now, _, kind, i, payload = heapq.heappop(heap)
            # record active ratio on a time grid: active = computing/uploading
            while next_active_t <= now:
                up_now = sum(1 for v in busy_up.values()
                             if v is not None and v > next_active_t)
                hist.active_times.append(next_active_t)
                hist.active_ratio.append(up_now / n)
                next_active_t += record_active_every
            if kind == "down_done":
                version = int(self.state["t"])
                delta, _ = self._jit_update(self.state["params"],
                                            self._sample(i))
                t_up = now + self.delays.sample_upload(i)
                busy_up[i] = t_up
                heapq.heappush(heap, (t_up, seq, "up_done", i,
                                      (delta, version)))
                seq += 1
            elif kind == "up_done":
                delta, version = payload
                staleness = int(self.state["t"]) - version
                hist.staleness.append(staleness)
                self.state = apply_update(self.state, delta, self.pcfg.beta,
                                          staleness)
                busy_up[i] = None
                t_round = int(self.state["t"])
                if eval_fn is not None and t_round % eval_every == 0:
                    hist.times.append(now)
                    hist.rounds.append(t_round)
                    hist.acc.append(float(eval_fn(self.state["params"])))
                t_down = now + self.delays.sample_download(i)
                heapq.heappush(heap, (t_down, seq, "down_done", i, None))
                seq += 1
        self.final_stats = jax.tree.map(np.asarray,
                                        staleness_stats(self.state))
        return hist


class SyncSimulator:
    """Synchronous rounds (FedAvg-family baselines, paper Figure 2)."""

    def __init__(self, *, clients: List[ClientData], loss_fn: Callable,
                 init_params, pcfg: PersAFLConfig, delays: DelayModel,
                 algo: str = "fedavg", clients_per_round: int = 10,
                 batch_size: int = 32, seed: int = 0,
                 fedprox_mu: float = 0.1):
        self.clients = clients
        self.pcfg = pcfg
        self.delays = delays
        self.algo = algo
        self.m = clients_per_round
        self.batch_size = batch_size
        self.rng = np.random.RandomState(seed)
        self.loss_fn = loss_fn
        self.params = init_params
        if algo == "scaffold":
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 init_params)
            self.c_global = zeros
            self.c_clients = [zeros for _ in clients]

        option = {"fedavg": "A", "perfedavg": "B", "pfedme": "C",
                  "fedprox": "A", "scaffold": "A"}[algo]
        pcfg_local = dataclasses.replace(pcfg, option=option)
        self.pcfg_local = pcfg_local

        if algo == "fedprox":
            self._jit = jax.jit(lambda p, b: fedprox_update(
                pcfg_local, loss_fn, p,
                jax.tree.map(lambda x: x[:pcfg.q_local], b), mu=fedprox_mu))
        elif algo == "scaffold":
            self._jit = jax.jit(lambda p, b, cg, ci: scaffold_update(
                pcfg_local, loss_fn, p,
                jax.tree.map(lambda x: x[:pcfg.q_local], b), cg, ci))
        else:
            def _update(params, batches_3q):
                batches = split_batches_for_option(option, batches_3q)
                return client_update(pcfg_local, loss_fn, params, batches)
            self._jit = jax.jit(_update)

    def run(self, *, max_rounds: int, eval_every: int = 5,
            eval_fn: Optional[Callable] = None,
            record_active_every: float = 5.0) -> History:
        hist = History()
        n = len(self.clients)
        now = 0.0
        next_active_t = 0.0
        for rnd in range(max_rounds):
            sel = self.rng.choice(n, self.m, replace=False)
            finish, deltas = [], []
            c_updates = []
            for i in sel:
                b = sample_batches(self.clients[i], self.rng,
                                   3 * self.pcfg.q_local, self.batch_size)
                if self.algo == "scaffold":
                    delta, c_new, _ = self._jit(self.params, b,
                                                self.c_global,
                                                self.c_clients[i])
                    c_updates.append((i, c_new))
                else:
                    delta, _ = self._jit(self.params, b)
                deltas.append(delta)
                finish.append(self.delays.sample_download(int(i))
                              + self.delays.sample_upload(int(i)))
            round_len = max(finish)
            # active-ratio grid: client i is busy until its own finish time
            while next_active_t <= now + round_len:
                rel = next_active_t - now
                busy = sum(1 for f in finish if f > rel)
                hist.active_times.append(next_active_t)
                hist.active_ratio.append(busy / n)
                next_active_t += record_active_every
            now += round_len
            mean_delta = jax.tree.map(
                lambda *xs: sum(xs) / len(xs), *deltas)
            self.params = jax.tree.map(
                lambda w, d: (w.astype(jnp.float32)
                              - self.pcfg.beta * d).astype(w.dtype),
                self.params, mean_delta)
            if self.algo == "scaffold":
                for i, c_new in c_updates:
                    old = self.c_clients[i]
                    self.c_clients[i] = c_new
                    self.c_global = jax.tree.map(
                        lambda cg, cn, co: cg + (cn - co) / n,
                        self.c_global, c_new, old)
            if eval_fn is not None and (rnd + 1) % eval_every == 0:
                hist.times.append(now)
                hist.rounds.append(rnd + 1)
                hist.acc.append(float(eval_fn(self.params)))
        return hist
