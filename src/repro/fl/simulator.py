"""REMOVED — the PR-4 deprecation shims ended their one-release window.

The three legacy simulator classes lived here as ``DeprecationWarning``
shims from PR 4 until PR 10.  Importing them now raises ``ImportError``
with the exact :mod:`repro.fl.api` spelling to migrate to:

    AsyncSimulator(clients, loss_fn, init_params, pcfg, delays)
        -> FLRun(clients=..., loss_fn=..., init_params=..., pcfg=...,
                 delays=..., strategy="persafl", schedule=immediate())

    BufferedAsyncSimulator(..., buffer_size=M)
        -> FLRun(..., schedule=buffered(M))

    SyncSimulator(..., algo="fedavg"|"perfedavg"|"pfedme"|"fedprox"|
                  "scaffold", clients_per_round=m, fedprox_mu=mu)
        -> FLRun(..., strategy=algo, schedule=sync_barrier(m))
           (fedprox_mu=mu  ->  strategy=strategy("fedprox", mu=mu))

``run(max_server_rounds=N)`` is ``run(max_rounds=N)`` (the alias is still
accepted); History, eval hooks and the stats surface carry over unchanged.
"""
from __future__ import annotations

_REMOVED = {
    "AsyncSimulator":
        "FLRun(..., strategy='persafl', schedule=immediate())",
    "BufferedAsyncSimulator":
        "FLRun(..., schedule=buffered(M))",
    "SyncSimulator":
        "FLRun(..., strategy=<algo name>, schedule=sync_barrier(m))",
}


def __getattr__(name: str):
    if name in _REMOVED:
        raise ImportError(
            f"repro.fl.simulator.{name} was removed in PR 10 (deprecated "
            f"since PR 4); use {_REMOVED[name]} from repro.fl.api — the "
            f"repro.fl.simulator module docstring has the full migration "
            f"map.")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
