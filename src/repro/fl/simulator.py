"""Discrete-event federated-learning simulator.

Reproduces the paper's §5 communication setup: n clients with random
upload/download delays (upload 4–6× download), communication time dominating
local compute.  The simulator drives the *same jitted client/server step
functions* as the production launcher — only event ordering is simulated
(DESIGN.md §2).

Three schedulers:
  * :class:`AsyncSimulator` — Algorithm 1: the server applies each client's
    Δ the moment it arrives; staleness τ is measured per update.
  * :class:`BufferedAsyncSimulator` — FedBuff-style [51,63]: arrivals are
    buffered and M deltas are applied as one w ← w − β/M ΣΔ server round
    (``PersAFLConfig.buffer_size``); staleness bookkeeping still counts
    every contributing delta.
  * :class:`SyncSimulator`  — FedAvg-family rounds: sample m clients, wait
    for the slowest, apply the averaged Δ (supports FedAvg / Per-FedAvg /
    pFedMe / FedProx / SCAFFOLD via ``algo``).

Execution engine: per-client compute is *deferred*.  A client's batches are
recorded when its download completes and materialized lazily — in one
:class:`repro.fl.engine.CohortEngine` cohort call — right before the next
server apply.  Because params only change at applies, every delta is
computed on exactly the snapshot the per-event path would have used, while
the device sees one batched call per inter-apply window instead of one call
per client (the win grows with ``buffer_size``: applies thin out, cohorts
fatten up).  Each cohort call yields an on-device
:class:`repro.fl.engine.DeltaBank`; buffered and sync applies reduce the
stacked buffer with the fused ``apply_rows`` weight-vector pass (no
per-client host transfer), while the paper-faithful immediate apply
materializes single rows lazily and routes through the scalar fused-update
op (one read-modify-write pass, traced scale).

All schedulers record the active-client ratio over time (paper Figure 2a)
and accuracy-vs-simulated-time via a pluggable eval callback.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PersAFLConfig, admission_weights,
                        apply_buffered_rows, apply_update, init_server_state)
from repro.core.server import staleness_stats
from repro.data.federated import ClientData, sample_batches
from repro.fl.algorithms import fedprox_update, scaffold_update
from repro.fl.delays import DelayModel
from repro.fl.engine import CohortEngine, DeltaBank
from repro.kernels.fused_update.ops import apply_delta_tree, apply_rows_tree


@dataclasses.dataclass
class History:
    times: List[float] = dataclasses.field(default_factory=list)
    rounds: List[int] = dataclasses.field(default_factory=list)
    acc: List[float] = dataclasses.field(default_factory=list)
    active_times: List[float] = dataclasses.field(default_factory=list)
    active_ratio: List[float] = dataclasses.field(default_factory=list)
    staleness: List[int] = dataclasses.field(default_factory=list)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _own_copy(params):
    """Private copy of the caller's params: server applies donate the old
    buffer (in-place on TPU), which must never invalidate caller arrays."""
    return jax.tree.map(lambda x: jnp.array(x), params)


class AsyncSimulator:
    """PersA-FL / FedAsync event-driven runner (Algorithms 1 & 2).

    ``vectorized=False`` keeps the per-event sequential dispatch (the
    baseline the ``engine`` benchmark row measures against).
    """

    def __init__(self, *, clients: List[ClientData], loss_fn: Callable,
                 init_params, pcfg: PersAFLConfig, delays: DelayModel,
                 batch_size: int = 32, seed: int = 0,
                 vectorized: bool = True):
        self.clients = clients
        self.pcfg = pcfg
        self.delays = delays
        self.batch_size = batch_size
        self.rng = np.random.RandomState(seed)
        self.loss_fn = loss_fn
        self.state = init_server_state(_own_copy(init_params))
        self.engine = CohortEngine(pcfg, loss_fn, vectorized=vectorized)

    def _sample(self, i: int):
        return sample_batches(self.clients[i], self.rng,
                              3 * self.pcfg.q_local, self.batch_size)

    # -- apply-side hook (overridden by BufferedAsyncSimulator) ------------

    def _on_upload(self, now: float, rid: int, version: int, hist: History,
                   eval_fn, eval_every: int) -> None:
        """Paper-faithful Algorithm 1: apply the delta the moment it lands."""
        self._flush()
        bank, idx = self._computed.pop(rid)
        # per-row host materialization keeps exact single-delta semantics
        # (one transfer of the whole bank, numpy views per row after that)
        delta = bank.row(idx)
        # _t mirrors state["t"] host-side: reading the device scalar every
        # event would force a sync per event — O(n) stalls per window
        staleness = self._t - version
        hist.staleness.append(staleness)
        self.state = apply_update(self.state, delta, self.pcfg.beta,
                                  staleness,
                                  damping=self.pcfg.staleness_damping)
        self._t += 1
        if eval_fn is not None and self._t % eval_every == 0:
            hist.times.append(now)
            hist.rounds.append(self._t)
            hist.acc.append(float(eval_fn(self.state["params"])))

    def _flush(self) -> None:
        """Materialize every pending client update in one cohort call.

        Called right before any server apply: params have not changed since
        these clients' downloads completed, so the whole cohort shares one
        snapshot and the cohort call is exact.  Deltas are recorded as
        (DeltaBank, row) handles — the stacked buffer stays on device and a
        bank outlives its window for clients whose upload lands after the
        next apply."""
        if not self._pending:
            return
        bank = self.engine.update_cohort(
            self.state["params"], [b for _, b in self._pending])
        for idx, (rid, _) in enumerate(self._pending):
            self._computed[rid] = (bank, idx)
        self._pending = []

    def run(self, *, max_server_rounds: int, eval_every: int = 50,
            eval_fn: Optional[Callable] = None,
            record_active_every: float = 5.0) -> History:
        hist = History()
        n = len(self.clients)
        heap: List = []
        seq = 0
        # download requests start at t=0
        for i in range(n):
            t_done = self.delays.sample_download(i)
            heapq.heappush(heap, (t_done, seq, "down_done", i, None))
            seq += 1
        now = 0.0
        next_active_t = 0.0
        busy_up = {i: None for i in range(n)}  # upload finish times
        self._pending: List[Tuple[int, Dict]] = []  # (rid, batches)
        self._computed: Dict[int, Tuple] = {}       # rid -> (DeltaBank, row)
        self._t = int(self.state["t"])              # host-side round mirror
        next_rid = 0

        while self._t < max_server_rounds and heap:
            now, _, kind, i, payload = heapq.heappop(heap)
            # record active ratio on a time grid: active = computing/uploading
            while next_active_t <= now:
                up_now = sum(1 for v in busy_up.values()
                             if v is not None and v > next_active_t)
                hist.active_times.append(next_active_t)
                hist.active_ratio.append(up_now / n)
                next_active_t += record_active_every
            if kind == "down_done":
                version = self._t
                rid = next_rid
                next_rid += 1
                self._pending.append((rid, self._sample(i)))
                t_up = now + self.delays.sample_upload(i)
                busy_up[i] = t_up
                heapq.heappush(heap, (t_up, seq, "up_done", i,
                                      (rid, version)))
                seq += 1
            elif kind == "up_done":
                rid, version = payload
                self._on_upload(now, rid, version, hist, eval_fn, eval_every)
                busy_up[i] = None
                t_down = now + self.delays.sample_download(i)
                heapq.heappush(heap, (t_down, seq, "down_done", i, None))
                seq += 1
        self.final_stats = jax.tree.map(np.asarray,
                                        staleness_stats(self.state))
        return hist


class BufferedAsyncSimulator(AsyncSimulator):
    """FedBuff-style buffered asynchronous scheduler (beyond-paper [51,63]).

    Arrivals accumulate in a size-M buffer (``pcfg.buffer_size``); when full,
    every still-pending client update is computed in ONE cohort call and the
    buffer is applied as one w ← w − β/M ΣΔ server round, consumed straight
    from the on-device DeltaBank through ``apply_rows`` — flushes never move
    per-client deltas to the host (``engine.stats["host_materializations"]``
    stays 0).  Between flushes the params are frozen, so cohorts grow to ≳M
    clients — this is the scheduler the vectorized engine was built for.
    Staleness Σ/max are accounted per contributing delta (Assumption 1
    bookkeeping).

    Note: t advances in M-sized jumps, so a run stops at the first flush
    that reaches ``max_server_rounds`` — the final t is the next multiple
    of M (an overshoot bounded by M), like finishing a partial epoch."""

    def __init__(self, *, buffer_size: Optional[int] = None, **kw):
        super().__init__(**kw)
        self.buffer_size = buffer_size or max(int(self.pcfg.buffer_size), 1)
        self._buffer: List[Tuple[int, int]] = []  # (rid, staleness)

    def run(self, **kw) -> History:
        self._buffer = []
        return super().run(**kw)

    def _on_upload(self, now: float, rid: int, version: int, hist: History,
                   eval_fn, eval_every: int) -> None:
        staleness = self._t - version
        hist.staleness.append(staleness)
        self._buffer.append((rid, staleness))
        if len(self._buffer) < self.buffer_size:
            return
        self._flush()  # compute buffered AND in-flight pending deltas
        m = len(self._buffer)
        damping = self.pcfg.staleness_damping
        # group the buffer's rows by owning DeltaBank (in-flight clients
        # were computed in an earlier window's bank) and consume each bank
        # on device: β/M and the per-delta FedAsync discount (1+τ)^{-a} —
        # which must act BEFORE the sum, a post-sum scale could not tell
        # fresh deltas from stale ones — are rows of ONE weight vector, and
        # the whole flush is one fused apply_rows pass per bank instead of
        # M host-side tree.maps.
        groups: Dict[int, Tuple[DeltaBank, List[Tuple[int, int]]]] = {}
        for r, s in self._buffer:
            bank, idx = self._computed.pop(r)
            groups.setdefault(id(bank), (bank, []))[1].append((idx, s))
        t_old = self._t
        for bank, rows in groups.values():
            weights = admission_weights(bank.capacity, rows,
                                        beta=self.pcfg.beta, count=m,
                                        damping=damping)
            self.state = apply_buffered_rows(
                self.state, bank.stacked, weights, len(rows),
                staleness_max=max(s for _, s in rows),
                staleness_sum=float(sum(s for _, s in rows)))
        self._buffer = []
        self._t = t_old + m
        # t jumps by M per flush: eval whenever a multiple of eval_every
        # is crossed (the immediate-apply modulo test would skip most)
        if eval_fn is not None \
                and self._t // eval_every > t_old // eval_every:
            hist.times.append(now)
            hist.rounds.append(self._t)
            hist.acc.append(float(eval_fn(self.state["params"])))


class SyncSimulator:
    """Synchronous rounds (FedAvg-family baselines, paper Figure 2).

    The m sampled clients of a round share the round's params by definition,
    so fedavg/perfedavg/pfedme rounds run as one cohort-engine call;
    fedprox/scaffold carry per-client control state and keep the sequential
    path.  The server apply routes through the fused-update op."""

    def __init__(self, *, clients: List[ClientData], loss_fn: Callable,
                 init_params, pcfg: PersAFLConfig, delays: DelayModel,
                 algo: str = "fedavg", clients_per_round: int = 10,
                 batch_size: int = 32, seed: int = 0,
                 fedprox_mu: float = 0.1, vectorized: bool = True):
        self.clients = clients
        self.pcfg = pcfg
        self.delays = delays
        self.algo = algo
        self.m = clients_per_round
        self.batch_size = batch_size
        self.rng = np.random.RandomState(seed)
        self.loss_fn = loss_fn
        self.params = _own_copy(init_params)
        if algo == "scaffold":
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 init_params)
            self.c_global = zeros
            self.c_clients = [zeros for _ in clients]

        option = {"fedavg": "A", "perfedavg": "B", "pfedme": "C",
                  "fedprox": "A", "scaffold": "A"}[algo]
        pcfg_local = dataclasses.replace(pcfg, option=option)
        self.pcfg_local = pcfg_local
        self.engine = CohortEngine(pcfg_local, loss_fn,
                                   vectorized=vectorized)

        if algo == "fedprox":
            self._jit = jax.jit(lambda p, b: fedprox_update(
                pcfg_local, loss_fn, p,
                jax.tree.map(lambda x: x[:pcfg.q_local], b), mu=fedprox_mu))
        elif algo == "scaffold":
            self._jit = jax.jit(lambda p, b, cg, ci: scaffold_update(
                pcfg_local, loss_fn, p,
                jax.tree.map(lambda x: x[:pcfg.q_local], b), cg, ci))

    def run(self, *, max_rounds: int, eval_every: int = 5,
            eval_fn: Optional[Callable] = None,
            record_active_every: float = 5.0) -> History:
        hist = History()
        n = len(self.clients)
        now = 0.0
        next_active_t = 0.0
        for rnd in range(max_rounds):
            sel = self.rng.choice(n, self.m, replace=False)
            batches = [sample_batches(self.clients[i], self.rng,
                                      3 * self.pcfg.q_local, self.batch_size)
                       for i in sel]
            c_updates = []
            if self.algo == "scaffold":
                deltas = []
                for i, b in zip(sel, batches):
                    delta, c_new, _ = self._jit(self.params, b,
                                                self.c_global,
                                                self.c_clients[i])
                    c_updates.append((i, c_new))
                    deltas.append(delta)
                mean_delta = jax.tree.map(lambda *xs: sum(xs) / len(xs),
                                          *deltas)
            elif self.algo == "fedprox":
                deltas = [self._jit(self.params, b)[0] for b in batches]
                mean_delta = jax.tree.map(lambda *xs: sum(xs) / len(xs),
                                          *deltas)
            else:
                # engine-path rounds consume the DeltaBank on device: the
                # mean AND the β-scaled apply fuse into one apply_rows pass
                # (weights = β/m on real rows, 0 on bucket padding)
                bank = self.engine.update_cohort(self.params, batches)
                mean_delta = None
            finish = [self.delays.sample_download(int(i))
                      + self.delays.sample_upload(int(i)) for i in sel]
            round_len = max(finish)
            # active-ratio grid: client i is busy until its own finish time
            while next_active_t <= now + round_len:
                rel = next_active_t - now
                busy = sum(1 for f in finish if f > rel)
                hist.active_times.append(next_active_t)
                hist.active_ratio.append(busy / n)
                next_active_t += record_active_every
            now += round_len
            if mean_delta is not None:
                self.params = apply_delta_tree(self.params, mean_delta,
                                               jnp.float32(self.pcfg.beta))
            else:
                weights = np.zeros(bank.capacity, np.float32)
                weights[:len(batches)] = self.pcfg.beta / len(batches)
                self.params = apply_rows_tree(self.params, bank.stacked,
                                              weights)
            if self.algo == "scaffold":
                for i, c_new in c_updates:
                    old = self.c_clients[i]
                    self.c_clients[i] = c_new
                    self.c_global = jax.tree.map(
                        lambda cg, cn, co: cg + (cn - co) / n,
                        self.c_global, c_new, old)
            if eval_fn is not None and (rnd + 1) % eval_every == 0:
                hist.times.append(now)
                hist.rounds.append(rnd + 1)
                hist.acc.append(float(eval_fn(self.params)))
        return hist

