"""Declarative Strategy/Scheduler API: one run surface for simulation,
baselines, and serving (PR 4 tentpole).

The paper's Options A/B/C and its §5 baselines (Per-FedAvg, pFedMe, FedProx,
SCAFFOLD) are all "a local update rule plus a server apply policy".  This
module makes that factoring literal:

  * :class:`Strategy` — the local update rule.  ``init_client_state(params)``
    and ``local_update(params, batches, cstate) -> (delta, cstate, metrics)``;
    instances come from the registry (``strategy("fedprox", mu=0.1)``,
    ``strategy("persafl", option="B")``, …).  Client state is a *stacked
    pytree threaded through the cohort vmap/shard_map*, so stateful
    baselines (SCAFFOLD control variates) ride the exact same
    :class:`repro.fl.engine.CohortEngine` fast path as everyone else and
    their deltas land in the on-device DeltaBank.
  * :class:`ApplyPolicy` — the server apply schedule.  ``immediate()`` is
    Algorithm 1's paper-faithful per-arrival apply, ``buffered(M)`` the
    FedBuff-style M-deltas-per-round flush consumed straight from the bank
    through the fused ``apply_rows`` weight vector, ``sync_barrier(m)``
    FedAvg-family rounds that wait for the slowest of m sampled clients.
  * :class:`FLRun` — the one event-loop core replacing the three legacy
    simulator classes.  Strategy and schedule compose freely:
    ``FLRun(strategy="scaffold", schedule=sync_barrier(10), ...)`` is the
    old ``SyncSimulator(algo="scaffold")``;
    ``FLRun(strategy=strategy("persafl", option="C"),
    schedule=buffered(8), ...)`` is the old ``BufferedAsyncSimulator``.
    All schedules share the History / active-ratio / staleness bookkeeping
    and the typed :class:`repro.core.ServerState`.

Every new strategy automatically inherits the DeltaBank / ``apply_rows`` /
shard_map machinery — register it once and it runs under all three
schedules, the benchmarks, and (stateless ones) the serving micro-batcher.

The legacy class names (``AsyncSimulator``, ``BufferedAsyncSimulator``,
``SyncSimulator``) were removed in PR 10 after their one-release
deprecation window; :mod:`repro.fl.simulator` keeps ImportError
breadcrumbs with the exact FLRun spelling for each.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PersAFLConfig, admission_weights,
                        apply_buffered_rows, apply_update, client_update,
                        init_server_state, mask_rows,
                        robust_flush_weights, scale_rows,
                        split_batches_for_option)
from repro.core.moreau import solve_prox
from repro.core.server import staleness_stats
from repro.data.federated import sample_batches
from repro.fl.algorithms import fedprox_update, scaffold_update
from repro.fl.engine import CohortEngine, DeltaBank


@dataclasses.dataclass
class History:
    """Run trace shared by every schedule: accuracy-vs-simulated-time,
    active-client ratio on a time grid (paper Figure 2a), and per-applied-
    update staleness (Assumption 1 bookkeeping; empty for sync rounds).

    ``loss`` is recorded alongside ``acc`` whenever the run's ``eval_fn``
    reports one (a ``(acc, loss)`` pair or an ``{"acc":, "loss":}`` dict —
    scalar returns stay acc-only, so pre-existing eval functions keep
    their exact behavior).  When present it is parallel to ``times`` /
    ``rounds`` / ``acc``; the :mod:`repro.tune` stop rules read it live
    through the ``on_eval`` callback.
    """
    times: List[float] = dataclasses.field(default_factory=list)
    rounds: List[int] = dataclasses.field(default_factory=list)
    acc: List[float] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    active_times: List[float] = dataclasses.field(default_factory=list)
    active_ratio: List[float] = dataclasses.field(default_factory=list)
    staleness: List[int] = dataclasses.field(default_factory=list)
    # simulated time at which the run actually stopped — NOT the
    # 5s-grid-quantized last active_times entry; equal-simulated-time
    # comparisons must budget on this.  When a max_time budget binds the
    # event loop, end_time is clamped to exactly max_time (the first event
    # past the budget never runs and never advances the clock)
    end_time: float = 0.0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _normalize_eval(result) -> Tuple[float, Optional[float]]:
    """Normalize an ``eval_fn`` return to ``(acc, loss-or-None)``.

    Accepted spellings: a bare scalar (accuracy only — the historical
    contract), a 2-sequence ``(acc, loss)``, or a dict with ``"acc"`` and
    an optional ``"loss"``.
    """
    if isinstance(result, dict):
        loss = result.get("loss")
        return float(result["acc"]), None if loss is None else float(loss)
    if isinstance(result, (tuple, list)):
        if len(result) != 2:
            raise ValueError(f"eval_fn returned a {len(result)}-sequence; "
                             f"expected (acc, loss)")
        return float(result[0]), float(result[1])
    return float(result), None


def _own_copy(params):
    """Private copy of the caller's params: server applies donate the old
    buffer (in-place on TPU), which must never invalidate caller arrays."""
    return jax.tree.map(lambda x: jnp.array(x), params)


# ---------------------------------------------------------------------------
# Strategy protocol + registry
# ---------------------------------------------------------------------------

class Strategy:
    """A local update rule with a client-state lifecycle.

    Protocol (all jit-traceable in ``local_update``):

      * ``init_client_state(params)`` — the per-client state carried across
        rounds (None for stateless rules; SCAFFOLD returns its control
        variate c_i).
      * ``local_update(params, batches, cstate) -> (delta, cstate, metrics)``
        — one client's contribution against a frozen params snapshot.  The
        delta is params-shaped f32 (bank-row compatible); metrics are
        dead-code-eliminated on the cohort path.
      * ``dispatch_state(cstate)`` — host-side hook run right before a
        cohort dispatch (per-client pre-processing; identity by default).
      * ``shared_state()`` / ``assemble_state(cstate, shared)`` — the
        strategy-shared server-side input.  ``shared_state()`` is read once
        per cohort call and passed *replicated* (vmap in-axis None /
        shard_map ``P()``), and ``assemble_state`` recombines it with each
        client's row inside the traced cohort body — SCAFFOLD ships ONE
        c_global per call instead of one copy per cohort row.
      * ``post_round(updates, n_clients)`` — host-side hook run after the
        cohort's states are written back; ``updates`` is
        ``[(client_index, old_cstate, new_cstate), ...]`` in dispatch
        order (SCAFFOLD folds Δc into c_global here).

    Instances are single-run objects: :meth:`bind` attaches the run's
    (pcfg, loss_fn) and resets any strategy-shared state.

    ``personal_subset`` declares the *partial-model personalization* split
    (arXiv 2309.17409): any :class:`repro.core.SubsetSpec` spelling — path
    prefixes like ``("fc/#1",)`` or a pytree bool mask — naming the
    personal leaves.  A strategy that honors it returns deltas in the
    pruned subset structure (``SubsetSpec.extract``), so bank rows, ring
    snapshots and wire frames shrink to the subset while the shared
    backbone flows untouched; None (the default) keeps full-model deltas.
    """

    name = "strategy"
    option = "A"        # batch-split layout, for introspection
    stateful = False
    personal_subset = None   # SubsetSpec spelling, or None = full model

    def bind(self, pcfg: PersAFLConfig, loss_fn: Callable) -> "Strategy":
        self.pcfg = pcfg
        self.loss_fn = loss_fn
        return self

    def init_client_state(self, params):
        return None

    def dispatch_state(self, cstate):
        return cstate

    def shared_state(self):
        """Strategy-shared cohort input, replicated (not stacked) across
        the cohort axis; None for strategies without one."""
        return None

    def assemble_state(self, cstate, shared):
        """Recombine one client's state row with the shared input inside
        the traced cohort body (identity for shared-less strategies)."""
        return cstate

    def local_update(self, params, batches, cstate):
        raise NotImplementedError

    def post_round(self, updates: List[Tuple[int, object, object]],
                   n_clients: int) -> None:
        pass


_REGISTRY: Dict[str, Callable[..., Strategy]] = {}


def register_strategy(*names):
    """Class decorator: ``@register_strategy("fedprox")`` makes the rule
    constructible as ``strategy("fedprox", **kw)`` everywhere — FLRun, the
    benchmarks, and the serving micro-batcher."""
    def deco(factory):
        for nm in names:
            _REGISTRY[nm] = factory
        return factory
    return deco


def strategy(name: str, **kw) -> Strategy:
    """Construct a registered strategy: ``strategy("persafl", option="B")``,
    ``strategy("fedprox", mu=0.1)``, ``strategy("scaffold")``, …"""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"have {sorted(_REGISTRY)}") from None
    return factory(**kw)


def strategy_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_strategy(s) -> Strategy:
    if isinstance(s, str):
        return strategy(s)
    if isinstance(s, Strategy):
        return s
    raise TypeError(f"strategy must be a name or a Strategy, got {type(s)}")


@register_strategy("persafl")
class PersAFLStrategy(Strategy):
    """Algorithm 2, Options A/B/C (this paper): Q local steps of plain SGD
    (A), MAML (B, Per-FedAvg's rule) or Moreau-envelope prox grads (C,
    pFedMe's rule).  ``option=None`` takes the bound pcfg's option."""

    name = "persafl"

    def __init__(self, option: Optional[str] = None):
        self._option = option

    def bind(self, pcfg, loss_fn):
        self.option = self._option or pcfg.option
        return super().bind(dataclasses.replace(pcfg, option=self.option),
                            loss_fn)

    def local_update(self, params, batches_3q, cstate):
        delta, metrics = client_update(
            self.pcfg, self.loss_fn, params,
            split_batches_for_option(self.option, batches_3q))
        return delta, None, metrics


# the §5 baseline names are option presets of the same rule
for _nm, _opt in (("fedavg", "A"), ("fedasync", "A"),
                  ("perfedavg", "B"), ("pfedme", "C")):
    _REGISTRY[_nm] = functools.partial(PersAFLStrategy, option=_opt)


@register_strategy("fedprox")
class FedProxStrategy(Strategy):
    """FedProx [42]: local SGD on f_i(w) + μ/2 ‖w − w^t‖² (Option A
    batches).  Stateless; formerly exiled to a sequential per-client jit
    loop in SyncSimulator, now a plain cohort citizen."""

    name = "fedprox"

    def __init__(self, mu: float = 0.1):
        self.mu = mu

    def bind(self, pcfg, loss_fn):
        return super().bind(dataclasses.replace(pcfg, option="A"), loss_fn)

    def local_update(self, params, batches_3q, cstate):
        q = self.pcfg.q_local
        delta, metrics = fedprox_update(
            self.pcfg, self.loss_fn, params,
            jax.tree.map(lambda x: x[:q], batches_3q), mu=self.mu)
        return delta, None, metrics


@register_strategy("scaffold")
class ScaffoldStrategy(Strategy):
    """SCAFFOLD [34] (Option I): the first *stateful* registry strategy.

    Per-client state is the control variate c_i (params-shaped f32,
    stacked over the cohort axis); the shared c_global is injected into
    every dispatch via :meth:`dispatch_state` and updated host-side in
    :meth:`post_round` — c_global += (c_i⁺ − c_i)/n per participating
    client, in dispatch order (the legacy sequential path's exact fold).
    """

    name = "scaffold"
    stateful = True

    def bind(self, pcfg, loss_fn):
        self.c_global = None
        return super().bind(dataclasses.replace(pcfg, option="A"), loss_fn)

    def init_client_state(self, params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        if self.c_global is None:
            self.c_global = zeros
        return zeros

    def shared_state(self):
        return self.c_global

    def assemble_state(self, cstate, shared):
        return {"c": shared, "c_i": cstate}

    def local_update(self, params, batches_3q, dstate):
        q = self.pcfg.q_local
        delta, c_new, metrics = scaffold_update(
            self.pcfg, self.loss_fn, params,
            jax.tree.map(lambda x: x[:q], batches_3q),
            dstate["c"], dstate["c_i"])
        return delta, c_new, metrics

    def post_round(self, updates, n_clients):
        for _, c_old, c_new in updates:
            self.c_global = jax.tree.map(
                lambda cg, cn, co: cg + (cn - co) / n_clients,
                self.c_global, c_new, c_old)


@register_strategy("personalize")
class PersonalizeStrategy(Strategy):
    """Serving-side personalization delta (head = w − delta).

    mode "B": delta = α ∇f(w; D)   (head = the one-step MAML fine-tune)
    mode "C": delta = w − θ̃(w)     (head = the Moreau prox solution θ̃)

    ``batches`` here is the user's raw request batch (no leading-Q axis).
    Deltas accumulate in f32 like training deltas, so bank rows are
    directly consumable by the fused ``apply_rows`` server pass — this is
    the strategy behind :class:`repro.serving.PersonalizationServer`,
    replacing the old ``CohortEngine(client_fn=...)`` override.

    With ``personal_subset`` set, only the subset is personalized: the
    gradient / prox solve runs over the subset leaves with the backbone
    *frozen* at the global params (partial-model personalization, arXiv
    2309.17409), and the delta comes back in the pruned subset structure —
    a bank row shrinks from full-model to head-only bytes.
    """

    name = "personalize"

    def __init__(self, mode: str = "C", personal_subset=None):
        if mode not in ("B", "C"):
            raise ValueError(f"unknown personalization mode {mode!r}; "
                             f"have ('B', 'C')")
        self.mode = mode
        self.option = mode
        from repro.core.subset import SubsetSpec
        self.personal_subset = SubsetSpec.resolve(personal_subset)

    def local_update(self, params, batch, cstate):
        from repro.core.subset import merge_subset
        spec = self.personal_subset
        if spec is None:
            sub0, loss_fn = params, self.loss_fn
        else:
            # personalize the subset against a frozen backbone: grad/prox
            # run over the pruned subset tree, the closure re-merges it
            # into the full params for the loss
            sub0 = spec.extract(params)
            loss_fn = lambda s, b: self.loss_fn(merge_subset(params, s), b)
        if self.mode == "B":
            g = jax.grad(loss_fn)(sub0, batch)
            delta = jax.tree.map(
                lambda gg: self.pcfg.alpha * gg.astype(jnp.float32), g)
        else:
            theta, _ = solve_prox(loss_fn, sub0, batch,
                                  self.pcfg.lam, self.pcfg.inner_eta,
                                  self.pcfg.inner_steps)
            delta = jax.tree.map(
                lambda w, t: w.astype(jnp.float32) - t.astype(jnp.float32),
                sub0, theta)
        return delta, None, {}


# ---------------------------------------------------------------------------
# Apply policies (server schedules)
# ---------------------------------------------------------------------------

class ApplyPolicy:
    """Server apply schedule.  ``kind="event"`` policies plug into the
    async discrete-event loop via :meth:`on_upload`; ``kind="round"``
    policies drive barrier rounds.  Instances hold per-run state — create
    one per FLRun."""

    kind = "event"
    default_eval_every = 50

    def start(self, run: "FLRun") -> None:
        """Reset per-run policy state (called at the top of ``run()``)."""

    def on_upload(self, run: "FLRun", now: float, rid: int, version: int,
                  hist: History, eval_fn, eval_every: int) -> None:
        raise NotImplementedError


class Immediate(ApplyPolicy):
    """Paper-faithful Algorithm 1: apply each delta the moment it lands
    (staleness τ measured per update)."""

    def on_upload(self, run, now, rid, version, hist, eval_fn, eval_every):
        run._flush()
        bank, idx = run._computed.pop(rid)
        # per-row host materialization keeps exact single-delta semantics
        # (one transfer of the whole bank, numpy views per row after that)
        delta = bank.row(idx)
        # _t mirrors state.t host-side: reading the device scalar every
        # event would force a sync per event — O(n) stalls per window
        staleness = run._t - version
        hist.staleness.append(staleness)
        run.state = apply_update(run.state, delta, run.pcfg.beta, staleness,
                                 damping=run.pcfg.staleness_damping)
        run._record_window(now, 1, [staleness])
        run._t += 1
        if eval_fn is not None and run._t % eval_every == 0:
            run._record_eval(hist, now, eval_fn, run._t)


class Buffered(ApplyPolicy):
    """FedBuff-style buffered apply (beyond-paper [51,63]): arrivals
    accumulate in a size-M buffer; a full buffer flushes as ONE
    w ← w − β/M ΣΔ server round consumed straight from the on-device
    DeltaBank through the fused ``apply_rows`` weight vector (β/M,
    per-delta FedAsync damping ``(1+τ)^{-a}`` and padding masks are rows
    of one ``[bucket]`` array) — flushes never move per-client deltas to
    the host.  t advances in M-sized jumps; staleness Σ/max are accounted
    per contributing delta.

    ``robust="clip"`` / ``"trim"`` routes the flush through
    :func:`repro.core.robust_flush_weights` instead — per-row norm
    clipping or a norm-based trimmed mean calibrated over the WHOLE
    buffer, across the banks its rows live in (row norms reduced on
    device via :func:`repro.core.bank_row_norms`; non-finite rows zeroed
    through :func:`repro.core.mask_rows`), the defense
    against the scenario engine's adversarial clients
    (:class:`repro.fl.scenario.ChurnModel`).  Under trim, t still
    advances by the full buffer size — trimmed admissions contribute a
    zero weight, exactly like tau_max-dropped rows on the plain path."""

    def __init__(self, m: Optional[int] = None, *,
                 robust: Optional[str] = None,
                 clip_norm: Optional[float] = None,
                 trim_frac: float = 0.1):
        if robust not in (None, "clip", "trim"):
            raise ValueError(f"robust must be None, 'clip' or 'trim', "
                             f"got {robust!r}")
        self.m = m                # configured; None = the run's pcfg M
        self.robust = robust
        self.clip_norm = clip_norm
        self.trim_frac = trim_frac

    def start(self, run):
        # resolved per run — m=None must re-read each run's buffer_size
        self.m_effective = self.m if self.m is not None \
            else max(int(run.pcfg.buffer_size), 1)
        self._buffer: List[Tuple[int, int]] = []  # (rid, staleness)

    def on_upload(self, run, now, rid, version, hist, eval_fn, eval_every):
        staleness = run._t - version
        hist.staleness.append(staleness)
        self._buffer.append((rid, staleness))
        if len(self._buffer) < self.m_effective:
            return
        run._flush()  # compute buffered AND in-flight pending deltas
        m = len(self._buffer)
        damping = run.pcfg.staleness_damping
        # group the buffer's rows by owning DeltaBank (in-flight clients
        # were computed in an earlier window's bank) and consume each bank
        # on device: β/M and the per-delta FedAsync discount (1+τ)^{-a} —
        # which must act BEFORE the sum, a post-sum scale could not tell
        # fresh deltas from stale ones — are rows of ONE weight vector,
        # and the whole flush is one fused apply_rows pass per bank
        # instead of M host-side tree.maps.
        groups: Dict[int, Tuple[DeltaBank, List[Tuple[int, int]]]] = {}
        for r, s in self._buffer:
            bank, idx = run._computed.pop(r)
            groups.setdefault(id(bank), (bank, []))[1].append((idx, s))
        t_old = run._t
        robust_info = {"clipped": 0, "trimmed": 0, "nonfinite": 0}
        if self.robust is not None:
            # one call for the whole flush: the defense calibrates over
            # ALL m admissions, not per owning bank — a corrupted row
            # alone in its 1-row group would set its own clip median
            per_bank, robust_info = robust_flush_weights(
                groups, beta=run.pcfg.beta, count=m, damping=damping,
                method=self.robust, clip_norm=self.clip_norm,
                trim_frac=self.trim_frac)
        for key, (bank, rows) in groups.items():
            if self.robust is not None:
                weights, keep = per_bank[key]
                # non-finite rows are masked out of the stack, not just
                # zero-weighted: 0 × NaN = NaN
                stack = bank.stacked if bool(keep.all()) \
                    else mask_rows(bank.stacked, keep)
            else:
                weights = admission_weights(bank.capacity, rows,
                                            beta=run.pcfg.beta, count=m,
                                            damping=damping)
                stack = bank.stacked
            run.state = apply_buffered_rows(
                run.state, stack, weights, len(rows),
                staleness_max=max(s for _, s in rows),
                staleness_sum=float(sum(s for _, s in rows)))
        run._record_window(now, m, [s for _, s in self._buffer],
                           robust_clipped=robust_info["clipped"],
                           robust_trimmed=robust_info["trimmed"],
                           robust_nonfinite=robust_info["nonfinite"])
        self._buffer = []
        run._t = t_old + m
        # t jumps by M per flush: eval whenever a multiple of eval_every
        # is crossed (the immediate-apply modulo test would skip most)
        if eval_fn is not None \
                and run._t // eval_every > t_old // eval_every:
            run._record_eval(hist, now, eval_fn, run._t)


class SyncBarrier(ApplyPolicy):
    """FedAvg-family synchronous rounds: sample m clients, wait for the
    slowest, fold the cohort's bank into the params with one fused
    ``apply_rows`` pass (weights = β/m on real rows, 0 on padding)."""

    kind = "round"
    default_eval_every = 5

    def __init__(self, m: int = 10):
        self.m = m


def immediate() -> Immediate:
    return Immediate()


def buffered(m: Optional[int] = None, *, robust: Optional[str] = None,
             clip_norm: Optional[float] = None,
             trim_frac: float = 0.1) -> Buffered:
    """``m=None`` takes ``pcfg.buffer_size`` at run time.  ``robust=``
    selects the Byzantine-robust flush ("clip" / "trim"; see
    :class:`Buffered`)."""
    return Buffered(m, robust=robust, clip_norm=clip_norm,
                    trim_frac=trim_frac)


def sync_barrier(m: int = 10) -> SyncBarrier:
    return SyncBarrier(m)


_SCHEDULES: Dict[str, Callable[[], ApplyPolicy]] = {
    "immediate": immediate, "buffered": buffered,
    "sync": sync_barrier, "sync_barrier": sync_barrier,
}


def resolve_schedule(s) -> ApplyPolicy:
    if isinstance(s, str):
        try:
            return _SCHEDULES[s]()
        except KeyError:
            raise ValueError(f"unknown schedule {s!r}; "
                             f"have {sorted(_SCHEDULES)}") from None
    if isinstance(s, ApplyPolicy):
        return s
    raise TypeError(f"schedule must be a name or an ApplyPolicy, "
                    f"got {type(s)}")


# ---------------------------------------------------------------------------
# FLRun — the one event-loop core
# ---------------------------------------------------------------------------

class FLRun:
    """One federated run = a Strategy × an ApplyPolicy × a DelayModel.

    Replaces AsyncSimulator / BufferedAsyncSimulator / SyncSimulator with a
    single core sharing the engine dispatch, the typed
    :class:`repro.core.ServerState`, and the History / active-ratio /
    staleness bookkeeping.  Per-client compute is *deferred* exactly as
    before: batches are recorded when a download completes and materialized
    lazily — in one :class:`CohortEngine` cohort call, client state stacked
    alongside — right before the next server apply, so every delta is
    computed on the snapshot the per-event path would have used.

    ``vectorized=False`` keeps the per-event sequential dispatch (the
    baseline the ``engine`` benchmark row measures against).
    """

    def __init__(self, *, clients: List, loss_fn: Callable, init_params,
                 pcfg: PersAFLConfig, delays,
                 strategy="persafl", schedule="immediate",
                 batch_size: int = 32, seed: int = 0,
                 vectorized: bool = True, cohort_impl: str = "auto",
                 scheduler: str = "auto", mesh=None, param_shardings=None):
        if scheduler not in ("auto", "heap", "device"):
            raise ValueError(f"scheduler must be 'auto', 'heap' or "
                             f"'device', got {scheduler!r}")
        self.clients = clients
        self.pcfg = pcfg
        self.delays = delays
        self.batch_size = batch_size
        self.rng = np.random.RandomState(seed)
        self.loss_fn = loss_fn
        self.strategy = resolve_strategy(strategy).bind(pcfg, loss_fn)
        self.schedule = resolve_schedule(schedule)
        self.scheduler = scheduler
        self.state = init_server_state(_own_copy(init_params))
        # mesh / param_shardings thread straight to the engine: on a 2-D
        # ("cohort", "model") mesh the run's banks come back sharded on
        # both axes (see repro.sharding.ctx.cohort_model_mesh)
        self.engine = CohortEngine(self.strategy.pcfg, loss_fn,
                                   vectorized=vectorized,
                                   cohort_impl=cohort_impl,
                                   strategy=self.strategy, mesh=mesh,
                                   param_shardings=param_shardings)
        self._cstates: List = [None] * len(clients)
        self._on_eval: Optional[Callable] = None
        self._stop = False
        self.final_stats: Optional[Dict] = None
        # per-window scheduler observability (see _record_window)
        self.scheduler_stats: Dict = {
            "scheduler": scheduler, "windows": 0, "cohort_fill_sum": 0,
            "cohort_fill_max": 0, "dropouts": 0, "corrupted_rows": 0,
            "robust_clipped": 0, "robust_trimmed": 0,
            "robust_nonfinite": 0}
        self.window_log: List[Dict] = []
        self._window_log_cap = 1024

    # -- shared plumbing ---------------------------------------------------

    @property
    def params(self):
        """The current global model w."""
        return self.state.params

    def _sample(self, i: int):
        return sample_batches(self.clients[i], self.rng,
                              3 * self.pcfg.q_local, self.batch_size)

    def _cstate_for_dispatch(self, i: int):
        if not self.strategy.stateful:
            return None
        if self._cstates[i] is None:
            self._cstates[i] = self.strategy.init_client_state(
                self.state.params)
        return self.strategy.dispatch_state(self._cstates[i])

    def _write_back(self, client_ids: List[int], bank: DeltaBank) -> None:
        """Store the cohort's updated client states and run the strategy's
        shared-state fold (SCAFFOLD's c_global)."""
        if not self.strategy.stateful:
            return
        updates = []
        for row, i in enumerate(client_ids):
            new = bank.client_state(row)
            updates.append((i, self._cstates[i], new))
            self._cstates[i] = new
        self.strategy.post_round(updates, len(self.clients))

    @property
    def stats(self) -> Dict:
        """Engine + per-window scheduler counters, one machine-readable
        dict (churn sweeps consume this; ``window_log`` holds the
        per-window records)."""
        s = dict(self.engine.stats)
        s.update(self.scheduler_stats)
        s["mean_cohort_fill"] = (
            self.scheduler_stats["cohort_fill_sum"]
            / max(self.scheduler_stats["windows"], 1))
        return s

    def _record_window(self, now: float, fill: int, taus: List[int],
                       **extra: int) -> None:
        """Per-server-apply scheduler bookkeeping: cohort fill, staleness
        spread, robust-admission actions.  Aggregates accumulate in
        ``scheduler_stats``; the first ``_window_log_cap`` windows also
        get a per-window record in ``window_log``."""
        st = self.scheduler_stats
        st["windows"] += 1
        st["cohort_fill_sum"] += fill
        st["cohort_fill_max"] = max(st["cohort_fill_max"], fill)
        for key, val in extra.items():
            st[key] = st.get(key, 0) + val
        if len(self.window_log) < self._window_log_cap:
            self.window_log.append({
                "window": st["windows"], "time": float(now),
                "fill": int(fill),
                "tau_mean": float(np.mean(taus)) if taus else 0.0,
                "tau_max": int(max(taus)) if taus else 0,
                "dropouts": st["dropouts"],
                "corrupted_rows": st["corrupted_rows"], **extra})

    def _flush(self) -> None:
        """Materialize every pending client update in one cohort call.

        Called right before any server apply: params have not changed since
        these clients' downloads completed, so the whole cohort shares one
        snapshot and the cohort call is exact.  Deltas are recorded as
        (DeltaBank, row) handles — the stacked buffer stays on device and a
        bank outlives its window for clients whose upload lands after the
        next apply.

        Adversarial clients (a ChurnModel with an adversarial population)
        corrupt their rows HERE, right after the cohort computes them —
        one on-device ``scale_rows`` pass over the bank, exactly where a
        malicious client would hand the server a doctored delta."""
        if not self._pending:
            return
        stateful = self.strategy.stateful
        bank = self.engine.update_cohort(
            self.state.params, [b for _, _, b, _ in self._pending],
            cstate_list=[c for _, _, _, c in self._pending]
            if stateful else None)
        ids = [i for _, i, _, _ in self._pending]
        factors = self.delays.corruption_factors(np.asarray(ids)) \
            if hasattr(self.delays, "corruption_factors") else None
        if factors is not None and bool(np.any(factors != 1.0)):
            vec = np.ones(bank.capacity, np.float32)
            vec[:len(ids)] = factors
            if bank._stacked is not None or bank._rows is None:
                bank._stacked = scale_rows(bank.stacked, vec)
            else:
                # per-event (vectorized=False) banks hold per-row trees
                bank._rows = [
                    jax.tree.map(lambda x: x * jnp.float32(f), r)
                    for r, f in zip(bank._rows, vec[:len(bank._rows)])]
            self.scheduler_stats["corrupted_rows"] += \
                int(np.sum(factors != 1.0))
        for idx, (rid, _, _, _) in enumerate(self._pending):
            self._computed[rid] = (bank, idx)
        if stateful:
            self._write_back(ids, bank)
        self._pending = []

    def _on_upload(self, now: float, rid: int, version: int, hist: History,
                   eval_fn, eval_every: int) -> None:
        self.schedule.on_upload(self, now, rid, version, hist, eval_fn,
                                eval_every)

    def _record_eval(self, hist: History, now: float, eval_fn,
                     t: int, notify: bool = True) -> None:
        """Run one evaluation and append it to the History (acc, and loss
        when the eval_fn reports one — see :func:`_normalize_eval`).

        With ``notify=True`` the run's ``on_eval`` callback (if any) sees
        the updated History; a ``"stop"`` return raises the stop flag the
        event/round loops check after every server apply — the clean
        mid-run abort path the :mod:`repro.tune` runner halts arms with.
        """
        acc, loss = _normalize_eval(eval_fn(self.state.params))
        hist.times.append(now)
        hist.rounds.append(int(t))
        hist.acc.append(acc)
        if loss is not None:
            hist.loss.append(loss)
        if notify and self._on_eval is not None \
                and self._on_eval(hist) == "stop":
            self._stop = True

    # -- the run surface ---------------------------------------------------

    def run(self, *, max_rounds: Optional[int] = None,
            max_server_rounds: Optional[int] = None,
            eval_every: Optional[int] = None,
            eval_fn: Optional[Callable] = None,
            record_active_every: float = 5.0,
            max_time: Optional[float] = None,
            on_eval: Optional[Callable[[History], Optional[str]]] = None,
            final_eval: bool = False) -> History:
        """Drive the run to ``max_rounds`` server rounds (or ``max_time``
        simulated seconds, whichever first).  ``max_server_rounds`` is an
        accepted alias.  Returns the :class:`History`.

        ``on_eval(hist)`` is called after every recorded evaluation with
        the live History; returning ``"stop"`` halts the event loop
        cleanly after the current server apply (History stays well-formed:
        the active-ratio grid is closed out and ``end_time`` is the true
        stop time).  This is the abort path self-stopping sweeps
        (:mod:`repro.tune`) kill diverging or plateaued arms through.

        ``final_eval=True`` forces one evaluation at the actual stop time
        if the last recorded one is stale (or none was recorded at all) —
        "final accuracy" reads (``hist.acc[-1]``) are then never a stale
        grid point, even when ``eval_every`` exceeds the round count or a
        ``max_time`` budget bites between grid points.
        """
        if max_rounds is None:
            max_rounds = max_server_rounds
        if max_rounds is None:
            raise TypeError("run() needs max_rounds=")
        if eval_every is None:
            eval_every = self.schedule.default_eval_every
        self._on_eval = on_eval
        self._stop = False
        self.schedule.start(self)
        if self.schedule.kind == "round":
            hist = self._run_rounds(max_rounds, eval_every, eval_fn,
                                    record_active_every, max_time)
        else:
            hist = self._run_events(max_rounds, eval_every, eval_fn,
                                    record_active_every, max_time)
        if final_eval and eval_fn is not None:
            t_now = int(np.asarray(self.state.t))
            # params only move with the round counter: a last eval at the
            # current t already IS the end-time accuracy, re-running it
            # would burn an eval to recompute an identical value
            if not hist.rounds or hist.rounds[-1] != t_now:
                # the forced final eval never re-enters on_eval: the run
                # is already over, a "stop" could not mean anything
                self._record_eval(hist, hist.end_time, eval_fn, t_now,
                                  notify=False)
        self.final_stats = jax.tree.map(np.asarray,
                                        staleness_stats(self.state))
        return hist

    # -- event-driven core (immediate / buffered schedules) ----------------

    def _heap_events(self):
        """Per-event heap scheduler as an infinite event generator.

        Yields ``(t, client, kind, dropped, t_up)`` with kind 0 = download
        complete, 1 = upload complete.  Heap keys are ``(t, client, kind)``
        — the documented deterministic total order on events (download
        sorts before upload at equal time for the same client), identical
        to the ``np.lexsort`` order :class:`repro.fl.scenario.EventStream`
        emits, which is what makes the two sources bit-equal.  The old
        insertion-``seq`` tie-break depended on *push* order, which no
        vectorized scheduler can reproduce.

        A dropped download (ChurnModel mid-round dropout: the client
        vanishes after its download completes, before uploading) yields
        with ``dropped=True`` and schedules the client's next download at
        the time its upload *would* have finished — realized timelines are
        drop-independent, so heap and device paths stay aligned.
        """
        heap: List[Tuple[float, int, int]] = []
        for i in range(len(self.clients)):
            heapq.heappush(heap,
                           (self.delays.sample_download(i, 0.0), i, 0))
        while True:
            now, i, kind = heapq.heappop(heap)
            if kind == 0:
                dropped = self.delays.drops(i)
                t_up = now + self.delays.sample_upload(i, now)
                if dropped:
                    heapq.heappush(
                        heap,
                        (t_up + self.delays.sample_download(i, t_up), i, 0))
                else:
                    heapq.heappush(heap, (t_up, i, 1))
                yield now, i, 0, dropped, t_up
            else:
                heapq.heappush(
                    heap,
                    (now + self.delays.sample_download(i, now), i, 0))
                yield now, i, 1, False, now

    def _run_events(self, max_rounds, eval_every, eval_fn,
                    record_active_every, max_time) -> History:
        from repro.fl.scenario.sched import EventStream
        hist = History()
        n = len(self.clients)
        mode = self.scheduler
        if mode == "auto":
            # the Python heap wins at small n (no chunk overhead); past a
            # few thousand clients the vectorized stream takes over
            mode = "device" if n >= 4096 else "heap"
        self.scheduler_stats["scheduler"] = mode
        events = EventStream(self.delays).events() if mode == "device" \
            else self._heap_events()
        now = 0.0
        next_active_t = 0.0
        busy_up = {i: None for i in range(n)}  # upload finish times
        inflight: Dict[int, Tuple[int, int]] = {}  # client -> (rid, version)
        # (rid, client, batches, dispatch-ready cstate or None)
        self._pending: List[Tuple[int, int, Dict, object]] = []
        self._computed: Dict[int, Tuple] = {}   # rid -> (DeltaBank, row)
        self._t = int(self.state.t)             # host-side round mirror
        next_rid = 0

        for now_e, i, kind, dropped, t_up in events:
            if self._t >= max_rounds:
                break
            if max_time is not None and now_e > max_time:
                # this event lies PAST the budget: it must not run, and
                # the clock stops AT the budget — end_time must never
                # overshoot max_time or equal-simulated-time comparisons
                # (experiments/sweeps/buffered_vs_immediate.py) would hand
                # the overshooting run extra simulated seconds
                now = max_time
                break
            now = now_e
            # record active ratio on a time grid: active = comp./uploading
            while next_active_t <= now:
                up_now = sum(1 for v in busy_up.values()
                             if v is not None and v > next_active_t)
                hist.active_times.append(next_active_t)
                hist.active_ratio.append(up_now / n)
                next_active_t += record_active_every
            if kind == 0:                       # download complete
                if dropped:
                    # mid-round dropout: the client vanished before its
                    # upload — no dispatch, no bank row, just a counter
                    self.scheduler_stats["dropouts"] += 1
                    continue
                rid = next_rid
                next_rid += 1
                self._pending.append((rid, i, self._sample(i),
                                      self._cstate_for_dispatch(i)))
                busy_up[i] = t_up
                inflight[i] = (rid, self._t)
            else:                               # upload complete
                rid, version = inflight.pop(i)
                self._on_upload(now, rid, version, hist, eval_fn,
                                eval_every)
                busy_up[i] = None
                if self._stop:
                    # on_eval requested a stop: halt cleanly after this
                    # apply — the grid closeout below and end_time keep
                    # the History well-formed
                    break
        # close out the active-ratio grid to the actual stop time: on a
        # max_time break the in-loop recording stopped at the last
        # *executed* event, leaving the grid short of the boundary
        while next_active_t <= now:
            up_now = sum(1 for v in busy_up.values()
                         if v is not None and v > next_active_t)
            hist.active_times.append(next_active_t)
            hist.active_ratio.append(up_now / n)
            next_active_t += record_active_every
        hist.end_time = now
        return hist

    # -- barrier-round core (sync_barrier schedule) ------------------------

    def _run_rounds(self, max_rounds, eval_every, eval_fn,
                    record_active_every, max_time) -> History:
        hist = History()
        n = len(self.clients)
        m = self.schedule.m
        now = 0.0
        next_active_t = 0.0
        for rnd in range(max_rounds):
            sel = self.rng.choice(n, m, replace=False)
            batches = [self._sample(int(i)) for i in sel]
            cstates = [self._cstate_for_dispatch(int(i)) for i in sel] \
                if self.strategy.stateful else None
            # the m sampled clients share the round's params by definition:
            # one cohort call, deltas land in the bank, client state rides
            # the stacked pytree
            bank = self.engine.update_cohort(self.state.params, batches,
                                             cstate_list=cstates)
            finish = [self.delays.sample_download(int(i), now)
                      + self.delays.sample_upload(int(i), now) for i in sel]
            round_len = max(finish)
            # active-ratio grid: client i is busy until its own finish time
            while next_active_t <= now + round_len:
                rel = next_active_t - now
                busy = sum(1 for f in finish if f > rel)
                hist.active_times.append(next_active_t)
                hist.active_ratio.append(busy / n)
                next_active_t += record_active_every
            now += round_len
            # the mean AND the β-scaled apply fuse into one apply_rows pass
            # (weights = β/m on real rows, 0 on bucket padding); one server
            # round per barrier, staleness 0 by construction
            weights = np.zeros(bank.capacity, np.float32)
            weights[:len(batches)] = self.pcfg.beta / len(batches)
            self.state = apply_buffered_rows(self.state, bank.stacked,
                                             weights, 1, staleness_max=0,
                                             staleness_sum=0.0)
            self._write_back([int(i) for i in sel], bank)
            if eval_fn is not None and (rnd + 1) % eval_every == 0:
                self._record_eval(hist, now, eval_fn, rnd + 1)
            if self._stop or (max_time is not None and now >= max_time):
                break
        hist.end_time = now
        return hist
