"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE, which
under-counts scan-over-layers / Q-local-steps / microbatch loops by their
trip counts (verified empirically — see EXPERIMENTS.md §Dry-run notes).
This module re-derives

  * flops            — matmul (dot) flops, 2·|out|·contraction
  * bytes            — operand+output bytes per top-level instruction
                       (fusion internals excluded: a fusion is one HBM
                       round-trip over its operands/outputs)
  * collective bytes — per kind, output bytes of each collective

by walking the computation call graph and multiplying by
``known_trip_count`` from each while's backend_config.

Conditionals sum all branches (zamba2's every-6th-layer shared-attention
cond is therefore over-counted toward the safe side; noted per-record).
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|pred|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"(\{[^}]*\}|%?[\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = [int(d) for d in dims.split(",")] if dims else []
        out.append((dt, shape))
    return out


def _numel_bytes(text: str) -> int:
    total = 0
    for dt, shape in _shapes(text):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


# elementwise float ops counted at 1 flop/output element (einsum patterns
# that XLA lowers to multiply+reduce instead of dot — e.g. the SSD chunked
# scan — are captured this way); reduce counted at input-numel flops.
_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "exponential-minus-one", "logistic", "cosine", "sine", "atan2",
}

# call-site plumbing with no HBM traffic of its own (bodies are walked
# separately via the call graph)
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "iota", "broadcast",
    "reshape",
}


class _Instr:
    __slots__ = ("name", "out_text", "op", "operands", "attrs")

    def __init__(self, name, out_text, op, operands, attrs):
        self.name = name
        self.out_text = out_text
        self.op = op
        self.operands = operands
        self.attrs = attrs


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _parse_instr(line: str):
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rest = m.groups()
    # rest = "<out-shapes> <op>(operands), attrs"
    # find op: first "word(" at paren depth 0 after the shape segment
    depth = 0
    op_start = None
    i = 0
    while i < len(rest):
        ch = rest[i]
        if ch == "(":
            # word before this paren?
            j = i - 1
            while j >= 0 and (rest[j].isalnum() or rest[j] in "-_"):
                j -= 1
            word = rest[j + 1:i]
            if depth == 0 and word and word[0].isalpha():
                op_start = (j + 1, i, word)
                break
            depth += 1
        elif ch == ")":
            depth -= 1
        i += 1
    if op_start is None:
        return None
    ws, istart, op = op_start
    out_text = rest[:ws]
    # operands segment: matching paren
    depth = 0
    j = istart
    while j < len(rest):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                break
        j += 1
    operands = re.findall(r"%([\w.\-]+)", rest[istart:j + 1])
    attrs = rest[j + 1:]
    return _Instr(name, out_text, op, operands, attrs)


def parse_hlo(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        h = _HEADER_RE.match(line)
        if h and ("->" in line):
            cur = h.group(2)
            comps[cur] = []
            if h.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            comps[cur].append(ins)
    comps["__entry__"] = entry  # type: ignore[assignment]
    return comps


def analyze(text: str) -> Dict:
    comps = parse_hlo(text)
    entry = comps.pop("__entry__")
    shape_of: Dict[Tuple[str, str], str] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            shape_of[(cname, ins.name)] = ins.out_text

    fused = {c for c in comps if c.startswith("fused_") or ".fused" in c}

    per_comp: Dict[str, Dict] = {}
    edges: Dict[str, List[Tuple[str, int]]] = {}
    for cname, instrs in comps.items():
        flops = 0.0
        bytes_ = 0.0
        coll = {k: 0.0 for k in _COLLECTIVES}
        edge_list: List[Tuple[str, int]] = []
        inside_fusion = cname in fused
        for ins in instrs:
            op = ins.op
            base = op.replace("-start", "").replace("-done", "")
            if op == "dot":
                shapes = _shapes(ins.out_text)
                out_numel = 1
                for _, s in shapes:
                    for d in s:
                        out_numel *= d
                m = _LHS_CDIMS_RE.search(ins.attrs)
                csize = 1
                if m and ins.operands:
                    lhs = shape_of.get((cname, ins.operands[0]), "")
                    ls = _shapes(lhs)
                    if ls:
                        dims = [int(x) for x in m.group(1).split(",") if x]
                        for d in dims:
                            if d < len(ls[0][1]):
                                csize *= ls[0][1][d]
                flops += 2.0 * out_numel * csize
            elif op in _ELEMENTWISE_FLOPS:
                shapes = _shapes(ins.out_text)
                n = 1
                for _, s in shapes:
                    for d in s:
                        n *= d
                # only count float outputs
                if shapes and shapes[0][0] in ("f32", "bf16", "f16", "f64"):
                    flops += float(n)
            elif op == "reduce" and ins.operands:
                inp = shape_of.get((cname, ins.operands[0]), "")
                sh = _shapes(inp)
                if sh and sh[0][0] in ("f32", "bf16", "f16", "f64"):
                    n = 1
                    for d in sh[0][1]:
                        n *= d
                    flops += float(n)
            if base in _COLLECTIVES and not op.endswith("-done"):
                coll[base] += _numel_bytes(ins.out_text)
            # call edges
            trip = 1
            tm = _TRIP_RE.search(ins.attrs)
            if tm:
                trip = int(tm.group(1))
            for am in _CALL_ATTR_RE.finditer(ins.attrs):
                kind = am.group(0).split("=", 1)[0]
                target = am.group(1)
                names = re.findall(r"%?([\w.\-]+)", target)
                for nm in names:
                    if nm in comps:
                        mult = trip if kind == "body" else 1
                        edge_list.append((nm, mult))
            # HBM bytes: skip inside fusions, params/constants/plumbing
            if not inside_fusion and op not in _NO_TRAFFIC:
                if op == "dynamic-update-slice":
                    # aliased in-place by XLA: traffic = read+write the
                    # update region, not the whole buffer
                    upd = (shape_of.get((cname, ins.operands[1]), "")
                           if len(ins.operands) > 1 else "")
                    b = 2 * _numel_bytes(upd)
                elif op == "dynamic-slice":
                    b = 2 * _numel_bytes(ins.out_text)
                else:
                    b = _numel_bytes(ins.out_text)
                    for opr in ins.operands:
                        b += _numel_bytes(shape_of.get((cname, opr), ""))
                bytes_ += b
        per_comp[cname] = {"flops": flops, "bytes": bytes_, "coll": coll}
        edges[cname] = edge_list

    totals = {"flops": 0.0, "bytes": 0.0,
              "coll": {k: 0.0 for k in _COLLECTIVES}}

    def dfs(cname: str, mult: float, depth: int = 0):
        if depth > 50 or cname not in per_comp:
            return
        pc = per_comp[cname]
        totals["flops"] += pc["flops"] * mult
        totals["bytes"] += pc["bytes"] * mult
        for k in _COLLECTIVES:
            totals["coll"][k] += pc["coll"][k] * mult
        for callee, emult in edges.get(cname, []):
            dfs(callee, mult * emult, depth + 1)

    if entry:
        dfs(entry, 1.0)
    return {"flops": totals["flops"], "bytes": totals["bytes"],
            "collective_bytes": {k: int(v) for k, v in totals["coll"].items()}}
