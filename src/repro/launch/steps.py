"""Production step functions — what the dry-run lowers and a real cluster
would run.

``make_train_step`` builds one PersA-FL *client round* under pjit:
Q scanned local steps (Option A/B/C) computed at the STALE parameters
w^{Ω(t)}, followed by the server apply w^{t+1} = w^t − β Δ (Algorithm 1).
Carrying the stale copy as an explicit input materializes Assumption-1
staleness in the compute graph (DESIGN.md §2).

SPMD semantics: the batch is sharded over the (pod, data) axes while the
stale params are replicated across them, so the gradient's implicit psum
over data axes makes the same graph serve both the paper-faithful mode
(the batch is one client's data) and the beyond-paper buffered-cohort mode
(the batch spans M clients — FedBuff-style aggregation for free).

Training memory: the client delta is accumulated (Δ = η Σ ∇̃, exact
telescoping of Algorithm 2) instead of keeping a second moving parameter
copy, and microbatching wraps the loss in remat'd gradient accumulation.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.core import PersAFLConfig, client_update
from repro.models import api
from repro.sharding.ctx import shard_map_compat as _shard_map


def microbatched(loss_fn: Callable, n_mb: int) -> Callable:
    """grad(microbatched(loss)) == grad-accumulation over n_mb slices with
    one-microbatch activation memory (each slice is remat'd)."""
    if n_mb <= 1:
        return loss_fn

    def loss(params, batch):
        b = jax.tree.map(
            lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]),
            batch)

        def body(acc, mb):
            return acc + jax.checkpoint(loss_fn)(params, mb), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), b)
        return total / n_mb

    return loss


def make_loss(cfg: ArchConfig, n_mb: int = 1) -> Callable:
    base = lambda p, b: api.loss_fn(cfg, p, b)
    return microbatched(base, n_mb)


def _q_batches(pcfg: PersAFLConfig, batch: Dict):
    """Broadcast the round's batch across the Q local steps (the dry-run
    feeds one batch; a real deployment streams fresh D_{i,q} per step —
    identical graph)."""
    q = pcfg.q_local

    def rep(x):
        return jnp.broadcast_to(x[None], (q,) + x.shape)

    tiled = jax.tree.map(rep, batch)
    if pcfg.option == "B":
        return {"d": tiled, "dp": tiled, "dpp": tiled}
    return tiled


def make_train_step(cfg: ArchConfig, pcfg: PersAFLConfig,
                    n_microbatches: int = 0) -> Callable:
    n_mb = n_microbatches or cfg.train_microbatches
    loss = make_loss(cfg, n_mb)

    def train_step(server_params, stale_params, batch):
        delta, metrics = client_update(pcfg, loss, stale_params,
                                       _q_batches(pcfg, batch))
        new_params = jax.tree.map(
            lambda w, d: (w.astype(jnp.float32)
                          - pcfg.beta * d).astype(w.dtype),
            server_params, delta)
        return new_params, metrics

    return train_step


def make_cohort_train_step(cfg: ArchConfig, pcfg: PersAFLConfig, mesh,
                           n_microbatches: int = 0,
                           cohort_axes=None) -> Callable:
    """Beyond-paper §Perf variant: FedBuff-style cohort round via shard_map.

    Each slice along the (pod, data) axes is an *independent client* running
    its own Q local steps on replicated params (no per-gradient psum); the
    deltas are averaged ONCE at the end (Algorithm 1 buffered apply,
    [51,63]).  Collective cost per round drops from one psum per gradient
    evaluation (Q·(K+1) with Option C) to a single delta pmean.

    Requires replicated (non-FSDP) parameter sharding — pair with
    ``--sharding dp``.
    """
    from jax.sharding import PartitionSpec as P

    n_mb = n_microbatches or cfg.train_microbatches
    loss = make_loss(cfg, n_mb)
    if cohort_axes is not None:
        d_axes = tuple(cohort_axes)
    else:
        d_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def _local_round(server_params, stale_params, batch):
        delta, metrics = client_update(pcfg, loss, stale_params,
                                       _q_batches(pcfg, batch))
        delta = jax.tree.map(lambda d: jax.lax.pmean(d, d_axes), delta)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, d_axes), metrics)
        new_params = jax.tree.map(
            lambda w, d: (w.astype(jnp.float32)
                          - pcfg.beta * d.astype(jnp.float32)).astype(w.dtype),
            server_params, delta)
        return new_params, metrics

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def train_step(server_params, stale_params, batch):
        batch_spec = jax.tree.map(
            lambda _: P(d_axes if len(d_axes) > 1 else d_axes[0]), batch)
        return _shard_map(
            _local_round,
            mesh=mesh,
            in_specs=(specs_like(server_params, P()),
                      specs_like(stale_params, P()), batch_spec),
            out_specs=(specs_like(server_params, P()),
                       {"grad_norm_mean": P(), "delta_norm": P(),
                        "nu_mean": P()}),
            # manual only over the cohort axes — the model axis stays Auto,
            # so tensor parallelism keeps working INSIDE each cohort member
            manual_axes=d_axes,
        )(server_params, stale_params, batch)

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        return api.prefill_logits(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    """One-token decode with KV/SSM cache (decode_32k / long_500k)."""
    def serve_step(params, cache, tokens, pos):
        return api.decode_step(cfg, params, cache, tokens, pos)

    return serve_step
