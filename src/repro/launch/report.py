"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from the
dry-run JSONs.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load_records(dirname: str, tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def _fmt_s(x: float) -> str:
    return f"{x:.3g}"


def roofline_table(recs: List[Dict], mesh: str = "16x16") -> str:
    rows = ["| arch | shape | status | compute (s) | memory (s) | "
            "collective (s) | dominant | MODEL/HLO | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = [r for r in recs if r.get("mesh") == mesh or
            (mesh == "16x16" and r.get("mesh") == "single")]
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in recs:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | — | — | — "
                        f"| — | — | {r.get('reason','')} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — "
                        f"| — | — | {r.get('error','')[:60]} |")
            continue
        rf = r["roofline"]
        note = ""
        if r["arch"].startswith("zamba2"):
            note = "cond branches both counted (shared-attn overcount)"
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {_fmt_s(rf['compute_s'])} "
            f"| {_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} "
            f"| {rf['dominant']} | {rf['useful_ratio']:.2f} | {note} |")
    return "\n".join(rows)


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | status | args GB/dev | temp GB/dev | "
            "compile s | collectives (GB/dev: AR/AG/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                       r.get("mesh", "")))
    for r in recs:
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','')} | "
                        f"{r['status']} | — | — | — | — |")
            continue
        ma = r.get("memory_analysis", {})
        gb = 1024 ** 3
        args = ma.get("argument_size_in_bytes", 0) / gb
        temp = ma.get("temp_size_in_bytes", 0) / gb
        cb = r.get("hlo_cost", {}).get("collective_bytes",
                                       r.get("collective_bytes", {}))
        coll = "/".join(f"{cb.get(k,0)/gb:.2f}" for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {args:.2f} | "
            f"{temp:.2f} | {r.get('compile_s','')} | {coll} |")
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skipped")
    er = len(recs) - ok - sk
    return f"{len(recs)} combinations: {ok} ok, {sk} skipped, {er} errors"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load_records(args.dir, args.tag)
    print("## Summary\n")
    print(summary(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, "16x16"))
    print("\n## Dry-run detail\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
