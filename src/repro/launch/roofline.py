"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Terms (per (arch × shape × mesh), seconds):
  compute    = HLO_FLOPs / (chips × 197e12)        [bf16 peak per chip]
  memory     = HLO_bytes / (chips × 819e9)          [HBM BW per chip]
  collective = per_device_collective_bytes / 50e9   [~link BW per chip]

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are parsed from the post-SPMD compiled HLO text (per-device shapes),
summing the output bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute; per-device bytes divided by link BW equals
the global-bytes/(chips×link) form of the assignment formula.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per gradient evaluation,
scaled by the PersA-FL option's gradient-evaluation count (Q local steps ×
{A:1, B(full/hf):4, B(fo):2, C:K+1}); decode/prefill use the 2·N·D forward
form.  The MODEL/HLO ratio flags remat/redundancy waste.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|"
                       r"pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device output bytes per collective kind, from compiled HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        shape_part, op = m.groups()
        op = op.replace("-start", "").replace("-done", "")
        if op in _COLLECTIVES:
            if op.endswith("-done"):
                continue
            out[op] += _shape_bytes(shape_part)
    return out


def grad_evals(option: str, q: int, maml_mode: str, inner_steps: int) -> int:
    per_step = {"A": 1, "C": inner_steps + 1}.get(option)
    if per_step is None:  # B
        per_step = 2 if maml_mode == "fo" else 4
    return q * per_step


def model_flops(n_active_params: int, tokens: int, *, kind: str,
                n_grad_evals: int = 1) -> float:
    if kind == "train":
        return 6.0 * n_active_params * tokens * n_grad_evals
    return 2.0 * n_active_params * tokens


def roofline_terms(record: Dict) -> Dict:
    """record: one dry-run JSON (see dryrun.py). Returns the three terms,
    dominant bottleneck and usefulness ratio.

    Prefers the trip-count-aware ``hlo_cost`` re-analysis when present
    (XLA's cost_analysis counts while/scan bodies once — under-counts
    scan-over-layers by ~L×Q×mb); falls back to raw cost_analysis."""
    chips = record["n_devices"]
    if "hlo_cost" in record:
        flops = record["hlo_cost"]["flops"]
        bytes_acc = record["hlo_cost"]["bytes"]
        coll = sum(record["hlo_cost"]["collective_bytes"].values())
    else:
        flops = record["cost_analysis"].get("flops", 0.0)
        bytes_acc = record["cost_analysis"].get("bytes accessed", 0.0)
        coll = sum(record["collective_bytes"].values())
    # the compiled module is post-SPMD: shapes (hence flops/bytes/collective
    # bytes) are PER-DEVICE, so global = per_device × chips and
    # global/(chips × per-chip-rate) == per_device / per-chip-rate.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf = record.get("model_flops", 0.0)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops": flops,
        "useful_ratio": (mf / (flops * chips)) if flops else 0.0,
        "collective_by_kind": record["collective_bytes"],
    }
