"""ShapeDtypeStruct input specs for every (arch × input-shape) pair.

Stand-ins are weak-type-correct and shardable; nothing is allocated.  The
modality carve-outs live here: VLM shapes reserve ``n_visual_tokens``
positions for stubbed patch embeddings; audio shapes carry stubbed frame
embeddings of the fixed encoder length (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import api


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: InputShape) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    act = cfg.activation_dtype
    batch: Dict = {}
    if cfg.n_visual_tokens:
        text = S - cfg.n_visual_tokens
        batch["tokens"] = _sds((B, text), jnp.int32)
        batch["labels"] = _sds((B, text), jnp.int32)
        batch["visual"] = _sds((B, cfg.n_visual_tokens, cfg.d_model), act)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
        batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.is_encdec:
        batch["frames"] = _sds((B, cfg.enc_len, cfg.d_model), act)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape) -> Dict:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_specs(cfg: ArchConfig, shape: InputShape, params_struct):
    """-> (cache_struct, tokens_struct, pos_struct)."""
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, 1), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = _sds((B, cfg.enc_len, cfg.d_model),
                               cfg.activation_dtype)
    cache = jax.eval_shape(
        lambda p, b: api.init_cache(cfg, p, b, S, cfg.activation_dtype),
        params_struct, batch)
    return cache, _sds((B, 1), jnp.int32), _sds((), jnp.int32)


def params_struct(cfg: ArchConfig, cast: bool = True):
    """Abstract parameter tree; ≥2-D leaves stored in cfg.dtype (bf16 in
    production), 1-D norm/scale vectors kept f32."""
    struct = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    if not cast:
        return struct
    act = cfg.activation_dtype

    def recast(leaf):
        if leaf.ndim >= 2:
            return jax.ShapeDtypeStruct(leaf.shape, act)
        return leaf

    return jax.tree.map(recast, struct)


def cast_params(cfg: ArchConfig, params):
    """Concrete counterpart of params_struct's dtype policy."""
    act = cfg.activation_dtype

    def recast(leaf):
        return leaf.astype(act) if leaf.ndim >= 2 else leaf

    return jax.tree.map(recast, params)
