import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import (jax locks the device count on first
#   init) — dry-run only; tests/benches see the real single device.

"""Multi-pod dry-run: prove every (arch × input-shape × mesh) combination
lowers, SPMD-partitions and compiles on the production meshes, and extract
the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Writes one JSON per combination to experiments/dryrun/ (incremental;
--force re-runs).
"""
import argparse
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config, get_shape, list_archs
from repro.core import PersAFLConfig
from repro.launch import roofline as rl
from repro.launch import specs, steps
from repro.launch.mesh import make_production_mesh
from repro.sharding import rules
from repro.sharding.ctx import activation_sharding


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               pcfg: PersAFLConfig, extra_tag: str = "",
               sharding: str = "default", step: str = "pjit",
               n_mb: int = 0, remat_policy: str = "full",
               cache_sharding: str = "default") -> Dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if remat_policy != "full":
        cfg = _dc.replace(cfg, remat_policy=remat_policy)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    record: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "persafl": {"option": pcfg.option, "Q": pcfg.q_local,
                    "inner_steps": pcfg.inner_steps,
                    "maml_mode": pcfg.maml_mode,
                    "delta_dtype": pcfg.delta_dtype},
        "variant": {"sharding": sharding, "step": step, "n_mb": n_mb,
                    "remat_policy": remat_policy,
                    "cache_sharding": cache_sharding},
        "tag": extra_tag,
    }
    if not cfg.supports(shape_name):
        record["status"] = "skipped"
        record["reason"] = "full-attention arch; long_500k skipped (DESIGN.md §4)"
        return record

    p_struct = specs.params_struct(cfg)
    all_axes = mesh.axis_names
    p_shard = rules.param_shardings(
        cfg, p_struct, mesh,
        model_parallel=sharding not in ("dp", "dp2d"),
        mode="ep" if sharding == "ep" else "default")
    batch_axes = all_axes if sharding == "dp2d" else None
    t0 = time.time()
    # Activation-sharding rules vs the variant:
    #  * cohort: the cohort (data/pod) axes are Manual inside the shard_map
    #    — strip them; the model axis stays Auto so TP rules still apply
    #    (unless also dp, where everything is replicated).
    #  * dp: must not pin activations to the model axis or SPMD re-shards
    #    the weights back to tensor parallelism, overriding the replicated
    #    input sharding.
    if sharding in ("dp", "dp2d"):
        act_rules = {}
    elif step == "cohort":
        d_ax = ("pod", "data") if multi_pod else ("data",)
        act_rules = rules.strip_axes(rules.default_activation_rules(mesh),
                                     d_ax)
    else:
        act_rules = rules.default_activation_rules(mesh)
    with mesh:
        with activation_sharding(act_rules):
            if shape.kind == "train":
                batch = specs.train_batch_specs(cfg, shape)
                b_shard = rules.batch_shardings(batch, mesh, axes=batch_axes)
                if step == "cohort":
                    c_ax = all_axes if sharding == "dp2d" else None
                    fn = steps.make_cohort_train_step(cfg, pcfg, mesh, n_mb,
                                                      cohort_axes=c_ax)
                else:
                    fn = steps.make_train_step(cfg, pcfg, n_mb)
                jitted = jax.jit(fn,
                                 in_shardings=(p_shard, p_shard, b_shard),
                                 out_shardings=None)
                lowered = jitted.lower(p_struct, p_struct, batch)
            elif shape.kind == "prefill":
                batch = specs.prefill_batch_specs(cfg, shape)
                b_shard = rules.batch_shardings(batch, mesh)
                fn = steps.make_prefill_step(cfg)
                jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                                 out_shardings=None)
                lowered = jitted.lower(p_struct, batch)
            else:  # decode
                cache, tok, pos = specs.decode_specs(cfg, shape, p_struct)
                c_shard = rules.cache_shardings(
                    cfg, cache, mesh,
                    seq_on_model=(cache_sharding == "default"))
                t_shard = rules.batch_shardings(tok, mesh)
                r = rules.replicated(mesh)
                fn = steps.make_serve_step(cfg)
                jitted = jax.jit(fn,
                                 in_shardings=(p_shard, c_shard, t_shard, r),
                                 out_shardings=None)
                lowered = jitted.lower(p_struct, cache, tok, pos)
            record["lower_s"] = round(time.time() - t0, 1)
            t0 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t0, 1)

    ca = compiled.cost_analysis() or {}
    record["cost_analysis"] = {k: float(v) for k, v in ca.items()
                               if isinstance(v, (int, float))}
    ma = compiled.memory_analysis()
    if ma is not None:
        record["memory_analysis"] = {
            a: int(getattr(ma, a)) for a in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(ma, a)}
    hlo = compiled.as_text()
    record["collective_bytes"] = rl.collective_bytes(hlo)
    record["hlo_bytes_len"] = len(hlo)
    # trip-count-aware re-analysis (XLA cost_analysis counts while bodies
    # once — see launch/hlo_cost.py); preferred by roofline_terms
    from repro.launch import hlo_cost
    record["hlo_cost"] = hlo_cost.analyze(hlo)

    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "decode":
        tokens = shape.global_batch  # one token per request
    n_ge = rl.grad_evals(pcfg.option, pcfg.q_local, pcfg.maml_mode,
                         pcfg.inner_steps) if shape.kind == "train" else 1
    record["model_flops"] = rl.model_flops(
        cfg.n_active_params, tokens, kind=shape.kind, n_grad_evals=n_ge)
    record["n_params"] = cfg.n_params
    record["n_active_params"] = cfg.n_active_params
    record["status"] = "ok"
    record["roofline"] = rl.roofline_terms(record)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--option", default=None, help="override PersA-FL option")
    ap.add_argument("--q", type=int, default=2,
                    help="Q local steps for the lowered client round")
    ap.add_argument("--inner-steps", type=int, default=2,
                    help="ME inner prox steps (Option C)")
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--sharding", default="default",
                    choices=["default", "dp", "dp2d", "ep"],
                    help="dp = replicate params (pure cohort parallelism)")
    ap.add_argument("--step", default="pjit", choices=["pjit", "cohort"],
                    help="cohort = shard_map FedBuff round (delta pmean once)")
    ap.add_argument("--mb", type=int, default=0,
                    help="override train microbatch count")
    ap.add_argument("--delta-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--remat-policy", default="full", choices=["full", "dots"])
    ap.add_argument("--cache-sharding", default="default",
                    choices=["default", "batch"])
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch == "all") else [args.arch]
    shapes = ([s.name for s in INPUT_SHAPES]
              if (args.all or args.shape == "all") else [args.shape])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            for mp in meshes:
                mesh_tag = "multi" if mp else "single"
                suffix = f"_{args.tag}" if args.tag else ""
                fname = os.path.join(
                    args.out, f"{arch}_{shape}_{mesh_tag}{suffix}.json")
                if os.path.exists(fname) and not args.force:
                    print(f"[skip existing] {fname}")
                    continue
                option = args.option or cfg.persafl_option
                pcfg = PersAFLConfig(option=option, q_local=args.q,
                                     inner_steps=args.inner_steps,
                                     maml_mode=cfg.maml_mode,
                                     delta_dtype=args.delta_dtype)
                print(f"=== {arch} × {shape} × {mesh_tag} (option {option}"
                      f", {args.sharding}/{args.step}) ===", flush=True)
                try:
                    rec = dryrun_one(arch, shape, mp, pcfg, args.tag,
                                     sharding=args.sharding, step=args.step,
                                     n_mb=args.mb,
                                     remat_policy=args.remat_policy,
                                     cache_sharding=args.cache_sharding)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" compute={r['compute_s']:.3e}s"
                             f" memory={r['memory_s']:.3e}s"
                             f" coll={r['collective_s']:.3e}s"
                             f" lower={rec['lower_s']}s compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"--> {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
