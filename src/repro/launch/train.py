"""PersA-FL training driver.

Two modes:
  * ``--preset paper-mnist|paper-cifar`` — the paper's §5 experiment:
    asynchronous personalized FL over n heterogeneous clients with the
    paper's CNNs, driven by the ``repro.fl.api.FLRun`` event loop with the
    paper-faithful ``immediate()`` apply schedule (the end-to-end example;
    a few hundred server rounds on CPU).
  * ``--arch <id> [--smoke]`` — PersA-FL over an assigned LLM architecture
    (reduced config on CPU with --smoke; full config is what the dry-run
    lowers for the production mesh).

Examples:
  PYTHONPATH=src python -m repro.launch.train --preset paper-mnist \
      --option C --rounds 200
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --rounds 4
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_server_state
from repro.configs import get_config, reduce_for_smoke
from repro.configs.paper_models import CIFAR_CNN, MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset, synthetic_token_batch
from repro.fl import DelayModel, FLRun, immediate, make_personalized_eval
from repro.models import api
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn


def run_paper_preset(args) -> dict:
    kind = "mnist" if args.preset == "paper-mnist" else "cifar"
    ccfg = MNIST_CNN if kind == "mnist" else CIFAR_CNN
    cpc = 5 if kind == "mnist" else 3  # c classes per client (paper §5)
    clients = make_federated_dataset(kind, n_clients=args.clients,
                                     classes_per_client=cpc, seed=args.seed)
    params = init_cnn(ccfg, jax.random.PRNGKey(args.seed))
    loss = lambda p, b: cnn_loss(ccfg, p, b, train=False)
    acc = lambda p, b: cnn_accuracy(ccfg, p, b)
    ev = make_personalized_eval(loss, acc, clients, ft_steps=1,
                                ft_lr=args.eta)
    pcfg = PersAFLConfig(option=args.option, q_local=args.q, eta=args.eta,
                         beta=args.beta, alpha=args.alpha, lam=args.lam,
                         inner_steps=args.inner_steps,
                         maml_mode=args.maml_mode)
    sim = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(args.clients, seed=args.seed,
                                             scale=args.delay_scale),
                strategy="persafl", schedule=immediate(),
                batch_size=args.batch, seed=args.seed)
    t0 = time.time()
    hist = sim.run(max_rounds=args.rounds,
                   eval_every=args.eval_every, eval_fn=ev)
    wall = time.time() - t0
    out = {
        "preset": args.preset, "option": args.option, "rounds": args.rounds,
        "acc": hist.acc, "times": hist.times, "rounds_series": hist.rounds,
        "mean_active_ratio": float(np.mean(hist.active_ratio)),
        "staleness_max": int(max(hist.staleness)) if hist.staleness else 0,
        "staleness_mean": float(np.mean(hist.staleness)) if hist.staleness else 0,
        "wall_s": wall,
    }
    os.makedirs(args.out, exist_ok=True)
    ckpt = os.path.join(args.out, f"{args.preset}_opt{args.option}")
    save_server_state(ckpt, sim.state, meta=out)
    with open(ckpt + ".history.json", "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps({k: v for k, v in out.items()
                      if k not in ("times", "rounds_series")}, indent=2))
    return out


def run_arch(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    pcfg = PersAFLConfig(option=args.option, q_local=args.q, eta=args.eta,
                         lam=args.lam, inner_steps=args.inner_steps,
                         maml_mode=cfg.maml_mode)
    from repro.launch.steps import make_train_step
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    step = jax.jit(make_train_step(cfg, pcfg, n_microbatches=1))
    B, S = (args.batch, args.seq) if args.smoke else (8, 512)
    losses = []
    t0 = time.time()
    loss_of = jax.jit(lambda p, b: api.loss_fn(cfg, p, b))
    for r in range(args.rounds):
        batch = synthetic_token_batch(args.seed + r, B, S, cfg.vocab)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.n_visual_tokens:
            batch["visual"] = jnp.zeros((B, cfg.n_visual_tokens, cfg.d_model),
                                        cfg.activation_dtype)
        if cfg.is_encdec:
            batch["frames"] = jnp.zeros((B, cfg.enc_len, cfg.d_model),
                                        cfg.activation_dtype)
        # paper-faithful: the delta is computed at the (here: current)
        # downloaded params; staleness comes from the event schedule
        params, metrics = step(params, params, batch)
        losses.append(float(loss_of(params, batch)))
        print(f"round {r}: loss={losses[-1]:.4f} "
              f"delta_norm={float(metrics['delta_norm']):.4f}", flush=True)
    out = {"arch": cfg.arch_id, "losses": losses,
           "wall_s": time.time() - t0}
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"train_{cfg.arch_id}.json"), "w") as f:
        json.dump(out, f, indent=2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default=None,
                    choices=[None, "paper-mnist", "paper-cifar"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--option", default="C", choices=["A", "B", "C"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--q", type=int, default=10)
    ap.add_argument("--eta", type=float, default=0.01)
    ap.add_argument("--beta", type=float, default=1.0)
    ap.add_argument("--alpha", type=float, default=0.01)
    ap.add_argument("--lam", type=float, default=30.0)
    ap.add_argument("--inner-steps", type=int, default=10)
    ap.add_argument("--maml-mode", default="full")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--delay-scale", type=float, default=1.0)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/train")
    args = ap.parse_args()
    if args.preset:
        run_paper_preset(args)
    elif args.arch:
        run_arch(args)
    else:
        ap.error("need --preset or --arch")


if __name__ == "__main__":
    main()
