"""Personalized serving driver.

Decode is driven through :class:`repro.serving.PersonalizationServer`:
each request is a *user* with their own token stream; the server coalesces
all users' personalization (mode "B" one-step fine-tune or mode "C"
Moreau-envelope prox solve) into one pow2-bucketed cohort call, and decode
runs vmapped over the stacked per-user heads — no per-user Python loop on
either side.  Prompt prefill is a single jitted ``lax.scan`` dispatch
(prompt tokens advance on device); the decode loop proper stays
step-by-step because each token depends on the previous argmax.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --personalize --requests 4 --tokens 16

``--personal-subset PREFIXES`` switches personalization to partial-model
(head-only) form: only the named param subtrees are personalized, banked,
and stacked per user; decode merges the stacked heads over the one shared
backbone and vmaps with ``in_axes=None`` on backbone leaves, so backbone
memory stays O(1) in the user count.

``--listen PORT`` swaps the one-shot decode for a network front-end: the
PersonalizationServer is wrapped in a
:class:`repro.serving.transport.TransportServer` and a second OS process
(or a fleet of them) drives personalization over the socket with
:class:`repro.serving.transport.TransportClient` — submit a token batch
shaped like the model loss expects (``{"tokens": int32[1, L], "labels":
int32[1, L]}``, L a multiple of the arch's SSM chunk, plus ``visual`` /
``frames`` leaves for the archs that take them — see ``_user_batch``),
poll the personalized head back, decode locally or fetch it again later
via HEAD.  A malformed batch fails its flush group with a typed
``server_error`` reply; the server keeps serving.  ``--flush-ms`` bounds queueing latency,
``--window-ms`` drives the aggregation-window boundary on a wall clock,
``--max-inflight`` is the backpressure bound (queue full → BUSY frames).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --listen 7777 --mode C

``--model-axis M`` serves over the 2-D ``("cohort", "model")`` mesh
(:func:`repro.sharding.ctx.cohort_model_mesh`): cohort slices are
model-parallel device groups, and every capacity-bound artifact — delta
banks, ring snapshots, head rows, the global params — is stored
model-axis-sharded per :func:`repro.sharding.rules.param_shardings`.
Served bits are identical to the 1-D path (the ``serve_mesh`` bench gates
it); what the model axis buys is per-device residency.

Multi-process serving: ``--coordinator HOST:PORT --num-processes N
--process-id I`` runs ``jax.distributed.initialize`` before any device
use, so N OS processes (one per host) form one JAX runtime whose global
device set backs the mesh.  ``--num-processes 1`` (the default when only
``--coordinator`` is given) is the single-host spelling and is what CI
boots:

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --listen 0 --serve-seconds 2 --coordinator 127.0.0.1:12377 \
      --num-processes 1 --process-id 0
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.core.types import PersAFLConfig
from repro.data import synthetic_token_batch
from repro.models import api


def _personalize_len(cfg, n: int) -> int:
    """SSM/hybrid archs run the chunked SSD scan over the personalization
    stream, so the length rounds up to the next chunk multiple."""
    chunk = cfg.ssm.chunk if getattr(cfg, "ssm", None) else 1
    return -(-max(n, 1) // chunk) * chunk


def _user_batch(cfg, seed: int, length: int):
    """One user's personalization stream (leaves lead with batch dim 1)."""
    data = synthetic_token_batch(seed, 1, length, cfg.vocab)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    if cfg.n_visual_tokens:
        batch["visual"] = jnp.zeros((1, cfg.n_visual_tokens, cfg.d_model),
                                    cfg.activation_dtype)
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros((1, cfg.enc_len, cfg.d_model),
                                    cfg.activation_dtype)
    return batch


def make_prefill(cfg):
    """Single-dispatch prompt prefill.

    The prompt's first L−1 tokens only exist to warm the cache, so they
    advance inside one jitted ``lax.scan`` instead of paying one Python
    dispatch per token; the caller then decodes from the prompt's last
    token.
    """
    def prefill(params, cache, prompt):
        def body(c, t):
            tok = jax.lax.dynamic_slice_in_dim(prompt, t, 1, axis=1)
            _, c = api.decode_step(cfg, params, c, tok, t)
            return c, None
        steps = jnp.arange(prompt.shape[1] - 1, dtype=jnp.int32)
        cache, _ = jax.lax.scan(body, cache, steps)
        return cache
    return prefill


def _init_batch(cfg, tokens):
    """Cache-init batch: token ids plus the encdec encoder frames."""
    batch = {"tokens": tokens}
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros(
            (tokens.shape[0], cfg.enc_len, cfg.d_model),
            cfg.activation_dtype)
    return batch


def _decode_shared(cfg, params, prompt, max_len, prompt_len):
    """Batched decode with the shared global params (no personalization)."""
    cache = api.init_cache(cfg, params, _init_batch(cfg, prompt[:, :1]),
                           max_len, cfg.activation_dtype)
    prefill = jax.jit(make_prefill(cfg))
    step = jax.jit(lambda p, c, t, pos: api.decode_step(cfg, p, c, t, pos))
    cache = prefill(params, cache, prompt)
    tok = prompt[:, -1:]
    generated = []
    for pos in range(prompt_len - 1, max_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    return jnp.concatenate(generated, axis=1) if generated else None


def _decode_personalized(cfg, heads, prompt, max_len, prompt_len,
                         params=None, spec=None):
    """Per-user decode: every request carries its own personalized head, so
    params/cache/tokens all vmap over the user axis (inner batch of 1).

    With a ``personal_subset`` (``spec``/``params`` given) ``heads`` is a
    stacked *subset* tree; merging it over the shared backbone yields a
    mixed tree whose personal leaves carry the user axis and whose backbone
    leaves do not, and a pytree ``in_axes`` (0 on personal leaves, None on
    backbone) vmaps it without replicating the backbone per user.
    """
    if spec is not None:
        from repro.core.subset import merge_subset
        heads = merge_subset(params, heads)
        p_axes = jax.tree.map(lambda m: 0 if m else None, spec.mask(params))
    else:
        p_axes = 0
    prompt_u = prompt[:, None, :]                      # [U, 1, L]
    init = jax.vmap(lambda p, t: api.init_cache(
        cfg, p, _init_batch(cfg, t[:, :1]), max_len, cfg.activation_dtype),
        in_axes=(p_axes, 0))
    cache = init(heads, prompt_u)
    prefill = jax.jit(jax.vmap(make_prefill(cfg), in_axes=(p_axes, 0, 0)))
    step = jax.jit(jax.vmap(
        lambda p, c, t, pos: api.decode_step(cfg, p, c, t, pos),
        in_axes=(p_axes, 0, 0, None)))
    cache = prefill(heads, cache, prompt_u)
    tok = prompt_u[:, :, -1:]                          # [U, 1, 1]
    generated = []
    for pos in range(prompt_len - 1, max_len - 1):
        logits, cache = step(heads, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, :, -1, :], axis=-1)[..., None] \
            .astype(jnp.int32)                         # [U, 1, 1]
        generated.append(tok[:, 0])
    jax.block_until_ready(tok)
    return jnp.concatenate(generated, axis=1) if generated else None


def _serve_transport(args, server) -> None:
    """Run the socket front-end until --serve-seconds elapse or ^C."""
    from repro.serving.transport import PROTOCOL_VERSION, TransportServer
    ts = TransportServer(server, port=args.listen, flush_ms=args.flush_ms,
                         window_ms=args.window_ms,
                         max_inflight=args.max_inflight)

    async def run():
        await ts.start()
        print(f"serving personalization on 127.0.0.1:{ts.port} "
              f"(wire protocol v{PROTOCOL_VERSION}, mode {args.mode}, "
              f"flush_ms={args.flush_ms}, window_ms={args.window_ms}, "
              f"max_inflight={args.max_inflight})", flush=True)
        try:
            if args.serve_seconds is not None:
                await asyncio.sleep(args.serve_seconds)
            else:
                await asyncio.Event().wait()
        finally:
            await ts.stop()
            print(f"transport stopped after "
                  f"{ts.stats['connections']} connections / "
                  f"{ts.stats['frames']} frames "
                  f"(host_materializations="
                  f"{server.stats['host_materializations']})", flush=True)

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4,
                    help="concurrent users (decode batch size)")
    ap.add_argument("--tokens", type=int, default=16, help="tokens to decode")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--personalize", action="store_true",
                    help="serve per-user personalized heads through "
                         "PersonalizationServer")
    ap.add_argument("--personalize-len", type=int, default=None,
                    help="per-user personalization stream length "
                         "(default: --prompt-len)")
    ap.add_argument("--mode", choices=("B", "C"), default="C",
                    help="personalization mode: B = one-step MAML "
                         "fine-tune, C = Moreau prox solve")
    ap.add_argument("--personal-subset", default=None, metavar="PREFIXES",
                    help="comma-separated param-path prefixes (checkpoint "
                         "spelling, e.g. 'head' or 'blocks/#11') — only "
                         "these leaves are personalized per user; the "
                         "backbone stays shared and is never banked")
    ap.add_argument("--delta-dtype", choices=("fp32", "int8"),
                    default="fp32",
                    help="delta banking codec: int8 quantizes banked "
                         "delta/residual rows (error feedback keeps "
                         "convergence) and compresses the transport wire "
                         "for codec_ok clients")
    ap.add_argument("--lam", type=float, default=30.0)
    ap.add_argument("--alpha", type=float, default=0.01)
    ap.add_argument("--inner-steps", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/serve")
    ap.add_argument("--listen", type=int, default=None, metavar="PORT",
                    help="serve personalization over a socket transport "
                         "on this port (0 = ephemeral) instead of the "
                         "one-shot decode; implies --personalize")
    ap.add_argument("--flush-ms", type=float, default=10.0,
                    help="transport deadline flush: a partial request "
                         "queue older than this is flushed by timer")
    ap.add_argument("--window-ms", type=float, default=None,
                    help="advance the aggregation window on this "
                         "wall-clock period (default: only on ADVANCE "
                         "frames)")
    ap.add_argument("--max-inflight", type=int, default=256,
                    help="transport backpressure: max open tickets "
                         "before SUBMIT gets a BUSY frame")
    ap.add_argument("--serve-seconds", type=float, default=None,
                    help="with --listen: stop after this many seconds "
                         "(default: serve until interrupted)")
    ap.add_argument("--model-axis", type=int, default=None, metavar="M",
                    help="serve over the 2-D ('cohort', 'model') mesh with "
                         "M-way model parallelism (device count must be a "
                         "multiple of M); banks/snapshots/heads/params are "
                         "stored model-axis-sharded, served bits match the "
                         "1-D path")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator address; given alone "
                         "it implies --num-processes 1 (single-host boot)")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total process count for jax.distributed."
                         "initialize (multi-host serving: one process per "
                         "host, every process runs the same command with "
                         "its own --process-id)")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this process's rank in [0, --num-processes)")
    args = ap.parse_args()

    if args.listen is not None:
        args.personalize = True

    if args.coordinator is not None or args.num_processes is not None:
        # must run before any device/backend use in this process
        jax.distributed.initialize(
            coordinator_address=args.coordinator or "127.0.0.1:12377",
            num_processes=args.num_processes or 1,
            process_id=args.process_id)
        print(f"jax.distributed: process {jax.process_index()}/"
              f"{jax.process_count()}, "
              f"{jax.local_device_count()}/{jax.device_count()} local "
              f"devices", flush=True)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(cfg, key)

    B = args.requests
    max_len = args.prompt_len + args.tokens
    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)

    heads = None
    server_stats = None
    subset_spec = None
    if args.personalize:
        from repro.core.subset import SubsetSpec
        from repro.serving import PersonalizationServer
        plen = _personalize_len(cfg, args.personalize_len
                                if args.personalize_len is not None
                                else args.prompt_len)
        loss = lambda p, b: api.loss_fn(cfg, p, b)          # noqa: E731
        pcfg = PersAFLConfig(option="C", lam=args.lam, alpha=args.alpha,
                             inner_steps=args.inner_steps, inner_eta=0.01)
        subset_spec = SubsetSpec.resolve(args.personal_subset, params)
        mesh_kw = {}
        if args.model_axis is not None:
            from repro.sharding.ctx import cohort_model_mesh
            from repro.sharding.rules import param_shardings
            mesh = cohort_model_mesh(args.model_axis)
            mesh_kw = {"cohort_impl": "shard_map", "mesh": mesh,
                       "param_shardings":
                           param_shardings(cfg, params, mesh)}
            print(f"2-D mesh: cohort={mesh.devices.shape[0]} × "
                  f"model={mesh.devices.shape[1]} over "
                  f"{mesh.devices.size} devices", flush=True)
        server = PersonalizationServer(params, loss, pcfg,
                                       modes=(args.mode,),
                                       max_pending=max(B, 1),
                                       personal_subset=subset_spec,
                                       delta_dtype=args.delta_dtype,
                                       **mesh_kw)
        if args.listen is not None:
            _serve_transport(args, server)
            return
        tickets = [server.submit(f"user{u}",
                                 _user_batch(cfg, args.seed + u, plen),
                                 mode=args.mode)
                   for u in range(B)]
        server.flush()
        heads = server.stacked_heads([t.user for t in tickets])
        server_stats = server.stats
        print(f"personalized {B} users through PersonalizationServer "
              f"(mode {args.mode}, len={plen}, "
              f"cohort_calls={server_stats['cohort_calls']}, "
              f"host_materializations="
              f"{server_stats['host_materializations']})")

    t0 = time.time()
    if heads is not None:
        out_tokens = _decode_personalized(cfg, heads, prompt, max_len,
                                          args.prompt_len,
                                          params=params, spec=subset_spec)
    else:
        out_tokens = _decode_shared(cfg, params, prompt, max_len,
                                    args.prompt_len)
    wall = time.time() - t0
    tps = B * args.tokens / wall
    print(f"decoded {args.tokens} tokens × {B} requests "
          f"in {wall:.2f}s ({tps:.1f} tok/s)")
    if out_tokens is not None:
        print("sample:", out_tokens[0].tolist())
    os.makedirs(args.out, exist_ok=True)
    record = {"arch": cfg.arch_id, "tok_per_s": tps,
              "personalized": args.personalize, "mode": args.mode,
              "users": B, "model_axis": args.model_axis,
              "personal_subset": (subset_spec.descriptor()
                                  if subset_spec is not None else None),
              "delta_dtype": args.delta_dtype}
    if server_stats is not None:
        record["ring_bytes_per_user"] = server_stats["ring_bytes_per_user"]
        record["ring_bytes_saved_per_user"] = \
            server_stats["ring_bytes_saved_per_user"]
    if server_stats is not None:
        record["host_materializations"] = \
            server_stats["host_materializations"]
    with open(os.path.join(args.out, f"serve_{cfg.arch_id}.json"), "w") as f:
        json.dump(record, f, indent=2)


if __name__ == "__main__":
    main()
