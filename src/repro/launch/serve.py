"""Personalized serving driver (Option C semantics: each client serves its
Moreau-envelope personalized parameters θ̃_i(w), obtained with a few prox
steps on the client's own data before decoding).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --requests 4 --tokens 16
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.core import personalize_me
from repro.data import synthetic_token_batch
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4, help="batch size")
    ap.add_argument("--tokens", type=int, default=16, help="tokens to decode")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--personalize", action="store_true",
                    help="apply ME personalization before serving")
    ap.add_argument("--lam", type=float, default=30.0)
    ap.add_argument("--inner-steps", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/serve")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(cfg, key)

    if args.personalize:
        data = synthetic_token_batch(args.seed, args.requests, 32, cfg.vocab)
        batch = {k: jnp.asarray(v) for k, v in data.items()}
        if cfg.n_visual_tokens:
            batch["visual"] = jnp.zeros(
                (args.requests, cfg.n_visual_tokens, cfg.d_model),
                cfg.activation_dtype)
        if cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (args.requests, cfg.enc_len, cfg.d_model),
                cfg.activation_dtype)
        loss = lambda p, b: api.loss_fn(cfg, p, b)
        params = personalize_me(loss, params, batch, args.lam,
                                inner_eta=0.01, inner_steps=args.inner_steps)
        print(f"personalized with ME (lambda={args.lam}, "
              f"K={args.inner_steps})")

    B = args.requests
    max_len = args.prompt_len + args.tokens
    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    batch = {"tokens": prompt[:, :1]}
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros((B, cfg.enc_len, cfg.d_model),
                                    cfg.activation_dtype)
    cache = api.init_cache(cfg, params, batch, max_len, cfg.activation_dtype)

    step = jax.jit(lambda p, c, t, pos: api.decode_step(cfg, p, c, t, pos))
    # prefill the prompt token-by-token (batched requests advance together)
    tok = prompt[:, :1]
    t0 = time.time()
    generated = []
    for pos in range(max_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        tok = (prompt[:, pos + 1: pos + 2] if pos + 1 < args.prompt_len
               else nxt)
        if pos + 1 >= args.prompt_len:
            generated.append(nxt)
    jax.block_until_ready(tok)
    wall = time.time() - t0
    out_tokens = jnp.concatenate(generated, axis=1) if generated else None
    tps = B * args.tokens / wall
    print(f"decoded {args.tokens} tokens × {B} requests "
          f"in {wall:.2f}s ({tps:.1f} tok/s)")
    if out_tokens is not None:
        print("sample:", out_tokens[0].tolist())
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"serve_{cfg.arch_id}.json"), "w") as f:
        json.dump({"arch": cfg.arch_id, "tok_per_s": tps,
                   "personalized": args.personalize}, f, indent=2)


if __name__ == "__main__":
    main()
