"""Production mesh construction (TPU v5e target).

Defined as a FUNCTION so importing this module never touches jax device
state.  Single pod: (16, 16) = 256 chips, axes ("data","model"); two pods:
(2, 16, 16) = 512 chips, axes ("pod","data","model") — the "pod" axis is
the slow-ICI/DCN dimension, carrying only client-cohort (data) parallelism
so no tensor-parallel collective ever crosses it (DESIGN.md §5).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh over the local device (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
