"""Persistent sharded DeltaBank ring-buffer (the serving-side bank).

PR 2's :class:`repro.fl.engine.DeltaBank` dies with its simulator window:
banks are produced per inter-apply window and garbage-collected once every
row is applied.  A serving deployment has no "end of run" — personalization
traffic arrives forever and the global model advances in aggregation
*windows* — so :class:`DeltaRing` makes the bank persistent:

  * the banks AND the params snapshot of the last ``windows`` aggregation
    windows stay alive on device, keyed by window id and by user (a user's
    latest delta row is addressable until its window retires);
  * a row admitted with staleness τ > 0 — a straggler whose request was
    stamped in an earlier window — is folded into the *current* window's
    ``apply_rows`` weight vector via :func:`repro.core.admission_weights`
    (β/M with FedAsync damping ``(1+τ)^{-a}``) instead of being dropped:
    the bounded-staleness admission rule mirroring the paper's τ ≤ τ_max
    assumption.  Rows staler than ``tau_max`` ARE dropped (and counted);
  * the window apply routes through the non-donating
    :func:`repro.core.apply_admitted_rows`, because retained snapshots must
    outlive the apply (stragglers are computed against them).

Persistence scope: the ring persists *across windows* (device residency, no
host round-trip) AND — via :meth:`load` +
``PersonalizationServer.save/restore`` through ``repro.checkpoint.store`` —
its params snapshots, window counter and cumulative admission stats survive
process restarts (see :meth:`DeltaRing.load` for exactly which counters
persist and which are process-local).  What a restart still loses:
in-flight straggler delta rows (their banks are device-only); affected
users simply re-personalize against the restored snapshots.

Fairness: ``user_cap`` bounds the delta rows one user may have admitted
into a single window's apply (the ring is the admission authority; the
micro-batcher's matching cap refuses over-cap requests pre-cohort).

Partial-model personalization (``subset=``): with a ``personal_subset``
declared, every banked row and every retained snapshot holds only the
personal leaves (the pruned structure of ``repro.core.subset``) — the
shared backbone is stored ONCE (``_base``) and recombined on demand, so
per-user ring residency shrinks from full-model to subset bytes
(``row_nbytes``; the ``ring_bytes_per_user`` stat and bench gate).  This
is exact, not approximate: subset applies never touch backbone leaves, so
one backbone serves every retained window bit-for-bit.

Quantized delta banking (``delta_dtype="int8"``): the orthogonal residency
axis.  Retained banks arrive as :class:`repro.core.quant.QuantizedBank`
handles — int8 rows + per-row-per-leaf f32 scales (symmetric absmax,
chosen at admission by the server's flush, with per-user error feedback) —
so a banked row costs ~N bytes instead of 4N, and the window apply
dispatches through the fused dequant×weight×accumulate kernel
(``apply_rows_q`` via ``apply_admitted_rows``) without ever materializing
an fp32 row.  The ring additionally demotes the *personal leaves of
retired windows' snapshots* to int8 (:class:`repro.core.quant.QuantTree`,
per-leaf scale): the current window's snapshot stays exact fp32 — fresh
heads are never quantization-noisy — while straggler recomputes against
older windows transparently dequantize through :meth:`snapshot` /
:meth:`subset_snapshot`.  ``row_nbytes`` counts the quantized bytes and
``row_nbytes_fp32`` the fp32 baseline, which is what the ``quant`` bench's
≥ 3.5x ``ring_bytes_per_user`` gate measures.  The ring itself is
codec-agnostic at admission: it pins whatever bank handle the flush
retained and groups admitted rows per bank — fp32 and int8 windows can
even coexist during a migration.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Tuple

from repro.core import (admission_weights, apply_admitted_rows,
                        mask_rows, robust_flush_weights)
from repro.core.quant import (QuantStack, QuantTree, dequantize_tree,
                              fp32_row_nbytes, quantize_tree)
from repro.core.subset import SubsetSpec, merge_subset
from repro.core.subset import row_nbytes as _row_nbytes
from repro.core.types import ServerState
from repro.fl.engine import DeltaBank


class DeltaRing:
    """Ring of the last ``windows`` aggregation windows of stacked deltas.

    ``retain`` is shaped as a :meth:`CohortEngine.add_bank_hook` callback —
    attaching the ring to an engine keeps every bank the engine produces
    alive for ``windows`` windows.  ``admit`` marks a specific (bank, row)
    as contributing to the next server apply; :meth:`advance` closes the
    window with one fused ``apply_rows`` pass per contributing bank.
    """

    def __init__(self, params0, *, windows: int = 4,
                 tau_max: Optional[int] = None,
                 user_cap: Optional[int] = None, subset=None,
                 delta_dtype: str = "fp32",
                 robust: Optional[str] = None,
                 clip_norm: Optional[float] = None,
                 trim_frac: float = 0.1):
        if windows < 1:
            raise ValueError("need at least one retained window")
        if delta_dtype not in ("fp32", "int8"):
            raise ValueError(f"delta_dtype must be 'fp32' or 'int8', "
                             f"got {delta_dtype!r}")
        if robust not in (None, "clip", "trim"):
            raise ValueError(f"robust must be None, 'clip' or 'trim', "
                             f"got {robust!r}")
        self.delta_dtype = delta_dtype
        # Byzantine-robust window apply: "clip" bounds each admitted row's
        # L2 norm, "trim" drops the norm tails of the window, both
        # calibrated over the whole window's admissions (see
        # repro.core.robust_flush_weights); either one also drops
        # non-finite rows, so a NaN-bombing user cannot poison the window
        self.robust = robust
        self.clip_norm = clip_norm
        self.trim_frac = trim_frac
        self.windows = windows
        # a straggler can only be recomputed against a retained snapshot,
        # so the EFFECTIVE staleness bound never exceeds the ring depth —
        # but the REQUESTED value is kept (and checkpointed): restoring
        # this ring into a deeper one must widen back to the request, not
        # keep the accidentally-tightened clamp.
        self.tau_max_requested = int(tau_max) if tau_max is not None \
            else windows - 1
        if tau_max is not None and tau_max > windows - 1:
            warnings.warn(
                f"tau_max={tau_max} exceeds the ring depth; clamped to "
                f"{windows - 1} (a straggler can only be recomputed against "
                f"a retained snapshot).  The requested value is preserved "
                f"for checkpoint round-trips.", stacklevel=2)
        self.tau_max = min(self.tau_max_requested, windows - 1)
        self.user_cap = user_cap
        self.subset = SubsetSpec.resolve(subset, params0)
        # subset mode: snapshots store only the personal leaves; the shared
        # backbone lives once here and is updated by reference each advance
        # (subset applies never change it, so it is valid for EVERY window)
        self._base = params0
        self.row_nbytes: Optional[int] = None  # set at first retained bank
        # fp32-equivalent row bytes (== row_nbytes for fp32 banking): the
        # baseline the quant residency gate and bytes-saved stat compare to
        self.row_nbytes_fp32: Optional[int] = None
        self.current = 0
        self._snapshots: Dict[int, object] = {0: self._store(params0)}
        self._banks: Dict[int, List[DeltaBank]] = {0: []}
        # (bank, row, τ) admitted to the window currently accumulating
        self._pending: List[Tuple[DeltaBank, int, int]] = []
        # user -> rows admitted to the accumulating window (fairness cap)
        self._user_rows: Dict[object, int] = {}
        # user -> (window, bank, row): the user's latest served delta row
        self._by_user: Dict[object, Tuple[int, DeltaBank, int]] = {}
        self.stats = {"windows": 0, "admitted": 0, "stragglers": 0,
                      "dropped": 0, "fairness_capped": 0,
                      "robust_clipped": 0, "robust_trimmed": 0,
                      "robust_nonfinite": 0}

    # -- retention ---------------------------------------------------------

    def _store(self, params):
        """What a window snapshot physically retains: the personal subset
        only (pruned tree) in subset mode, the full params otherwise."""
        return self.subset.extract(params) if self.subset is not None \
            else params

    def _thaw(self, snap):
        """Demoted (int8) snapshots dequantize transparently on access."""
        return dequantize_tree(snap) if isinstance(snap, QuantTree) else snap

    def snapshot(self, window: int):
        """FULL params the given window's cohorts were computed against
        (subset snapshots recombine with the shared backbone on demand;
        int8-demoted snapshots of older windows dequantize on the fly)."""
        snap = self._thaw(self._snapshots[window])
        if self.subset is not None:
            return merge_subset(self._base, snap)
        return snap

    def subset_snapshot(self, window: int):
        """The window's snapshot in stored *structure* — the pruned subset
        tree in subset mode (what head computation subtracts subset delta
        stacks from), the full params otherwise — dequantized to fp32 when
        the window was demoted to int8."""
        return self._thaw(self._snapshots[window])

    def retain(self, bank) -> None:
        """Bank-handoff hook: pin ``bank`` to the current window so its
        device buffer outlives the window (stragglers, head gathers).
        ``bank`` is a DeltaBank or, under int8 banking, a
        :class:`repro.core.quant.QuantizedBank` — the ring only needs
        ``stacked``/``capacity``/``__len__``."""
        self._banks[self.current].append(bank)
        if self.row_nbytes is None and len(bank):
            self.row_nbytes = _row_nbytes(bank.stacked)
            self.row_nbytes_fp32 = (
                fp32_row_nbytes(bank.stacked)
                if isinstance(bank.stacked, QuantStack)
                else self.row_nbytes)

    def lookup(self, user):
        """-> (window, bank, row) of the user's latest admitted delta, or
        None once the row's window has retired from the ring."""
        return self._by_user.get(user)

    def admitted_rows(self, user) -> int:
        """Rows this user already has admitted into the accumulating
        window — the consumed share of the ``user_cap`` fairness budget
        (front-ends consult this to refuse over-cap work at the door)."""
        return self._user_rows.get(user, 0)

    @property
    def live_banks(self) -> int:
        return sum(len(b) for b in self._banks.values())

    # -- admission ---------------------------------------------------------

    def admit_row(self, user, bank: DeltaBank, row: int, tau: int) -> str:
        """Admit one delta row into the accumulating window's apply.

        ``tau`` is the row's staleness in windows (0 = computed against the
        current snapshot).  Straggler rows (τ > 0) are re-weighted into
        THIS window — the "next" window relative to the one they were
        stamped in.  The ring is the admission authority, so a refusal
        *reports its cause*: ``"dropped"`` for rows past ``tau_max``,
        ``"capped"`` for a user's row past the per-window fairness cap
        (``user_cap``), ``"admitted"`` otherwise — callers surface the
        cause to the user (a fairness refusal is re-submittable next
        window; a staleness drop needs a fresh snapshot).
        """
        if tau > self.tau_max:
            self.stats["dropped"] += 1
            return "dropped"
        if self.user_cap is not None \
                and self._user_rows.get(user, 0) >= self.user_cap:
            self.stats["fairness_capped"] += 1
            return "capped"
        if tau > 0:
            self.stats["stragglers"] += 1
        self.stats["admitted"] += 1
        self._user_rows[user] = self._user_rows.get(user, 0) + 1
        self._pending.append((bank, row, tau))
        self._by_user[user] = (self.current, bank, row)
        return "admitted"

    def admit(self, user, bank: DeltaBank, row: int, tau: int) -> bool:
        """Boolean convenience wrapper over :meth:`admit_row`."""
        return self.admit_row(user, bank, row, tau) == "admitted"

    # -- window boundary ---------------------------------------------------

    def advance(self, state: ServerState, *, beta: float,
                damping: float = 0.0) -> ServerState:
        """Close the accumulating window: apply every admitted row to the
        server state and rotate the ring.

        One fused ``apply_rows`` pass per contributing bank — weights fold
        β/M, per-row staleness damping and bucket-padding masks, exactly
        the buffered scheduler's math (:func:`admission_weights` is shared
        with it).  Each bank's apply receives the window's ADMISSION order
        (the order rows entered ``admit_row`` — submit order, by the
        batcher's contract): on device-spanning banks the rows accumulate
        sequentially in that order, so the post-advance params are
        bit-identical between the 1-D and 2-D mesh layouts even though
        the user→row placement differs.  Returns the post-apply state;
        the pre-apply params become the closed window's snapshot and stay
        retained (the apply never donates them).
        """
        m = len(self._pending)
        if m:
            groups: Dict[int, Tuple[DeltaBank, List[Tuple[int, int]]]] = {}
            for bank, row, tau in self._pending:
                groups.setdefault(id(bank), (bank, []))[1].append((row, tau))
            if self.robust is not None:
                # one call for the whole window — the defense calibrates
                # over every pending admission, current bank and straggler
                # banks together (a lone straggler row would otherwise set
                # its own clip median); row norms are reduced on device
                # ([capacity] f32 is all that crosses to host)
                per_bank, info = robust_flush_weights(
                    groups, beta=beta, count=m, damping=damping,
                    tau_max=self.tau_max, method=self.robust,
                    clip_norm=self.clip_norm, trim_frac=self.trim_frac)
                for key in ("clipped", "trimmed", "nonfinite"):
                    self.stats[f"robust_{key}"] += info[key]
            for key, (bank, rows) in groups.items():
                if self.robust is not None:
                    weights, keep = per_bank[key]
                    # non-finite rows masked out of the stack so
                    # 0-weights cannot leak NaNs (0×NaN=NaN)
                    stack = bank.stacked if bool(keep.all()) \
                        else mask_rows(bank.stacked, keep)
                else:
                    weights = admission_weights(
                        bank.capacity, rows, beta=beta, count=m,
                        damping=damping, tau_max=self.tau_max)
                    stack = bank.stacked
                # admission order, deduped (a twice-admitted row already
                # carries its accumulated weight), then the zero-weight
                # remainder — a full permutation for the ordered apply
                seen, order = set(), []
                for r, _ in rows:
                    if r not in seen:
                        seen.add(r)
                        order.append(r)
                order.extend(r for r in range(bank.capacity)
                             if r not in seen)
                state = apply_admitted_rows(
                    state, stack, weights, len(rows),
                    staleness_max=max(t for _, t in rows),
                    staleness_sum=float(sum(t for _, t in rows)),
                    order=order)
        self._pending = []
        self._user_rows = {}
        self.stats["windows"] += 1
        self.current += 1
        self._base = state.params
        self._snapshots[self.current] = self._store(state.params)
        self._banks[self.current] = []
        if self.delta_dtype == "int8":
            # demote the just-closed window's snapshot (personal leaves) to
            # int8: only stragglers re-read it, and their banked deltas are
            # int8+EF anyway.  The CURRENT snapshot stays exact fp32 so
            # fresh heads carry no quantization noise.
            prev = self.current - 1
            if prev in self._snapshots \
                    and not isinstance(self._snapshots[prev], QuantTree):
                self._snapshots[prev] = quantize_tree(self._snapshots[prev])
        horizon = self.current - self.windows + 1
        for w in [w for w in self._snapshots if w < horizon]:
            del self._snapshots[w]
            self._banks.pop(w, None)
        for user in [u for u, (w, _, _) in self._by_user.items()
                     if w < horizon]:
            del self._by_user[user]
        return state

    # -- restart warm-start ------------------------------------------------

    def load(self, snapshots: Dict[int, object], current: int,
             stats: Optional[Dict[str, int]] = None) -> None:
        """Warm-start after a process restart: install the checkpointed
        params snapshots, window counter AND cumulative ``stats`` (see
        ``PersonalizationServer.save``/``restore``).  Banks, pending
        admissions and per-user delta rows start empty — in-flight
        straggler rows do not survive a restart — but straggler *requests*
        stamped before the crash can still drain against their restored
        snapshots.

        Persistence scope of the counters: every key of ``self.stats``
        (``windows``/``admitted``/``stragglers``/``dropped``/
        ``fairness_capped``) is lifetime-cumulative and survives restarts
        through the checkpoint — per-window serve metrics derived from
        them (e.g. admitted-per-window) stay consistent with the restored
        window counter instead of restarting at zero.  Engine and batcher
        stats (``host_materializations``, ``cohort_calls``, …) are
        *process-local* by design and always restart at zero.  Pre-stats
        checkpoints fall back to ``windows = current`` (the one counter
        the window id implies) with the rest unknown-as-zero."""
        if current not in snapshots:
            raise ValueError(f"current window {current} has no snapshot")
        horizon = current - self.windows + 1
        self.current = current
        self._snapshots = {w: s for w, s in snapshots.items()
                           if w >= horizon}
        self._banks = {w: [] for w in self._snapshots}
        self._pending = []
        self._user_rows = {}
        self._by_user = {}
        if stats is not None:
            self.stats.update({k: int(v) for k, v in stats.items()
                               if k in self.stats})
        else:
            self.stats["windows"] = current
