"""Asyncio socket front-end: the network transport for
:class:`repro.serving.PersonalizationServer`.

Until this module, no request could reach the serving stack from outside
the Python process — submit/flush/poll were in-process method calls.
:class:`TransportServer` makes the server network-addressable while keeping
every micro-batching property intact: concurrent connections' SUBMIT frames
land in the same :class:`repro.serving.batcher.MicroBatcher` queue and
coalesce into the same pow2-bucketed cohort calls, so the transport never
forfeits the batched-personalization win.

Wire protocol (version 1) — length-prefixed JSON + binary frames::

    frame  := u32 len(rest) | u32 len(header) | header | body
    header := UTF-8 JSON object; "op" selects the operation
    body   := npz-encoded pytree (numpy ``savez`` of the checkpoint
              store's ``path/to/leaf`` flat layout) or empty

All u32 are big-endian.  Client → server ops and their replies:

    SUBMIT {user, mode, subset_ok?, codec_ok?}  + npz(batch)
                                       → OK {ticket, window}
                                         | BUSY {scope, open}
    POLL   {ticket, wait_ms?, subset_ok?}
                                       → OK {status:"queued"}
                                         | OK {status:"done", subset?,
                                               codec} + npz(head)
                                         | ERR {code: dropped|capped|
                                                evicted|superseded, error}
    HEAD   {user, subset_ok?, codec_ok?} → OK {subset?, codec} + npz(head)
                                         | ERR unknown_user
    STATS  {}                          → OK {stats: {...}, subset?}
    FLUSH  {}                          → OK {served}
    ADVANCE{}                          → OK {window}

Subset negotiation: when the fronted server personalizes a
``personal_subset`` only, head bodies are *subset pytrees* (pruned
structure; merge over the global backbone with
``repro.core.merge_subset``).  A v1 client that does not declare
``subset_ok: true`` on SUBMIT/POLL/HEAD gets a typed
``ERR subset_unsupported`` instead of a silently-partial pytree; replies
that carry a subset body stamp the resolved leaf paths in the header's
``subset`` key (both clients record it as ``.last_subset``).

Codec negotiation (compressed wire): npz bodies may carry float leaves as
symmetric-absmax **int8 codes + one f32 scale per leaf** — the scale rides
in the same flat layout under a ``__q8s__:<key>`` marker, so an int8 body
is self-describing and ``decode_pytree`` dequantizes transparently.  The
negotiation mirrors the subset handshake but FALLS BACK instead of
refusing: a quantized-banking server (``delta_dtype="int8"``, or an
explicit ``wire_codec=``) sends int8 head bodies only to clients that
declared ``codec_ok: true`` at SUBMIT (HEAD negotiates per request);
non-declaring clients get plain fp32 bodies — a precision downgrade is
never silent, and replies stamp the body's actual codec in the header's
``codec`` key (clients record ``.last_codec``).  Uplink SUBMIT bodies are
the client's choice: constructing a client with ``codec="int8"`` encodes
its batches quantized (the server decodes either form).  At the ``quant``
bench's serve config both directions shrink ≥ 3.5x.

Deadline-driven flushing: a SUBMIT that fills the underlying server's
``max_pending`` queue flushes synchronously (the micro-batch path); a
partial queue is flushed by a ``flush_ms`` timer armed at the first queued
request — so latency is bounded by ``max(flush_ms, cohort call)`` even at
low request rates.  ``window_ms`` optionally drives ``advance_window`` on a
wall-clock timer (the aggregation-window boundary of the serving rules).

Backpressure is explicit, never unbounded growth: ``max_inflight`` bounds
the server-wide open tickets (submitted, not yet terminally polled),
``conn_inflight`` bounds one connection's, and with the server's
``user_cap`` fairness bound set, a user's queued submissions per window are
refused at the door — each refusal is a ``BUSY`` frame naming its scope
(``server`` / ``connection`` / ``user``), and clients raise
:class:`TransportBusy` so callers can back off and retry.

Clients: :class:`TransportClient` is the blocking library (any second OS
process: ``submit``/``poll``/``head``/``stats``), :class:`AsyncTransportClient`
the asyncio twin (the load generator drives N of them concurrently).
Frames on one connection are handled in order; issue one RPC at a time per
connection and open more connections for concurrency.

Quickstart (see also ``launch/serve.py --listen PORT``)::

    # process 1
    srv = PersonalizationServer(params, loss, pcfg)
    ts = TransportServer(srv, port=7777, flush_ms=10.0)
    asyncio.run(ts.serve_forever())

    # process 2
    c = TransportClient("127.0.0.1", 7777)
    head = c.poll(c.submit("user-a", batch, mode="C"), wait_ms=5_000)

``python -m repro.serving.transport`` runs a loopback selftest (tiny
logistic workload, concurrent clients, zero-host-materialization check,
clean shutdown) — the CI ``transport-smoke`` job's entry point.
"""
from __future__ import annotations

import asyncio
import io
import json
import socket
import struct
from typing import Dict, Optional, Tuple

import numpy as np

from repro.checkpoint.store import (flatten_pytree, pack_dtypes,
                                    unflatten_pytree, unpack_dtypes)

PROTOCOL_VERSION = 1
WIRE_CODECS = ("fp32", "int8")
# per-leaf wire-quantization scale marker: like the checkpoint store's
# ``__dt__:`` markers, the ``:`` keeps it disjoint from every data key
Q8_KEY_PREFIX = "__q8s__:"
_U32 = struct.Struct("!I")
# reject absurd frames instead of buffering our way to OOM
MAX_FRAME_BYTES = 1 << 28


class ProtocolError(RuntimeError):
    """Malformed frame or header (framing, not application, errors)."""


class TransportError(RuntimeError):
    """Application-level ERR reply surfaced client-side.

    ``code`` mirrors the server's refusal cause: ``dropped`` (staleness
    past tau_max), ``capped`` (per-window fairness cap), ``superseded``
    (the ticket's ring window retired before it was polled), ``evicted``
    (LRU head-cache pressure on a handle-less ticket),
    ``subset_unsupported`` (the server serves personal-subset heads and
    the client did not declare ``subset_ok``), ``unknown_user`` /
    ``unknown_ticket`` / ``bad_request``.
    """

    def __init__(self, code: str, message: str):
        super().__init__(f"{code}: {message}")
        self.code = code


class TransportBusy(TransportError):
    """BUSY reply: the server refused to queue more work.  ``scope`` says
    which bound tripped (``server`` / ``connection`` / ``user``) — back
    off and retry, nothing was queued."""

    def __init__(self, scope: str, open_tickets: int):
        super().__init__(
            "busy", f"backpressure at scope={scope!r} "
                    f"(open={open_tickets}); retry later")
        self.scope = scope
        self.open_tickets = open_tickets


# ---------------------------------------------------------------------------
# codec: npz pytrees + length-prefixed frames
# ---------------------------------------------------------------------------

def encode_pytree(tree, codec: str = "fp32") -> bytes:
    """Pytree → npz bytes in the checkpoint store's flat key layout.
    ``np.asarray`` on each leaf moves device arrays to the host — the wire
    is a host boundary by definition (this is NOT a DeltaBank
    materialization; the ``host_materializations`` stat stays untouched).

    Non-float dtypes (int8/uint8/bf16/...) round-trip EXACTLY in every
    codec: ml_dtypes leaves travel as bit patterns + ``__dt__:`` markers
    (``pack_dtypes``), integer leaves natively.  ``codec="int8"``
    additionally rewrites each f32/f64 leaf as int8 codes + one f32 scale
    under ``__q8s__:<key>`` (symmetric absmax — the delta-banking codec
    reused on the wire); the body stays self-describing, so the decoder
    needs no negotiated state.
    """
    if codec not in WIRE_CODECS:
        raise ValueError(f"codec must be one of {WIRE_CODECS}, "
                         f"got {codec!r}")
    flat = flatten_pytree(tree)
    if codec == "int8":
        out = {}
        for key, val in flat.items():
            arr = np.asarray(val)
            if arr.dtype.kind == "f" and arr.dtype.itemsize >= 4:
                x = arr.astype(np.float32)
                scale = np.float32(np.max(np.abs(x)) / 127.0
                                   if x.size else 0.0)
                safe = scale if scale > 0 else np.float32(1.0)
                out[key] = np.clip(np.round(x / safe),
                                   -127, 127).astype(np.int8)
                out[Q8_KEY_PREFIX + key] = scale
            else:
                out[key] = arr
        flat = out
    buf = io.BytesIO()
    np.savez(buf, **pack_dtypes(flat))
    return buf.getvalue()


def decode_pytree(data: bytes):
    """npz bytes → pytree (dicts/lists of numpy arrays).  Self-describing
    inverse of :func:`encode_pytree`: ``__dt__:`` markers restore exact
    non-native dtypes, ``__q8s__:`` markers dequantize int8 leaves."""
    with np.load(io.BytesIO(data)) as z:
        flat = unpack_dtypes({k: z[k] for k in z.files})
    scales = {k[len(Q8_KEY_PREFIX):]: flat[k] for k in flat
              if k.startswith(Q8_KEY_PREFIX)}
    if scales:
        flat = {k: v for k, v in flat.items()
                if not k.startswith(Q8_KEY_PREFIX)}
        for key, scale in scales.items():
            flat[key] = flat[key].astype(np.float32) * np.float32(scale)
    return unflatten_pytree(flat)


def pack_frame(header: Dict, body: bytes = b"") -> bytes:
    hdr = json.dumps(header, separators=(",", ":")).encode()
    return (_U32.pack(4 + len(hdr) + len(body)) + _U32.pack(len(hdr))
            + hdr + body)


def split_frame(payload: bytes) -> Tuple[Dict, bytes]:
    if len(payload) < 4:
        raise ProtocolError("truncated frame")
    (hlen,) = _U32.unpack_from(payload)
    if 4 + hlen > len(payload):
        raise ProtocolError("header length exceeds frame")
    try:
        header = json.loads(payload[4:4 + hlen])
    except ValueError as e:
        raise ProtocolError(f"bad header JSON: {e}") from None
    if not isinstance(header, dict):
        raise ProtocolError("header must be a JSON object")
    return header, payload[4 + hlen:]


async def read_frame(reader: asyncio.StreamReader
                     ) -> Optional[Tuple[Dict, bytes]]:
    """One frame off an asyncio stream; None on clean EOF."""
    try:
        raw = await reader.readexactly(4)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    (n,) = _U32.unpack(raw)
    if n < 4 or n > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame length {n} out of bounds")
    try:
        payload = await reader.readexactly(n)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None
    return split_frame(payload)


def _no_nagle(sock_like) -> None:
    """Frames are small request/reply pairs: Nagle + delayed ACK would add
    ~40ms per RPC on loopback, drowning the micro-batch win."""
    try:
        sock_like.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    except (OSError, AttributeError):
        pass


def _jsonable(stats: Dict) -> Dict:
    out = {}
    for k, v in stats.items():
        if isinstance(v, str):
            out[k] = v          # e.g. delta_codec
        elif isinstance(v, (float, np.floating)):
            out[k] = float(v)
        elif isinstance(v, (int, np.integer)):
            out[k] = int(v)
    return out


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

class _Record:
    """One open ticket: the server-side Ticket plus the event POLL waits
    on (set when a flush turns the ticket terminal), for served tickets
    the pre-encoded npz reply body, and for tickets lost to a poisoned
    flush the failure message (see ``TransportServer._resolve`` /
    ``_safe_call``)."""

    __slots__ = ("ticket", "event", "user", "encoded", "failed", "codec")

    def __init__(self, ticket, user, codec: str = "fp32"):
        self.ticket = ticket
        self.event = asyncio.Event()
        self.user = user
        self.encoded: Optional[bytes] = None
        self.failed: Optional[str] = None
        # reply-body codec negotiated at SUBMIT: "int8" only when the
        # server runs a quantized wire AND this client declared codec_ok
        self.codec = codec


class _Conn:
    __slots__ = ("records", "next_tid")

    def __init__(self):
        self.records: Dict[int, _Record] = {}
        self.next_tid = 0


class TransportServer:
    """Bridges concurrent socket connections into one
    :class:`PersonalizationServer`'s submit/flush/poll surface.

    Parameters
    ----------
    server        : the PersonalizationServer being fronted
    host, port    : bind address (``port=0`` = ephemeral; ``self.port``
                    holds the bound port after :meth:`start`)
    flush_ms      : deadline flush — a partial queue older than this is
                    flushed by timer (a full ``max_pending`` queue flushes
                    synchronously inside submit, as in-process)
    window_ms     : optional wall-clock aggregation-window timer driving
                    ``advance_window`` (None = windows advance only via
                    ADVANCE frames or the owning process)
    max_inflight  : server-wide bound on open tickets → ``BUSY server``
    conn_inflight : per-connection bound on open tickets → ``BUSY
                    connection``
    per-user      : with the fronted server's ``user_cap`` set, a user's
                    *queued* submissions in the current window are bounded
                    by it → ``BUSY user`` (cheaper than burning a queue
                    slot on a request the ring would refuse as "capped")

    Everything runs on one event loop; cohort compute blocks it for the
    duration of a flush, which is exactly the micro-batch amortization the
    serving stack is built around.
    """

    def __init__(self, server, *, host: str = "127.0.0.1", port: int = 0,
                 flush_ms: float = 10.0, window_ms: Optional[float] = None,
                 max_inflight: int = 256, conn_inflight: int = 64,
                 wire_codec: Optional[str] = None):
        self.server = server
        self.host = host
        spec = getattr(server, "personal_subset", None)
        # resolved once: the leaf paths stamped into subset reply headers
        # and matched against clients' subset_ok declarations
        self._subset_desc = spec.descriptor(server.params) \
            if spec is not None else None
        # the wire codec follows the fronted server's banking codec unless
        # overridden; int8 bodies still reach only codec_ok clients
        if wire_codec is None:
            wire_codec = getattr(server, "delta_dtype", "fp32")
        if wire_codec not in WIRE_CODECS:
            raise ValueError(f"wire_codec must be one of {WIRE_CODECS}, "
                             f"got {wire_codec!r}")
        self.wire_codec = wire_codec
        self.requested_port = port
        self.flush_ms = flush_ms
        self.window_ms = window_ms
        self.max_inflight = max_inflight
        self.conn_inflight = conn_inflight
        self.port: Optional[int] = None
        self._srv: Optional[asyncio.AbstractServer] = None
        self._conns: set = set()
        self._writers: set = set()
        self._tasks: set = set()
        self._inflight = 0
        self._flush_handle = None
        self._window_handle = None
        self.stats = {"connections": 0, "frames": 0, "busy": 0,
                      "timer_flushes": 0, "window_advances": 0,
                      "failed_flushes": 0}

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "TransportServer":
        self._srv = await asyncio.start_server(self._handle, self.host,
                                               self.requested_port)
        self.port = self._srv.sockets[0].getsockname()[1]
        if self.window_ms is not None:
            self._window_handle = asyncio.get_running_loop().call_later(
                self.window_ms / 1e3, self._on_window_timer)
        return self

    async def stop(self) -> None:
        """Clean shutdown: stop listening, drop connections, cancel
        timers.  Queued-but-unflushed requests stay in the fronted
        server's queue (its owner may still flush them)."""
        for h in (self._flush_handle, self._window_handle):
            if h is not None:
                h.cancel()
        self._flush_handle = self._window_handle = None
        if self._srv is not None:
            self._srv.close()
            await self._srv.wait_closed()
        for w in list(self._writers):
            w.close()
        # a handler parked in a long POLL wait is not woken by its writer
        # closing — cancel outright so shutdown never strands a task
        for t in list(self._tasks):
            t.cancel()
        if self._tasks:
            await asyncio.gather(*list(self._tasks),
                                 return_exceptions=True)

    async def serve_forever(self, *, announce: bool = False) -> None:
        if self._srv is None:
            await self.start()
        if announce:
            print(f"transport: listening on {self.host}:{self.port} "
                  f"(wire protocol v{PROTOCOL_VERSION})", flush=True)
        try:
            await self._srv.serve_forever()
        finally:
            await self.stop()

    # -- timers ------------------------------------------------------------

    def _sync_flush_timer(self) -> None:
        """The deadline belongs to the oldest queued request: armed when
        the queue goes non-empty, cancelled the moment a flush empties it
        (a stale timer would fire mid-next-batch and split its cohort)."""
        if len(self.server.batcher):
            if self._flush_handle is None:
                self._flush_handle = asyncio.get_running_loop().call_later(
                    self.flush_ms / 1e3, self._on_flush_timer)
        elif self._flush_handle is not None:
            self._flush_handle.cancel()
            self._flush_handle = None

    def _on_flush_timer(self) -> None:
        self._flush_handle = None
        self.stats["timer_flushes"] += 1
        self._safe_call(self.server.flush)
        self._resolve()

    def _on_window_timer(self) -> None:
        self.stats["window_advances"] += 1
        self._safe_call(self.server.advance_window)
        self._resolve()
        self._sync_flush_timer()
        self._window_handle = asyncio.get_running_loop().call_later(
            self.window_ms / 1e3, self._on_window_timer)

    def _safe_call(self, fn) -> Tuple[Optional[object], Optional[str]]:
        """Run a flush/advance without letting one poisoned batch kill
        the event loop: a cohort call raising (bad shapes, missing keys —
        remote clients send arbitrary pytrees) has already consumed the
        drained queue, so every still-queued ticket's batch is gone.
        Those tickets fail with the cause; the server keeps serving."""
        try:
            return fn(), None
        except Exception as e:      # noqa: BLE001 — remote input boundary
            msg = f"{type(e).__name__}: {e}"
            self._fail_queued(msg)
            return None, msg

    def _fail_queued(self, msg: str) -> None:
        self.stats["failed_flushes"] += 1
        for conn in self._conns:
            for rec in conn.records.values():
                if rec.ticket.status == "queued" \
                        and not rec.event.is_set():
                    rec.failed = msg
                    rec.event.set()

    def _resolve(self) -> None:
        """Wake every POLL waiter whose ticket a flush just turned
        terminal, and micro-batch the response path: the heads this flush
        served are encoded from ONE stacked gather + ONE host transfer
        (per-ticket npz slicing in numpy) instead of two eager gather
        dispatches and a device sync per POLL — the wire must not forfeit
        the batching the cohort call just won.

        Refused tickets (dropped/capped) — and handle-less done tickets,
        which the per-POLL fallback resolves — carry no body here.  The
        gather is PER TICKET HANDLE, not per user: each record's head
        comes from its own ticket's (bank, row), grouped by bank into one
        ``jnp.take`` + one transfer each (steady state: one bank per
        flush), so an older ticket's body is never aliased to the user's
        newest head.  (An executor-thread variant of the blocking
        ``device_get`` was measured and rejected: on CPU the PJRT
        client serializes with the loop thread's dispatches and the hop
        costs more than it overlaps.)"""
        done = []
        horizon = self.server.window - self.server.ring.windows + 1
        for conn in self._conns:
            for rec in conn.records.values():
                if rec.ticket.status != "queued" and not rec.event.is_set():
                    # retired-window tickets are NOT encoded: their poll
                    # must report superseded, not a stale body
                    if rec.ticket.status == "done" \
                            and rec.ticket.head is not None \
                            and rec.ticket.window >= horizon:
                        done.append(rec)
                    else:
                        rec.event.set()
        if not done:
            return
        import jax
        import jax.numpy as jnp
        groups: Dict[int, Tuple[object, list]] = {}
        for rec in done:
            bank, row = rec.ticket.head
            groups.setdefault(id(bank), (bank, []))[1].append((rec, row))
        for bank, pairs in groups.values():
            rows = jnp.asarray([r for _, r in pairs], jnp.int32)
            # quantized banking serves LAZY head handles (no .stacked):
            # .rows() is the fused snapshot − scale·q gather — still one
            # device gather + one transfer for the whole group
            if hasattr(bank, "rows"):
                gathered = bank.rows(rows)
            else:
                gathered = jax.tree.map(
                    lambda x: jnp.take(x, rows, axis=0), bank.stacked)
            host = jax.device_get(gathered)
            for i, (rec, _) in enumerate(pairs):
                rec.encoded = encode_pytree(
                    jax.tree.map(lambda x: x[i], host), codec=rec.codec)
                rec.event.set()

    # -- connection handling -----------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        conn = _Conn()
        _no_nagle(writer.get_extra_info("socket"))
        self._conns.add(conn)
        self._writers.add(writer)
        self._tasks.add(asyncio.current_task())
        self.stats["connections"] += 1
        try:
            while True:
                frame = await read_frame(reader)
                if frame is None:
                    break
                self.stats["frames"] += 1
                header, body = frame
                try:
                    reply, rbody = await self._dispatch(conn, header, body)
                except (ProtocolError, KeyError, TypeError,
                        ValueError) as e:
                    reply, rbody = {"op": "ERR", "code": "bad_request",
                                    "error": str(e)}, b""
                writer.write(pack_frame(reply, rbody))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError, ProtocolError):
            pass
        finally:
            # a dead connection releases its open tickets (backpressure
            # slots must not leak); the server-side work still completes
            self._inflight -= len(conn.records)
            conn.records.clear()
            self._conns.discard(conn)
            self._writers.discard(writer)
            self._tasks.discard(asyncio.current_task())
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    async def _dispatch(self, conn: _Conn, header: Dict,
                        body: bytes) -> Tuple[Dict, bytes]:
        op = header.get("op")
        if op == "SUBMIT":
            return self._op_submit(conn, header, body)
        if op == "POLL":
            return await self._op_poll(conn, header)
        if op == "HEAD":
            return self._op_head(header)
        if op == "STATS":
            return self._op_stats()
        if op == "FLUSH":
            served, err = self._safe_call(self.server.flush)
            self._resolve()
            self._sync_flush_timer()
            if err is not None:
                return {"op": "ERR", "code": "flush_failed",
                        "error": err}, b""
            return {"op": "OK", "served": served}, b""
        if op == "ADVANCE":
            # flush=false models a window boundary firing while requests
            # are still queued: they become stragglers, recomputed against
            # their stamped snapshot at the next flush
            _, err = self._safe_call(lambda: self.server.advance_window(
                flush=bool(header.get("flush", True))))
            self._resolve()
            self._sync_flush_timer()
            if err is not None:
                return {"op": "ERR", "code": "flush_failed",
                        "error": err}, b""
            return {"op": "OK", "window": self.server.window}, b""
        return {"op": "ERR", "code": "unknown_op",
                "error": f"unknown op {op!r}"}, b""

    def _subset_refusal(self, header: Dict) -> Optional[Tuple[Dict, bytes]]:
        """Typed ERR for pre-subset clients against a subset server: a head
        body would be a *partial* pytree — a client that has not declared
        ``subset_ok`` would silently treat it as the full model."""
        if self._subset_desc is not None and not header.get("subset_ok"):
            return {"op": "ERR", "code": "subset_unsupported",
                    "error": "server personalizes a param subset "
                             f"(subset={self._subset_desc}); declare "
                             "subset_ok and merge heads with "
                             "repro.core.merge_subset"}, b""
        return None

    def _op_submit(self, conn: _Conn, header: Dict,
                   body: bytes) -> Tuple[Dict, bytes]:
        refusal = self._subset_refusal(header)
        if refusal is not None:
            return refusal
        user = header["user"]
        mode = header.get("mode", "C")
        busy_scope = None
        if self._inflight >= self.max_inflight:
            busy_scope = "server"
        elif len(conn.records) >= self.conn_inflight:
            busy_scope = "connection"
        else:
            cap = self.server.ring.user_cap
            if cap is not None:
                # the user's consumed window budget = rows the ring
                # already admitted + submissions queued on ANY connection
                # (one user may fan out over several) — refusing here is
                # cheaper than burning a queue slot and a cohort row on a
                # request the ring would refuse as "capped"
                window = self.server.window
                used = self.server.ring.admitted_rows(user)
                for cn in self._conns:
                    used += sum(1 for r in cn.records.values()
                                if r.user == user
                                and r.ticket.status == "queued"
                                and r.ticket.stamp == window)
                if used >= cap:
                    busy_scope = "user"
        if busy_scope is not None:
            self.stats["busy"] += 1
            return {"op": "BUSY", "scope": busy_scope,
                    "open": self._inflight}, b""
        if mode not in self.server.engines:
            return {"op": "ERR", "code": "bad_mode",
                    "error": f"mode {mode!r} not enabled; "
                             f"have {sorted(self.server.engines)}"}, b""
        # decode BEFORE the flush-capable submit: an undecodable body is a
        # bad frame from this one client — nothing was queued or drained,
        # so it must not be treated as a poisoned flush
        try:
            batch = decode_pytree(body)
        except Exception as e:      # noqa: BLE001 — remote input boundary
            return {"op": "ERR", "code": "bad_request",
                    "error": f"undecodable npz body: {e}"}, b""
        try:
            ticket = self.server.submit(user, batch, mode=mode)
        except Exception as e:      # noqa: BLE001 — the submit may have
            # auto-flushed a full queue, and THIS request's batch may be
            # the poison: the drain is spent, so fail the queued tickets
            # and report the cause instead of killing the connection
            msg = f"{type(e).__name__}: {e}"
            self._fail_queued(msg)
            self._resolve()
            return {"op": "ERR", "code": "server_error", "error": msg}, b""
        tid = conn.next_tid
        conn.next_tid += 1
        codec = "int8" if (self.wire_codec == "int8"
                           and header.get("codec_ok")) else "fp32"
        conn.records[tid] = _Record(ticket, user, codec=codec)
        self._inflight += 1
        # a full queue already flushed inside submit; otherwise the
        # deadline timer guarantees the partial queue drains within
        # flush_ms
        self._sync_flush_timer()
        self._resolve()
        return {"op": "OK", "ticket": tid, "window": ticket.stamp}, b""

    async def _op_poll(self, conn: _Conn,
                       header: Dict) -> Tuple[Dict, bytes]:
        refusal = self._subset_refusal(header)
        if refusal is not None:
            return refusal
        tid = int(header["ticket"])
        rec = conn.records.get(tid)
        if rec is None:
            return {"op": "ERR", "code": "unknown_ticket",
                    "error": f"no open ticket {tid}"}, b""
        wait_ms = header.get("wait_ms")
        if wait_ms and rec.ticket.status == "queued":
            try:
                await asyncio.wait_for(rec.event.wait(),
                                       float(wait_ms) / 1e3)
            except asyncio.TimeoutError:
                pass
        status = rec.ticket.status
        if rec.failed is not None:
            # the ticket's batch died with a poisoned flush: terminal
            del conn.records[tid]
            self._inflight -= 1
            return {"op": "ERR", "code": "server_error",
                    "error": f"request lost to a failed flush "
                             f"({rec.failed}); re-submit"}, b""
        if status == "queued":
            return {"op": "OK", "status": "queued"}, b""
        # terminal either way: the backpressure slot frees NOW
        del conn.records[tid]
        self._inflight -= 1
        ok = {"op": "OK", "status": "done", "window": self.server.window,
              "codec": rec.codec}
        if self._subset_desc is not None:
            ok["subset"] = self._subset_desc
        if rec.encoded is not None:
            return ok, rec.encoded
        try:
            head = self.server.poll(rec.ticket)
        except RuntimeError as e:
            if status in ("dropped", "capped"):
                code = status
            elif rec.ticket.window >= 0 and rec.ticket.window < (
                    self.server.window - self.server.ring.windows + 1):
                code = "superseded"
            else:
                code = "evicted"
            return {"op": "ERR", "code": code, "error": str(e)}, b""
        return ok, encode_pytree(head, codec=rec.codec)

    def _op_head(self, header: Dict) -> Tuple[Dict, bytes]:
        refusal = self._subset_refusal(header)
        if refusal is not None:
            return refusal
        user = header["user"]
        try:
            head = self.server.head(user)
        except KeyError:
            return {"op": "ERR", "code": "unknown_user",
                    "error": f"no cached head for {user!r}"}, b""
        codec = "int8" if (self.wire_codec == "int8"
                           and header.get("codec_ok")) else "fp32"
        ok = {"op": "OK", "user": user, "codec": codec}
        if self._subset_desc is not None:
            ok["subset"] = self._subset_desc
        return ok, encode_pytree(head, codec=codec)

    def _op_stats(self) -> Tuple[Dict, bytes]:
        stats = _jsonable(self.server.stats)
        stats.update({f"transport_{k}": v
                      for k, v in _jsonable(self.stats).items()})
        stats["transport_inflight"] = self._inflight
        stats["window"] = self.server.window
        stats["wire_codec"] = self.wire_codec
        ok = {"op": "OK", "stats": stats}
        if self._subset_desc is not None:
            ok["subset"] = self._subset_desc
        return ok, b""


# ---------------------------------------------------------------------------
# clients
# ---------------------------------------------------------------------------

def _check_reply(header: Dict) -> Dict:
    op = header.get("op")
    if op == "BUSY":
        raise TransportBusy(header.get("scope", "server"),
                            int(header.get("open", -1)))
    if op == "ERR":
        raise TransportError(header.get("code", "error"),
                             header.get("error", ""))
    if op != "OK":
        raise ProtocolError(f"unexpected reply op {op!r}")
    return header


class TransportClient:
    """Blocking client library — what a second OS process uses.

    One RPC at a time per connection; every method is a single
    request/reply frame pair.  ``poll`` returns None while the ticket is
    queued and the head pytree once served; refusals raise
    :class:`TransportError` (``.code`` = dropped/capped/superseded/
    evicted) and backpressure raises :class:`TransportBusy`.

    Subset-aware: every request declares ``subset_ok``, and when the
    server personalizes a subset the served head is a *subset pytree* —
    ``last_subset`` holds the reply's leaf-path descriptor (None for
    full-model servers); merge with ``repro.core.merge_subset``.

    Codec-aware: ``codec="int8"`` declares ``codec_ok`` (accept int8 head
    bodies from a quantized-wire server) AND quantizes this client's own
    SUBMIT batch bodies; the default ``"fp32"`` client negotiates nothing
    and always receives fp32 bodies.  ``last_codec`` records each head
    reply's actual body codec.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 timeout: float = 30.0, codec: str = "fp32"):
        if codec not in WIRE_CODECS:
            raise ValueError(f"codec must be one of {WIRE_CODECS}, "
                             f"got {codec!r}")
        self.timeout = timeout
        self.codec = codec
        self.last_subset = None
        self.last_codec = None
        self._sock = socket.create_connection((host, port),
                                              timeout=timeout)
        _no_nagle(self._sock)

    def _recvn(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise ConnectionError("server closed the connection")
            buf.extend(chunk)
        return bytes(buf)

    def _rpc(self, header: Dict, body: bytes = b"",
             extra_wait_s: float = 0.0) -> Tuple[Dict, bytes]:
        self._sock.settimeout(self.timeout + extra_wait_s)
        self._sock.sendall(pack_frame(header, body))
        (n,) = _U32.unpack(self._recvn(4))
        if n < 4 or n > MAX_FRAME_BYTES:
            raise ProtocolError(f"frame length {n} out of bounds")
        rh, rb = split_frame(self._recvn(n))
        return _check_reply(rh), rb

    def submit(self, user, batch, mode: str = "C") -> int:
        h, _ = self._rpc({"op": "SUBMIT", "user": user, "mode": mode,
                          "subset_ok": True,
                          "codec_ok": self.codec == "int8"},
                         encode_pytree(batch, codec=self.codec))
        return int(h["ticket"])

    def poll(self, ticket: int, wait_ms: Optional[float] = None):
        header = {"op": "POLL", "ticket": int(ticket), "subset_ok": True}
        if wait_ms is not None:
            header["wait_ms"] = float(wait_ms)
        h, b = self._rpc(header,
                         extra_wait_s=(wait_ms or 0.0) / 1e3)
        if h["status"] != "done":
            return None
        self.last_subset = h.get("subset")
        self.last_codec = h.get("codec", "fp32")
        return decode_pytree(b)

    def head(self, user):
        h, b = self._rpc({"op": "HEAD", "user": user, "subset_ok": True,
                          "codec_ok": self.codec == "int8"})
        self.last_subset = h.get("subset")
        self.last_codec = h.get("codec", "fp32")
        return decode_pytree(b)

    def stats(self) -> Dict:
        h, _ = self._rpc({"op": "STATS"})
        return h["stats"]

    def flush(self) -> int:
        h, _ = self._rpc({"op": "FLUSH"})
        return int(h["served"])

    def advance(self, flush: bool = True) -> int:
        h, _ = self._rpc({"op": "ADVANCE", "flush": flush})
        return int(h["window"])

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "TransportClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class AsyncTransportClient:
    """Asyncio twin of :class:`TransportClient` — the load generator runs
    N of these concurrently on one event loop.  Subset- and codec-aware
    like the blocking client (``subset_ok`` declared, ``codec=`` opt-in,
    ``last_subset``/``last_codec`` recorded)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 codec: str = "fp32"):
        if codec not in WIRE_CODECS:
            raise ValueError(f"codec must be one of {WIRE_CODECS}, "
                             f"got {codec!r}")
        self.host = host
        self.port = port
        self.codec = codec
        self.last_subset = None
        self.last_codec = None
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None

    async def connect(self) -> "AsyncTransportClient":
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        _no_nagle(self._writer.get_extra_info("socket"))
        return self

    async def _rpc(self, header: Dict,
                   body: bytes = b"") -> Tuple[Dict, bytes]:
        self._writer.write(pack_frame(header, body))
        await self._writer.drain()
        frame = await read_frame(self._reader)
        if frame is None:
            raise ConnectionError("server closed the connection")
        rh, rb = frame
        return _check_reply(rh), rb

    async def submit(self, user, batch, mode: str = "C") -> int:
        h, _ = await self._rpc({"op": "SUBMIT", "user": user, "mode": mode,
                                "subset_ok": True,
                                "codec_ok": self.codec == "int8"},
                               encode_pytree(batch, codec=self.codec))
        return int(h["ticket"])

    async def poll(self, ticket: int, wait_ms: Optional[float] = None):
        header = {"op": "POLL", "ticket": int(ticket), "subset_ok": True}
        if wait_ms is not None:
            header["wait_ms"] = float(wait_ms)
        h, b = await self._rpc(header)
        if h["status"] != "done":
            return None
        self.last_subset = h.get("subset")
        self.last_codec = h.get("codec", "fp32")
        return decode_pytree(b)

    async def head(self, user):
        h, b = await self._rpc({"op": "HEAD", "user": user,
                                "subset_ok": True,
                                "codec_ok": self.codec == "int8"})
        self.last_subset = h.get("subset")
        self.last_codec = h.get("codec", "fp32")
        return decode_pytree(b)

    async def stats(self) -> Dict:
        h, _ = await self._rpc({"op": "STATS"})
        return h["stats"]

    async def flush(self) -> int:
        h, _ = await self._rpc({"op": "FLUSH"})
        return int(h["served"])

    async def advance(self, flush: bool = True) -> int:
        h, _ = await self._rpc({"op": "ADVANCE", "flush": flush})
        return int(h["window"])

    async def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass


# ---------------------------------------------------------------------------
# loopback selftest (the CI transport-smoke entry point)
# ---------------------------------------------------------------------------

def _selftest(n_clients: int, rounds: int) -> None:
    import jax
    import jax.numpy as jnp

    from repro.core import PersAFLConfig
    from repro.serving import PersonalizationServer

    d = 16
    rng = np.random.RandomState(0)

    def loss(p, b):
        logits = b["x"] @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(jax.nn.one_hot(b["y"], 4) * logp, -1))

    params = {"w": jnp.asarray(0.1 * rng.randn(d, 4).astype(np.float32)),
              "b": jnp.zeros((4,))}
    pcfg = PersAFLConfig(option="C", lam=20.0, inner_steps=5,
                         inner_eta=0.05, beta=0.5)
    batches = [{"x": rng.randn(8, d).astype(np.float32),
                "y": rng.randint(0, 4, 8).astype(np.int32)}
               for _ in range(n_clients)]

    async def run() -> Dict:
        psrv = PersonalizationServer(params, loss, pcfg, modes=("C",),
                                     max_pending=n_clients)
        ts = await TransportServer(psrv, flush_ms=20.0,
                                   max_inflight=4 * n_clients).start()

        async def one_client(u: int) -> None:
            c = await AsyncTransportClient("127.0.0.1", ts.port).connect()
            for _ in range(rounds):
                tid = await c.submit(f"user{u}", batches[u], mode="C")
                head = await c.poll(tid, wait_ms=30_000)
                assert head is not None, "poll timed out"
                assert all(np.all(np.isfinite(leaf))
                           for leaf in jax.tree.leaves(head))
            again = await c.head(f"user{u}")
            assert all(np.array_equal(a, b) for a, b in
                       zip(jax.tree.leaves(head), jax.tree.leaves(again)))
            await c.close()

        await asyncio.gather(*(one_client(u) for u in range(n_clients)))
        admin = await AsyncTransportClient("127.0.0.1", ts.port).connect()
        await admin.advance()
        stats = await admin.stats()
        await admin.close()
        await ts.stop()
        return stats

    stats = asyncio.run(run())
    assert stats["host_materializations"] == 0, stats
    assert stats["cached_heads"] == n_clients, stats
    print(f"transport_selftest,clients={n_clients},rounds={rounds},"
          f"frames={stats['transport_frames']},"
          f"timer_flushes={stats['transport_timer_flushes']},"
          f"host_materializations={stats['host_materializations']},ok",
          flush=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="loopback transport selftest (CI transport-smoke)")
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()
    # under ``python -m`` this file runs as __main__ while the package
    # __init__ imported it once already — delegate to the canonical
    # module instance so there is exactly one set of classes
    from repro.serving import transport as _canonical
    _canonical._selftest(args.clients, args.rounds)
