"""PersonalizationServer — submit/poll front-end over the cohort engine.

Request lifecycle::

    t = server.submit(user, batch, mode="C")   # queued, stamped w/ window
    server.flush()                             # micro-batch -> cohort call
    head = server.poll(t)                      # device-resident head pytree
    ...
    server.advance_window()                    # fold deltas into global w

``flush`` turns the queue into pow2-bucketed cohort calls (one per
(mode, window-stamp) group), computes a stacked *head bank*
``heads = w_stamp − delta_stack`` in one jitted pass, admits every real row
into the :class:`repro.serving.bank.DeltaRing`, and caches per-user head
handles.  ``advance_window`` closes the aggregation window: admitted rows
(including stragglers re-weighted from earlier windows) are applied to the
global params with one fused ``apply_rows`` pass per bank.

Steady-state guarantee: submit → flush → poll/head → advance never moves a
tensor to the host — heads are device-side gathers from stacked head banks
and ``stats["host_materializations"]`` stays 0 (pinned by tests and the
``serve`` benchmark row).

Result handles are **per ticket**: every "done" ticket owns the
(heads bank, row) pair its flush produced, so polling an older ticket
after a newer flush returns that ticket's head — never silently the
newest one — and a ticket whose window has retired from the ring fails
explicitly as superseded-and-retired.

Partial-model personalization: construct with ``personal_subset=`` (any
``repro.core.SubsetSpec`` spelling, e.g. ``("fc/#1",)``) and only the
personal leaves are banked — delta rows, head rows, ring snapshots and
the head cache all shrink to the subset while the shared backbone flows
once on the buffered path (``stats["ring_bytes_per_user"]`` reports the
per-user ring residency this buys; the ``partial`` bench gates it).
Served heads are subset pytrees; callers merge them over the global
backbone with ``repro.core.merge_subset``.

This surface is in-process; other processes reach it over the socket
front-end (:class:`repro.serving.transport.TransportServer` bridges
concurrent connections into submit/flush/poll with deadline-driven flush
timers and explicit backpressure — see that module for the wire protocol;
subset-serving servers require clients to declare ``subset_ok`` and stamp
replies with the subset descriptor).
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.store import load_meta, load_pytree, save_pytree
from repro.core import init_server_state, staleness_stats
from repro.core.subset import SubsetSpec
from repro.core.types import PersAFLConfig, ServerState
from repro.fl.engine import CohortEngine, DeltaBank
from repro.serving.bank import DeltaRing
from repro.serving.batcher import (MODES, MicroBatcher, Ticket,
                                   personalize_strategy)


def _own_copy(params):
    return jax.tree.map(lambda x: jnp.array(x), params)


class PersonalizationServer:
    """Live-traffic serving of personalized heads (Options B and C).

    Parameters
    ----------
    init_params : global model w (copied; the server owns its state)
    loss_fn     : (params, batch) -> scalar, the per-user objective f_i
    pcfg        : personalization hyper-params (α for mode B, λ/K/η_in for
                  mode C, β/damping for the window apply)
    cohort_impl : forwarded to :class:`CohortEngine` — ``"shard_map"``
                  splits user cohorts over the ``("cohort",)`` mesh and the
                  batcher keys users to shards
    windows     : ring depth W (banks + params snapshots retained)
    tau_max     : bounded-staleness admission (≤ W−1; default W−1)
    max_pending : auto-flush threshold for the request queue
    head_cache  : max cached per-user head handles (LRU)
    user_cap    : fairness bound — max delta rows one user may have
                  admitted into a single aggregation window (None = off)
    personal_subset : the personal param subset (SubsetSpec spelling);
                  None = full-model personalization

    Each mode's cohort engine is driven by the registry strategy
    ``repro.fl.api.strategy("personalize", mode=...)`` — the serving rules
    are plain Strategy citizens, not a ``client_fn`` special case.
    """

    def __init__(self, init_params, loss_fn: Callable,
                 pcfg: PersAFLConfig, *, cohort_impl: str = "auto",
                 modes: Iterable[str] = MODES, windows: int = 4,
                 tau_max: Optional[int] = None, max_pending: int = 64,
                 head_cache: int = 4096, user_cap: Optional[int] = None,
                 personal_subset=None):
        self.pcfg = pcfg
        self.loss_fn = loss_fn
        self.state = init_server_state(_own_copy(init_params))
        self.max_pending = max_pending
        self.head_cache = head_cache
        self.personal_subset = SubsetSpec.resolve(personal_subset,
                                                 self.state.params)

        engines: Dict[str, CohortEngine] = {}
        shared_stats = None
        for mode in modes:
            eng = CohortEngine(
                pcfg, loss_fn, cohort_impl=cohort_impl,
                strategy=personalize_strategy(
                    pcfg, loss_fn, mode,
                    personal_subset=self.personal_subset))
            if shared_stats is None:
                shared_stats = eng.stats
            else:
                eng.stats = shared_stats  # one counter set across modes
            engines[mode] = eng
        if not engines:
            raise ValueError("need at least one personalization mode")
        self.engines = engines
        self._engine_stats = shared_stats

        self.ring = DeltaRing(self.state.params, windows=windows,
                              tau_max=tau_max, user_cap=user_cap,
                              subset=self.personal_subset)
        for eng in engines.values():
            eng.add_bank_hook(self.ring.retain)   # bank handoff
        n_shards = max(eng._ndev for eng in engines.values())
        self.batcher = MicroBatcher(engines, n_shards=n_shards,
                                    user_cap=user_cap)

        # user -> (head DeltaBank, row): device-resident, LRU-evicted
        self._heads: "collections.OrderedDict" = collections.OrderedDict()
        # one compile per (stacked-shape); reused every flush
        self._jit_heads = jax.jit(lambda p, s: jax.tree.map(
            lambda pp, ss: (pp[None].astype(jnp.float32) - ss).astype(
                pp.dtype), p, s))

    # -- request path ------------------------------------------------------

    @property
    def params(self):
        """The current global model w (post last window apply)."""
        return self.state.params

    @property
    def window(self) -> int:
        return self.ring.current

    def submit(self, user, batch, mode: str = "C") -> Ticket:
        """Queue one personalization request; stamps the current window."""
        ticket = self.batcher.submit(
            Ticket(user=user, mode=mode, stamp=self.ring.current), batch)
        if len(self.batcher) >= self.max_pending:
            self.flush()
        return ticket

    def flush(self) -> int:
        """Drain the queue into cohort calls; returns #requests served.

        Per (mode, stamp) group: ONE cohort call against the stamped
        snapshot, ONE jitted stacked-head computation, then per-row ring
        admission + head-cache insertion (all device handles, no
        transfers).
        """
        served = 0
        for mode, stamp, bank, placed in self.batcher.drain(
                self.ring.current, self.ring.snapshot,
                tau_max=self.ring.tau_max):
            # subset mode: the delta stack is subset-shaped, so the head
            # subtraction runs against the snapshot's stored subset tree
            # (same pruned structure) — heads are subset pytrees
            heads = DeltaBank(
                stacked=self._jit_heads(self.ring.subset_snapshot(stamp),
                                        bank.stacked),
                k=bank.k, stats=self._engine_stats)
            self.ring.retain(heads)   # head rows live as long as the bank
            for ticket, row in placed:
                # the ring is the admission authority: the batcher's drain
                # bound normally pre-filters, but a refusal here must not
                # serve a head whose delta never reached the global apply.
                # The refusal CAUSE must survive to poll: a fairness-cap
                # refusal is "capped" (re-submit next window), never
                # "dropped" (which poll reports as a tau_max violation)
                verdict = self.ring.admit_row(ticket.user, bank, row,
                                              ticket.tau)
                if verdict != "admitted":
                    ticket.status = verdict
                    continue
                self._cache_head(ticket.user, heads, row)
                # the ticket owns its result: poll resolves THIS handle,
                # not whatever head the user's latest flush produced
                ticket.head = (heads, row)
                ticket.window = self.ring.current
                ticket.status = "done"
                served += 1
        return served

    def poll(self, ticket: Ticket):
        """None while queued; THIS ticket's head pytree once served.

        The head comes from the ticket's own (bank, row) handle — polling
        an older ticket after a newer flush for the same user returns the
        older head, it is never silently aliased to the newest one.  Raises
        on dropped tickets (staleness bound exceeded), capped tickets
        (fairness), and superseded-and-retired tickets (the ticket's ring
        window rotated out: its bank is gone) — all mean the user must
        re-submit against a fresh snapshot.
        """
        if ticket.status == "queued":
            return None
        if ticket.status == "dropped":
            raise RuntimeError(
                f"request for {ticket.user!r} exceeded tau_max="
                f"{self.ring.tau_max} (tau={ticket.tau}); re-submit")
        if ticket.status == "capped":
            raise RuntimeError(
                f"request for {ticket.user!r} exceeded the per-window "
                f"fairness cap (user_cap={self.batcher.user_cap}); "
                f"re-submit next window")
        if ticket.head is None:
            # handle-less done ticket (constructed by hand / pre-restart):
            # the cache is the only resolver left
            if ticket.user not in self._heads:
                raise RuntimeError(
                    f"head for {ticket.user!r} was evicted from the cache "
                    f"(head_cache={self.head_cache}); re-submit")
            return self.head(ticket.user)
        horizon = self.ring.current - self.ring.windows + 1
        if ticket.window < horizon:
            ticket.head = None   # the bank is gone; drop our pin on it
            raise RuntimeError(
                f"ticket for {ticket.user!r} was superseded and retired: "
                f"served in window {ticket.window}, ring horizon is "
                f"{horizon} (windows={self.ring.windows}); re-submit")
        heads, row = ticket.head
        return jax.tree.map(lambda x: x[row], heads.stacked)

    def _cache_head(self, user, heads: DeltaBank, row: int) -> None:
        self._heads[user] = (heads, row)
        self._heads.move_to_end(user)
        while len(self._heads) > self.head_cache:
            self._heads.popitem(last=False)

    def head(self, user):
        """The user's personalized head — a device-side row gather from the
        stacked head bank (never a host materialization)."""
        heads, row = self._heads[user]
        self._heads.move_to_end(user)
        return jax.tree.map(lambda x: x[row], heads.stacked)

    def stacked_heads(self, users: List):
        """``[len(users), ...]`` stacked heads (batched decode input).

        One ``jnp.take`` gather when every user sits in the same head bank
        (the steady-state micro-batch case), row-stack fallback otherwise.
        """
        handles = [self._heads[u] for u in users]
        first = handles[0][0]
        if all(h is first for h, _ in handles):
            rows = jnp.asarray([r for _, r in handles], jnp.int32)
            return jax.tree.map(lambda x: jnp.take(x, rows, axis=0),
                                first.stacked)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[self.head(u) for u in users])

    # -- window boundary ---------------------------------------------------

    def advance_window(self, *, flush: bool = True) -> None:
        """Close the aggregation window: every admitted delta row
        (stragglers included, re-weighted by ``admission_weights``) is
        folded into the global params and the ring rotates.

        ``flush=False`` models a timer-driven boundary firing while
        requests are still queued — those requests become stragglers: the
        next flush computes them against their *stamped* (retained)
        snapshot and admits them into the new window's weight vector.
        """
        if flush:
            self.flush()
        self.state = self.ring.advance(self.state, beta=self.pcfg.beta,
                                       damping=self.pcfg.staleness_damping)

    # -- restart warm-start ------------------------------------------------

    def save(self, path: str) -> None:
        """Checkpoint the serving state through ``repro.checkpoint.store``:
        the typed ServerState, the ring's retained params snapshots +
        window counter + cumulative admission stats, and the head cache as
        ONE stacked head bank.

        A restart restored from this no longer rebuilds the ring empty —
        users keep their cached heads and straggler *requests* stamped
        before the restart still find their snapshots.  In-flight delta
        rows (unapplied bank admissions) are the one thing lost; affected
        users re-personalize against the restored snapshots.
        """
        users = list(self._heads)
        tree = {
            "server_state": self.state.as_dict(),
            "ring_snapshots": {f"w{w}": snap
                               for w, snap in self.ring._snapshots.items()},
            "head_stack": self.stacked_heads(users) if users else None,
        }
        # tau_max persists as REQUESTED, not as clamped to this ring's
        # depth: restoring into a deeper ring must widen back to the
        # request (the clamp is a property of the ring, not of the config)
        meta = {"users": users, "ring_current": self.ring.current,
                "windows": self.ring.windows,
                "tau_max": self.ring.tau_max_requested,
                "user_cap": self.ring.user_cap,
                "personal_subset":
                    self.personal_subset.descriptor(self.state.params)
                    if self.personal_subset is not None else None,
                "ring_stats": {k: int(v)
                               for k, v in self.ring.stats.items()}}
        save_pytree(path, tree, meta=meta)

    @classmethod
    def restore(cls, path: str, loss_fn: Callable, pcfg: PersAFLConfig,
                **kw) -> "PersonalizationServer":
        """Rebuild a server from :meth:`save`'s checkpoint (warm start).

        Ring depth / staleness bound / fairness cap / personal subset come
        from the checkpoint, but any of them may be overridden through
        ``**kw`` (e.g. restore into a deeper ring with ``windows=8`` — the
        checkpointed *requested* ``tau_max`` then re-clamps against the new
        depth, not the old one).  ``**kw`` otherwise forwards the
        process-local knobs (``cohort_impl``, ``modes``, ``max_pending``,
        ``head_cache``).  Head-cache users must be JSON-serializable keys
        (strings in practice) — they round-trip through the sidecar meta.
        """
        tree = load_pytree(path)
        meta = load_meta(path)
        state = ServerState.from_dict(
            jax.tree.map(jnp.asarray, tree["server_state"]))
        windows = kw.pop("windows", meta["windows"])
        tau_max = kw.pop("tau_max", meta.get("tau_max"))
        user_cap = kw.pop("user_cap", meta.get("user_cap"))
        subset = kw.pop("personal_subset", meta.get("personal_subset"))
        srv = cls(state.params, loss_fn, pcfg, windows=windows,
                  tau_max=tau_max, user_cap=user_cap,
                  personal_subset=subset, **kw)
        srv.state = state
        snapshots = {int(k[1:]): jax.tree.map(jnp.asarray, snap)
                     for k, snap in tree["ring_snapshots"].items()}
        srv.ring.load(snapshots, meta["ring_current"],
                      stats=meta.get("ring_stats"))
        users = meta["users"]
        if users:
            heads = DeltaBank(
                stacked=jax.tree.map(jnp.asarray, tree["head_stack"]),
                k=len(users), stats=srv._engine_stats)
            srv.ring.retain(heads)  # device residency across windows
            for row, user in enumerate(users):
                srv._cache_head(user, heads, row)
        return srv

    # -- observability -----------------------------------------------------

    @property
    def stats(self) -> Dict:
        s = dict(self._engine_stats)
        s.update({f"ring_{k}": v for k, v in self.ring.stats.items()})
        s.update({f"batcher_{k}": v for k, v in self.batcher.stats.items()})
        s["live_banks"] = self.ring.live_banks
        s["cached_heads"] = len(self._heads)
        # per-user steady-state ring residency: one delta row + one head
        # row per served user per window (both row-shaped, so 2x the bank
        # row bytes) — the number the partial-personalization bench gates
        row = self.ring.row_nbytes or 0
        s["ring_row_bytes"] = row
        s["ring_bytes_per_user"] = 2 * row
        return s

    def staleness(self) -> Dict:
        return staleness_stats(self.state)
