"""PersonalizationServer — submit/poll front-end over the cohort engine.

Request lifecycle::

    t = server.submit(user, batch, mode="C")   # queued, stamped w/ window
    server.flush()                             # micro-batch -> cohort call
    head = server.poll(t)                      # device-resident head pytree
    ...
    server.advance_window()                    # fold deltas into global w

``flush`` turns the queue into pow2-bucketed cohort calls (one per
(mode, window-stamp) group), computes a stacked *head bank*
``heads = w_stamp − delta_stack`` in one jitted pass, admits every real row
into the :class:`repro.serving.bank.DeltaRing`, and caches per-user head
handles.  ``advance_window`` closes the aggregation window: admitted rows
(including stragglers re-weighted from earlier windows) are applied to the
global params with one fused ``apply_rows`` pass per bank.

Steady-state guarantee: submit → flush → poll/head → advance never moves a
tensor to the host — heads are device-side gathers from stacked head banks
and ``stats["host_materializations"]`` stays 0 (pinned by tests and the
``serve`` benchmark row).

Result handles are **per ticket**: every "done" ticket owns the
(heads bank, row) pair its flush produced, so polling an older ticket
after a newer flush returns that ticket's head — never silently the
newest one — and a ticket whose window has retired from the ring fails
explicitly as superseded-and-retired.

Partial-model personalization: construct with ``personal_subset=`` (any
``repro.core.SubsetSpec`` spelling, e.g. ``("fc/#1",)``) and only the
personal leaves are banked — delta rows, head rows, ring snapshots and
the head cache all shrink to the subset while the shared backbone flows
once on the buffered path (``stats["ring_bytes_per_user"]`` reports the
per-user ring residency this buys; the ``partial`` bench gates it).
Served heads are subset pytrees; callers merge them over the global
backbone with ``repro.core.merge_subset``.

Quantized delta banking: construct with ``delta_dtype="int8"`` and every
flush quantizes its cohort's delta stack to int8 rows + per-row-per-leaf
f32 scales (symmetric absmax) with **error feedback** — each user's
quantization error is banked as an int8 residual and added to that user's
next delta before re-quantizing, so banking noise stays a bounded residual
instead of a bias.  Heads become *lazy*: no fp32 head bank is stored at
all; ``poll``/``head`` gather ``snapshot − scale·q`` on device
(:class:`repro.core.quant.QuantizedHeads`), the window apply dispatches
the :class:`repro.core.quant.QuantStack` through the fused
``apply_rows_q`` kernel, and per-user ring residency drops ~4x
(``stats["ring_bytes_per_user"]`` vs ``ring_bytes_per_user_fp32``; the
``quant`` bench gates ≥ 3.5x at equal convergence).

This surface is in-process; other processes reach it over the socket
front-end (:class:`repro.serving.transport.TransportServer` bridges
concurrent connections into submit/flush/poll with deadline-driven flush
timers and explicit backpressure — see that module for the wire protocol;
subset-serving servers require clients to declare ``subset_ok`` and stamp
replies with the subset descriptor, and int8 bodies are sent only to
clients that negotiated the ``codec``).
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.store import load_meta, load_pytree, save_pytree
from repro.core import init_server_state, staleness_stats
from repro.core.quant import (QuantStack, QuantTree, QuantizedBank,
                              QuantizedHeads, ef_quantize_stack)
from repro.core.subset import SubsetSpec
from repro.core.types import PersAFLConfig, ServerState
from repro.fl.engine import CohortEngine, DeltaBank
from repro.serving.bank import DeltaRing
from repro.serving.batcher import (MODES, MicroBatcher, Ticket,
                                   personalize_strategy)


def _own_copy(params):
    return jax.tree.map(lambda x: jnp.array(x), params)


def _row_of(handle, row: int):
    """One head row from a ticket/cache handle: device-side either way —
    an eager gather for fp32 head banks, a fused dequantizing gather for
    lazy :class:`QuantizedHeads` views."""
    if isinstance(handle, QuantizedHeads):
        return handle.row(row)
    return jax.tree.map(lambda x: x[row], handle.stacked)


def _rows_of(handle, rows):
    if isinstance(handle, QuantizedHeads):
        return handle.rows(rows)
    return jax.tree.map(lambda x: jnp.take(x, rows, axis=0),
                        handle.stacked)


class PersonalizationServer:
    """Live-traffic serving of personalized heads (Options B and C).

    Parameters
    ----------
    init_params : global model w (copied; the server owns its state)
    loss_fn     : (params, batch) -> scalar, the per-user objective f_i
    pcfg        : personalization hyper-params (α for mode B, λ/K/η_in for
                  mode C, β/damping for the window apply)
    cohort_impl : forwarded to :class:`CohortEngine` — ``"shard_map"``
                  splits user cohorts over the mesh's "cohort" axis and
                  the batcher keys users to cohort slices
    mesh        : optional explicit mesh for the shard_map engines — a 1-D
                  ``("cohort",)`` mesh or a 2-D ``("cohort", "model")``
                  mesh from :func:`repro.sharding.ctx.cohort_model_mesh`;
                  defaults to the ambient :func:`use_mesh` context, else
                  the memoized 1-D cohort mesh
    param_shardings : optional pytree of ``NamedSharding`` matching the
                  params — placement constraint for the model axis of a
                  2-D mesh; forwarded to every mode's engine, and the
                  server's own params/snapshots are device_put to it so
                  delta banks, head rows and ring snapshots inherit
                  model-axis sharding (gather-not-transfer serving)
    windows     : ring depth W (banks + params snapshots retained)
    tau_max     : bounded-staleness admission (≤ W−1; default W−1)
    max_pending : auto-flush threshold for the request queue
    head_cache  : max cached per-user head handles (LRU)
    user_cap    : fairness bound — max delta rows one user may have
                  admitted into a single aggregation window (None = off)
    personal_subset : the personal param subset (SubsetSpec spelling);
                  None = full-model personalization
    delta_dtype : ``"fp32"`` (exact banking) or ``"int8"`` (quantized
                  banking with per-user error feedback; see the module
                  docstring)
    robust      : Byzantine-robust window apply — ``None`` (plain),
                  ``"clip"`` (per-row norm clipping) or ``"trim"``
                  (norm-trimmed mean); forwarded to the ring together
                  with ``clip_norm``/``trim_frac`` (see
                  :func:`repro.core.robust_admission_weights`)

    Each mode's cohort engine is driven by the registry strategy
    ``repro.fl.api.strategy("personalize", mode=...)`` — the serving rules
    are plain Strategy citizens, not a ``client_fn`` special case.
    """

    def __init__(self, init_params, loss_fn: Callable,
                 pcfg: PersAFLConfig, *, cohort_impl: str = "auto",
                 modes: Iterable[str] = MODES, windows: int = 4,
                 tau_max: Optional[int] = None, max_pending: int = 64,
                 head_cache: int = 4096, user_cap: Optional[int] = None,
                 personal_subset=None, delta_dtype: str = "fp32",
                 robust: Optional[str] = None,
                 clip_norm: Optional[float] = None,
                 trim_frac: float = 0.1,
                 mesh=None, param_shardings=None):
        self.pcfg = pcfg
        self.loss_fn = loss_fn
        params0 = _own_copy(init_params)
        if param_shardings is not None:
            # model-axis placement up front: every downstream artifact
            # (snapshots, delta banks, head rows) derives its sharding
            # from the params it was computed against
            params0 = jax.device_put(params0, param_shardings)
        self.state = init_server_state(params0)
        self.max_pending = max_pending
        self.head_cache = head_cache
        self.delta_dtype = delta_dtype
        self.personal_subset = SubsetSpec.resolve(personal_subset,
                                                 self.state.params)

        engines: Dict[str, CohortEngine] = {}
        shared_stats = None
        for mode in modes:
            eng = CohortEngine(
                pcfg, loss_fn, cohort_impl=cohort_impl,
                mesh=mesh, param_shardings=param_shardings,
                strategy=personalize_strategy(
                    pcfg, loss_fn, mode,
                    personal_subset=self.personal_subset))
            if shared_stats is None:
                shared_stats = eng.stats
            else:
                eng.stats = shared_stats  # one counter set across modes
            engines[mode] = eng
        if not engines:
            raise ValueError("need at least one personalization mode")
        self.engines = engines
        self._engine_stats = shared_stats

        self.ring = DeltaRing(self.state.params, windows=windows,
                              tau_max=tau_max, user_cap=user_cap,
                              subset=self.personal_subset,
                              delta_dtype=delta_dtype, robust=robust,
                              clip_norm=clip_norm, trim_frac=trim_frac)
        if delta_dtype == "fp32":
            for eng in engines.values():
                eng.add_bank_hook(self.ring.retain)   # bank handoff
        # int8 banking: the raw fp32 cohort bank must NOT be pinned — the
        # flush quantizes it (with the per-user EF residual folded in) and
        # retains only the QuantizedBank, so the fp32 stack is transient
        n_shards = max(eng._ndev for eng in engines.values())
        self.batcher = MicroBatcher(engines, n_shards=n_shards,
                                    user_cap=user_cap)

        # user -> (head DeltaBank, row): device-resident, LRU-evicted
        self._heads: "collections.OrderedDict" = collections.OrderedDict()
        # user -> (residual QuantizedBank, row): the quantization error of
        # the user's last banked delta, added to their next delta before
        # re-quantizing (error feedback); LRU-evicted like the head cache
        self._residuals: "collections.OrderedDict" = \
            collections.OrderedDict()
        # one compile per (stacked-shape); reused every flush
        self._jit_heads = jax.jit(lambda p, s: jax.tree.map(
            lambda pp, ss: (pp[None].astype(jnp.float32) - ss).astype(
                pp.dtype), p, s))

    # -- request path ------------------------------------------------------

    @property
    def params(self):
        """The current global model w (post last window apply)."""
        return self.state.params

    @property
    def window(self) -> int:
        return self.ring.current

    def submit(self, user, batch, mode: str = "C") -> Ticket:
        """Queue one personalization request; stamps the current window."""
        ticket = self.batcher.submit(
            Ticket(user=user, mode=mode, stamp=self.ring.current), batch)
        if len(self.batcher) >= self.max_pending:
            self.flush()
        return ticket

    def flush(self) -> int:
        """Drain the queue into cohort calls; returns #requests served.

        Per (mode, stamp) group: ONE cohort call against the stamped
        snapshot, ONE jitted stacked-head computation, then per-row ring
        admission + head-cache insertion (all device handles, no
        transfers).
        """
        served = 0
        for mode, stamp, bank, placed in self.batcher.drain(
                self.ring.current, self.ring.snapshot,
                tau_max=self.ring.tau_max):
            resbank = None
            if self.delta_dtype == "int8":
                # quantize the cohort's fp32 delta stack (adding each
                # user's banked EF residual first) and pin ONLY the int8
                # bank; heads become a lazy snapshot − scale·q view over
                # it — no fp32 head bank is ever stored
                bank, resbank = self._quantize_bank(bank, placed)
                self.ring.retain(bank)
                heads = QuantizedHeads(self.ring.subset_snapshot(stamp),
                                       bank)
            else:
                # subset mode: the delta stack is subset-shaped, so the
                # head subtraction runs against the snapshot's stored
                # subset tree (same pruned structure) — heads are subset
                # pytrees
                heads = DeltaBank(
                    stacked=self._jit_heads(
                        self.ring.subset_snapshot(stamp), bank.stacked),
                    k=bank.k, stats=self._engine_stats)
                self.ring.retain(heads)  # head rows live with the bank
            for ticket, row in placed:
                # the ring is the admission authority: the batcher's drain
                # bound normally pre-filters, but a refusal here must not
                # serve a head whose delta never reached the global apply.
                # The refusal CAUSE must survive to poll: a fairness-cap
                # refusal is "capped" (re-submit next window), never
                # "dropped" (which poll reports as a tau_max violation)
                verdict = self.ring.admit_row(ticket.user, bank, row,
                                              ticket.tau)
                if verdict != "admitted":
                    ticket.status = verdict
                    continue
                self._cache_head(ticket.user, heads, row)
                if resbank is not None:
                    # the NEW residual (this row's quantization error)
                    # replaces the user's banked one — consumed-and-
                    # replaced is exactly the EF recurrence.  Refused rows
                    # never apply, so their user keeps the old residual.
                    self._cache_residual(ticket.user, resbank, row)
                # the ticket owns its result: poll resolves THIS handle,
                # not whatever head the user's latest flush produced
                ticket.head = (heads, row)
                ticket.window = self.ring.current
                ticket.status = "done"
                served += 1
        return served

    # -- quantized banking (error feedback) --------------------------------

    def _quantize_bank(self, bank: DeltaBank, placed):
        """int8-quantize a cohort's delta stack with error feedback.

        Each placed user's banked residual (the quantization error of
        their previous delta) is added to their row before re-quantizing;
        the new per-row error comes back as an int8 residual bank whose
        rows replace the users' entries after admission.  Returns
        ``(delta QuantizedBank, residual QuantizedBank)``.
        """
        residual = self._residual_stack(bank.stacked, placed)
        qstack, resstack = ef_quantize_stack(bank.stacked, residual)
        qbank = QuantizedBank(qstack, k=bank.k, stats=self._engine_stats)
        resbank = QuantizedBank(resstack, k=bank.k,
                                stats=self._engine_stats)
        return qbank, resbank

    def _residual_stack(self, raw, placed):
        """fp32 residual stack row-aligned with ``raw`` (None if no placed
        user has a banked residual).  One dequantizing gather per source
        residual bank; a user appearing twice in one cohort gets their
        residual credited once (first row) — crediting both rows would
        double-apply the error."""
        seen = set()
        groups: Dict[int, list] = {}
        for ticket, row in placed:
            user = ticket.user
            if user in seen or user not in self._residuals:
                continue
            seen.add(user)
            src_bank, src_row = self._residuals[user]
            groups.setdefault(id(src_bank), [src_bank, [], []])
            groups[id(src_bank)][1].append(src_row)
            groups[id(src_bank)][2].append(row)
        if not groups:
            return None
        out = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), raw)
        for src_bank, src_rows, dst_rows in groups.values():
            vals = src_bank.rows(jnp.asarray(src_rows, jnp.int32))
            dst = jnp.asarray(dst_rows, jnp.int32)
            out = jax.tree.map(
                lambda o, v: o.at[dst].set(v.astype(jnp.float32)),
                out, vals)
        return out

    def _cache_residual(self, user, resbank: QuantizedBank,
                        row: int) -> None:
        self._residuals[user] = (resbank, row)
        self._residuals.move_to_end(user)
        while len(self._residuals) > self.head_cache:
            self._residuals.popitem(last=False)

    def poll(self, ticket: Ticket):
        """None while queued; THIS ticket's head pytree once served.

        The head comes from the ticket's own (bank, row) handle — polling
        an older ticket after a newer flush for the same user returns the
        older head, it is never silently aliased to the newest one.  Raises
        on dropped tickets (staleness bound exceeded), capped tickets
        (fairness), and superseded-and-retired tickets (the ticket's ring
        window rotated out: its bank is gone) — all mean the user must
        re-submit against a fresh snapshot.
        """
        if ticket.status == "queued":
            return None
        if ticket.status == "dropped":
            raise RuntimeError(
                f"request for {ticket.user!r} exceeded tau_max="
                f"{self.ring.tau_max} (tau={ticket.tau}); re-submit")
        if ticket.status == "capped":
            raise RuntimeError(
                f"request for {ticket.user!r} exceeded the per-window "
                f"fairness cap (user_cap={self.batcher.user_cap}); "
                f"re-submit next window")
        if ticket.head is None:
            # handle-less done ticket (constructed by hand / pre-restart):
            # the cache is the only resolver left
            if ticket.user not in self._heads:
                raise RuntimeError(
                    f"head for {ticket.user!r} was evicted from the cache "
                    f"(head_cache={self.head_cache}); re-submit")
            return self.head(ticket.user)
        horizon = self.ring.current - self.ring.windows + 1
        if ticket.window < horizon:
            ticket.head = None   # the bank is gone; drop our pin on it
            raise RuntimeError(
                f"ticket for {ticket.user!r} was superseded and retired: "
                f"served in window {ticket.window}, ring horizon is "
                f"{horizon} (windows={self.ring.windows}); re-submit")
        heads, row = ticket.head
        return _row_of(heads, row)

    def _cache_head(self, user, heads: DeltaBank, row: int) -> None:
        self._heads[user] = (heads, row)
        self._heads.move_to_end(user)
        while len(self._heads) > self.head_cache:
            self._heads.popitem(last=False)

    def head(self, user):
        """The user's personalized head — a device-side row gather from the
        stacked head bank (never a host materialization)."""
        heads, row = self._heads[user]
        self._heads.move_to_end(user)
        return _row_of(heads, row)

    def stacked_heads(self, users: List):
        """``[len(users), ...]`` stacked heads (batched decode input).

        One ``jnp.take`` gather when every user sits in the same head bank
        (the steady-state micro-batch case), row-stack fallback otherwise.
        """
        handles = [self._heads[u] for u in users]
        first = handles[0][0]
        if all(h is first for h, _ in handles):
            rows = jnp.asarray([r for _, r in handles], jnp.int32)
            return _rows_of(first, rows)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[self.head(u) for u in users])

    # -- window boundary ---------------------------------------------------

    def advance_window(self, *, flush: bool = True) -> None:
        """Close the aggregation window: every admitted delta row
        (stragglers included, re-weighted by ``admission_weights``) is
        folded into the global params and the ring rotates.

        ``flush=False`` models a timer-driven boundary firing while
        requests are still queued — those requests become stragglers: the
        next flush computes them against their *stamped* (retained)
        snapshot and admits them into the new window's weight vector.
        """
        if flush:
            self.flush()
        self.state = self.ring.advance(self.state, beta=self.pcfg.beta,
                                       damping=self.pcfg.staleness_damping)

    # -- restart warm-start ------------------------------------------------

    @staticmethod
    def _ckpt_snap(snap):
        """NamedTuples flatten as anonymous tuples in the checkpoint
        layout, so an int8-demoted snapshot is stored as an explicit
        marker dict — ``{"__q8__": q, "__q8s__": scales}`` — and re-typed
        on restore.  Bit-exact: the int8 codes and f32 scales round-trip
        untouched."""
        if isinstance(snap, QuantTree):
            return {"__q8__": snap.q, "__q8s__": snap.scales}
        return snap

    @staticmethod
    def _unckpt_snap(snap):
        if isinstance(snap, dict) and set(snap) == {"__q8__", "__q8s__"}:
            return QuantTree(
                q=jax.tree.map(jnp.asarray, snap["__q8__"]),
                scales=jax.tree.map(jnp.asarray, snap["__q8s__"]))
        return jax.tree.map(jnp.asarray, snap)

    def _gathered_residuals(self):
        """(stacked residual QuantStack, users) — one row per cached user,
        gathered from the source residual banks WITHOUT dequantizing (the
        int8 codes themselves persist, so save→restore is bit-exact)."""
        users = list(self._residuals)
        if not users:
            return None, []
        groups: Dict[int, list] = {}
        for i, user in enumerate(users):
            src_bank, src_row = self._residuals[user]
            groups.setdefault(id(src_bank), [src_bank, [], []])
            groups[id(src_bank)][1].append(src_row)
            groups[id(src_bank)][2].append(i)
        template = next(iter(groups.values()))[0].stacked
        n = len(users)
        q = jax.tree.map(
            lambda x: jnp.zeros((n,) + x.shape[1:], x.dtype), template.q)
        s = jax.tree.map(
            lambda x: jnp.zeros((n,) + x.shape[1:], x.dtype),
            template.scales)
        for src_bank, src_rows, dst_rows in groups.values():
            src = jnp.asarray(src_rows, jnp.int32)
            dst = jnp.asarray(dst_rows, jnp.int32)
            q = jax.tree.map(
                lambda o, x: o.at[dst].set(jnp.take(x, src, axis=0)),
                q, src_bank.stacked.q)
            s = jax.tree.map(
                lambda o, x: o.at[dst].set(jnp.take(x, src, axis=0)),
                s, src_bank.stacked.scales)
        return QuantStack(q=q, scales=s), users

    def save(self, path: str) -> None:
        """Checkpoint the serving state through ``repro.checkpoint.store``:
        the typed ServerState, the ring's retained params snapshots +
        window counter + cumulative admission stats, and the head cache as
        ONE stacked head bank.  Under int8 banking the demoted snapshots
        and the per-user EF residuals persist *quantized* (codes + scales,
        bit-exact), so a restored server continues the error-feedback
        recurrence exactly where the saved one left off.

        A restart restored from this no longer rebuilds the ring empty —
        users keep their cached heads and straggler *requests* stamped
        before the restart still find their snapshots.  In-flight delta
        rows (unapplied bank admissions) are the one thing lost; affected
        users re-personalize against the restored snapshots.
        """
        users = list(self._heads)
        res_stack, res_users = self._gathered_residuals()
        tree = {
            "server_state": self.state.as_dict(),
            "ring_snapshots": {f"w{w}": self._ckpt_snap(snap)
                               for w, snap in self.ring._snapshots.items()},
            "head_stack": self.stacked_heads(users) if users else None,
            "residuals": ({"q": res_stack.q, "scales": res_stack.scales}
                          if res_stack is not None else None),
        }
        # tau_max persists as REQUESTED, not as clamped to this ring's
        # depth: restoring into a deeper ring must widen back to the
        # request (the clamp is a property of the ring, not of the config)
        meta = {"users": users, "ring_current": self.ring.current,
                "windows": self.ring.windows,
                "tau_max": self.ring.tau_max_requested,
                "user_cap": self.ring.user_cap,
                "delta_dtype": self.delta_dtype,
                "residual_users": res_users,
                "personal_subset":
                    self.personal_subset.descriptor(self.state.params)
                    if self.personal_subset is not None else None,
                "ring_stats": {k: int(v)
                               for k, v in self.ring.stats.items()}}
        save_pytree(path, tree, meta=meta)

    @classmethod
    def restore(cls, path: str, loss_fn: Callable, pcfg: PersAFLConfig,
                **kw) -> "PersonalizationServer":
        """Rebuild a server from :meth:`save`'s checkpoint (warm start).

        Ring depth / staleness bound / fairness cap / personal subset come
        from the checkpoint, but any of them may be overridden through
        ``**kw`` (e.g. restore into a deeper ring with ``windows=8`` — the
        checkpointed *requested* ``tau_max`` then re-clamps against the new
        depth, not the old one).  ``**kw`` otherwise forwards the
        process-local knobs (``cohort_impl``, ``modes``, ``max_pending``,
        ``head_cache``).  Head-cache users must be JSON-serializable keys
        (strings in practice) — they round-trip through the sidecar meta.
        """
        tree = load_pytree(path)
        meta = load_meta(path)
        state = ServerState.from_dict(
            jax.tree.map(jnp.asarray, tree["server_state"]))
        windows = kw.pop("windows", meta["windows"])
        tau_max = kw.pop("tau_max", meta.get("tau_max"))
        user_cap = kw.pop("user_cap", meta.get("user_cap"))
        subset = kw.pop("personal_subset", meta.get("personal_subset"))
        delta_dtype = kw.pop("delta_dtype",
                             meta.get("delta_dtype", "fp32"))
        srv = cls(state.params, loss_fn, pcfg, windows=windows,
                  tau_max=tau_max, user_cap=user_cap,
                  personal_subset=subset, delta_dtype=delta_dtype, **kw)
        srv.state = state
        snapshots = {int(k[1:]): cls._unckpt_snap(snap)
                     for k, snap in tree["ring_snapshots"].items()}
        srv.ring.load(snapshots, meta["ring_current"],
                      stats=meta.get("ring_stats"))
        users = meta["users"]
        if users:
            heads = DeltaBank(
                stacked=jax.tree.map(jnp.asarray, tree["head_stack"]),
                k=len(users), stats=srv._engine_stats)
            if delta_dtype == "fp32":
                # device residency across windows; under int8 banking this
                # restored bank is a MATERIALIZED fp32 head stack — the
                # cache handles pin it, and retaining it would poison the
                # ring's quantized row_nbytes accounting
                srv.ring.retain(heads)
            for row, user in enumerate(users):
                srv._cache_head(user, heads, row)
        res_users = meta.get("residual_users") or []
        if res_users and tree.get("residuals") is not None:
            stack = QuantStack(
                q=jax.tree.map(jnp.asarray, tree["residuals"]["q"]),
                scales=jax.tree.map(jnp.asarray,
                                    tree["residuals"]["scales"]))
            resbank = QuantizedBank(stack, k=len(res_users),
                                    stats=srv._engine_stats)
            for row, user in enumerate(res_users):
                srv._cache_residual(user, resbank, row)
        return srv

    # -- observability -----------------------------------------------------

    @property
    def stats(self) -> Dict:
        s = dict(self._engine_stats)
        s.update({f"ring_{k}": v for k, v in self.ring.stats.items()})
        s.update({f"batcher_{k}": v for k, v in self.batcher.stats.items()})
        s["live_banks"] = self.ring.live_banks
        s["cached_heads"] = len(self._heads)
        # per-user steady-state ring residency, 2 rows per served user per
        # window: fp32 banking retains a delta row + a head row; int8
        # banking retains a delta row + an EF residual row (heads are lazy
        # views, they add no storage) — both cases 2x the bank row bytes.
        # The partial bench gates the subset shrink, the quant bench the
        # codec shrink (vs ``ring_bytes_per_user_fp32``).
        row = self.ring.row_nbytes or 0
        row_fp32 = self.ring.row_nbytes_fp32 or row
        s["ring_row_bytes"] = row
        s["ring_bytes_per_user"] = 2 * row
        s["ring_bytes_per_user_fp32"] = 2 * row_fp32
        s["ring_bytes_saved_per_user"] = 2 * (row_fp32 - row)
        s["delta_codec"] = self.delta_dtype
        return s

    def staleness(self) -> Dict:
        return staleness_stats(self.state)
