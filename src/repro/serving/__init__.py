"""Live-traffic serving of personalized heads (the ROADMAP serving item).

The paper's asynchronous bounded-staleness analysis *is* a serving problem:
millions of users, each owning a tiny personalized head derived from the
shared global model, arrive at arbitrary times with arbitrary (bounded)
staleness.  This package turns the cohort engine into that request-driven
service.

Batcher **modes** map to the paper's personalization options:

  * mode ``"B"`` — Option B / Per-FedAvg (Fallah et al. 2020): one-step
    MAML fine-tune, ``head_i = w − α ∇f_i(w; D_i)``.  Cheapest; one grad.
  * mode ``"C"`` — Option C / pFedMe (Dinh et al. 2020): Moreau-envelope
    prox solve, ``head_i = θ̃_i(w) ≈ argmin_θ f_i(θ) + λ/2‖θ − w‖²`` via K
    inner SGD steps.  Stronger personalization; K grads.

Both modes compose with **partial-model personalization** (arXiv
2309.17409): pass ``personal_subset`` (a :class:`repro.core.subset
.SubsetSpec` or any spelling it resolves) to
:class:`PersonalizationServer` and only that subset of the param tree is
personalized — grads/prox run over the subset with the backbone frozen,
the DeltaRing banks subset-shaped rows (one shared backbone serves every
retained window exactly, not approximately), the head cache holds subset
heads, and transport frames carry subset pytrees plus a ``subset``
descriptor header.  ``stats["ring_bytes_per_user"]`` reports the
steady-state per-user residency (one delta row + one head row); with a
head-only subset it shrinks by the head:model size ratio, which is the
lever toward millions of resident users.

Parts:

  * :mod:`repro.serving.batcher` — request queue + micro-batcher:
    concurrent requests coalesce into pow2-bucketed
    :class:`repro.fl.engine.CohortEngine` calls (vmap / lax.map /
    shard_map over the mesh's "cohort" axis — all devices of a 1-D
    ``("cohort",)`` mesh or the rows of a 2-D ``("cohort", "model")``
    mesh — with users keyed to cohort slices).
  * :mod:`repro.serving.bank` — :class:`DeltaRing`: persistent sharded
    DeltaBank ring-buffer holding the last W windows of stacked deltas and
    params snapshots (subset-pruned when a ``personal_subset`` is set) on
    device; straggler rows re-weight into the next window's ``apply_rows``
    weight vector (τ ≤ τ_max) instead of dropping.
  * :mod:`repro.serving.server` — :class:`PersonalizationServer`:
    submit/poll semantics (polls resolve through each ticket's own
    (bank, row) handle, never another ticket's for the same user),
    device-resident per-user head cache, window advance folding served
    deltas back into the global model, steady-state zero
    ``host_materializations``.
  * :mod:`repro.serving.transport` — :class:`TransportServer` /
    :class:`TransportClient`: the asyncio socket front-end that makes the
    server network-addressable (length-prefixed JSON + npz frames:
    SUBMIT/POLL/HEAD/STATS), with deadline-driven flushing (``flush_ms`` /
    ``window_ms`` timers), explicit backpressure (bounded in-flight
    tickets → ``BUSY``), and concurrent connections coalescing into the
    same micro-batched cohort calls.  ``launch/serve.py --listen PORT``
    boots it around a model-serving PersonalizationServer.
"""
from repro.serving.bank import DeltaRing                        # noqa: F401
from repro.serving.batcher import (MODES, MicroBatcher, Ticket,  # noqa: F401
                                   personalize_strategy)
from repro.serving.server import PersonalizationServer           # noqa: F401
from repro.serving.transport import (AsyncTransportClient,       # noqa: F401
                                     TransportBusy, TransportClient,
                                     TransportError, TransportServer)


def __getattr__(name: str):
    if name == "personalize_delta_fn":
        # removed in PR 10; the batcher module raises the full breadcrumb
        from repro.serving import batcher
        return getattr(batcher, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
