"""Request queue + micro-batcher for personalization traffic.

Batcher *modes* are the paper's personalization options (see the package
docstring): mode ``"B"`` is the Per-FedAvg one-step MAML fine-tune, mode
``"C"`` the pFedMe Moreau-envelope prox solve.  Each mode owns a
:class:`repro.fl.engine.CohortEngine` driven by the registry strategy
``repro.fl.api.strategy("personalize", mode=...)``, whose ``local_update``
computes the *personalization delta* — a params-shaped pytree with
``head = w − delta`` — so concurrent users ride the exact vmap / lax.map /
shard_map machinery (pow2 buckets, on-device DeltaBank) the training
cohorts use, and the resulting bank rows double as the server-side update
direction the ring folds back into the global model.  (The pre-PR-4
``CohortEngine(client_fn=...)`` override and its ``personalize_delta_fn``
helper were removed in PR 10.)

Fairness: ``user_cap`` bounds how many of one user's rows are admitted per
aggregation window, so users with unequal request rates cannot monopolize
the window's ``apply_rows`` weight vector — over-cap requests are refused
*before* spending a cohort slot (``status="capped"``; re-submit next
window) and counted in ``stats["fairness_capped"]``.

Under ``cohort_impl="shard_map"`` the batcher lays the cohort out
*cohort-slice-major*: user ``u`` always occupies a slot in cohort slice
``crc32(u) % n_slices``, where ``n_slices`` is the mesh's COHORT-axis
size — all of a 1-D ``("cohort",)`` mesh's devices, or the rows of a 2-D
``("cohort", "model")`` mesh, on which one slice is a whole
model-parallel device group.  The user's delta row therefore lands on the
same slice every window (stable row affinity — the "keyed by user slice"
part of the ring-buffer), with its model dims spread over that slice's
"model" devices.  Per-slice slots pad to a common pow2, which is exactly
the engine's slice-multiple bucket, so the layout adds no padding beyond
what the engine would.  The ``placed`` list a drain yields stays in
SUBMIT order — the mesh-independent admission order the ring passes to
the ordered window apply, which is what keeps post-advance params
bit-identical across mesh layouts.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.types import PersAFLConfig
from repro.fl.api import strategy as _strategy
from repro.fl.engine import CohortEngine, DeltaBank

MODES = ("B", "C")


def personalize_strategy(pcfg: PersAFLConfig, loss_fn: Callable, mode: str,
                         personal_subset=None):
    """The bound ``strategy("personalize", mode=...)`` behind one batcher
    mode — the registry rule whose ``local_update`` maps
    ``(params, batch)`` to the personalization delta (head = w − delta).
    With ``personal_subset`` set, the delta covers only the personal
    leaves (pruned subset structure; backbone frozen) and every bank row
    downstream shrinks accordingly."""
    return _strategy("personalize", mode=mode,
                     personal_subset=personal_subset).bind(pcfg, loss_fn)


def __getattr__(name: str):
    if name == "personalize_delta_fn":
        raise ImportError(
            "repro.serving.batcher.personalize_delta_fn was removed in "
            "PR 10 (deprecated since PR 4); use repro.fl.api.strategy("
            "'personalize', mode=...) / personalize_strategy instead.")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class Ticket:
    """Submit/poll handle for one personalization request.

    A "done" ticket carries its OWN result handle — ``head`` is the
    (heads DeltaBank, row) pair its flush produced and ``window`` the ring
    window it was served in — so polling an older ticket after a newer
    flush returns *that ticket's* head, never silently the newest one
    (resolving by user aliased them).  Once ``window`` retires from the
    ring the ticket is superseded-and-retired and polls fail explicitly.
    """
    user: object
    mode: str
    stamp: int                 # ring window the request was submitted in
    status: str = "queued"     # queued | done | dropped | capped
    tau: int = 0               # staleness in windows, set at drain time
    window: int = -1           # ring window the ticket was SERVED in
    head: Optional[tuple] = dataclasses.field(  # (heads bank, row)
        default=None, repr=False, compare=False)


def _pow2(k: int) -> int:
    return 1 << max(k - 1, 0).bit_length()


class MicroBatcher:
    """Coalesces concurrent personalization requests into cohort calls.

    Requests queue until :meth:`drain`, which groups them by
    ``(mode, stamp)`` — every group shares one params snapshot, the
    precondition for a single cohort call — and emits one pow2-bucketed
    ``update_cohort`` per group.  Straggler groups (stamp < current window)
    are computed against their *stamped* snapshot, so the delta the ring
    re-weights into the current window is the delta the user's own device
    would have uploaded.
    """

    def __init__(self, engines: Dict[str, CohortEngine],
                 n_shards: int = 1, user_cap: Optional[int] = None):
        self.engines = engines
        self.n_shards = max(int(n_shards), 1)
        self.user_cap = user_cap
        self._queue: List[Tuple[Ticket, Dict]] = []
        # per-user rows admitted to the window currently accumulating
        self._cap_window: int = -1
        self._user_rows: Dict[object, int] = {}
        self.stats = {"submitted": 0, "drains": 0, "cohort_calls": 0,
                      "max_coalesced": 0, "shard_padding": 0, "dropped": 0,
                      "fairness_capped": 0}

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, ticket: Ticket, batch) -> Ticket:
        if ticket.mode not in self.engines:
            raise ValueError(f"mode {ticket.mode!r} not enabled; "
                             f"have {sorted(self.engines)}")
        self.stats["submitted"] += 1
        self._queue.append((ticket, batch))
        return ticket

    def _shard(self, user) -> int:
        return zlib.crc32(str(user).encode()) % self.n_shards

    def _layout(self, reqs: List[Tuple[Ticket, Dict]]):
        """Slice-major cohort layout -> (batch_list, [(ticket, row)]).

        With one cohort slice the engine's own tail padding suffices; with
        N the per-slice slot count pads to a pow2 so the total is exactly
        the engine's slice-multiple bucket (row i ↦ cohort slice
        i // per_slice).  ``placed`` is emitted in SUBMIT order regardless
        of which slice each request landed on: admission order must be a
        mesh-independent total order on the window's rows (the ring feeds
        it to the ordered window apply), and slice-major order would
        permute with the mesh shape.
        """
        if self.n_shards == 1:
            return ([b for _, b in reqs],
                    [(t, i) for i, (t, _) in enumerate(reqs)])
        shards: List[List[Tuple[int, Dict]]] = \
            [[] for _ in range(self.n_shards)]
        for qi, (t, _) in enumerate(reqs):
            shards[self._shard(t.user)].append((qi, reqs[qi][1]))
        per = _pow2(max(max(len(s) for s in shards), 1))
        fill = reqs[-1][1]
        batch_list, row_of = [], {}
        for si, s in enumerate(shards):
            for j in range(per):
                if j < len(s):
                    qi, b = s[j]
                    batch_list.append(b)
                    row_of[qi] = si * per + j
                else:
                    batch_list.append(fill)
                    self.stats["shard_padding"] += 1
        return batch_list, [(t, row_of[qi])
                            for qi, (t, _) in enumerate(reqs)]

    def drain(self, current: int, snapshot_fn: Callable[[int], object], *,
              tau_max: int) -> Iterator[Tuple[str, int, DeltaBank,
                                              List[Tuple[Ticket, int]]]]:
        """Yield ``(mode, stamp, bank, [(ticket, row), ...])`` per group.

        Requests whose staleness ``current − stamp`` exceeds ``tau_max``
        (or whose snapshot already retired from the ring) are marked
        ``dropped`` without spending a cohort slot on them; with
        ``user_cap`` set, a user's requests beyond the cap *within one
        aggregation window* are likewise refused pre-cohort
        (``status="capped"``) so heavy users cannot monopolize the
        window's apply weight vector.
        """
        queue, self._queue = self._queue, []
        if not queue:
            return
        if current != self._cap_window:        # window rolled: caps reset
            self._cap_window = current
            self._user_rows = {}
        self.stats["drains"] += 1
        self.stats["max_coalesced"] = max(self.stats["max_coalesced"],
                                          len(queue))
        groups: Dict[Tuple[str, int], List[Tuple[Ticket, Dict]]] = {}
        for ticket, batch in queue:
            ticket.tau = current - ticket.stamp
            if ticket.tau > tau_max:
                ticket.status = "dropped"
                self.stats["dropped"] += 1
                continue
            if self.user_cap is not None:
                used = self._user_rows.get(ticket.user, 0)
                if used >= self.user_cap:
                    ticket.status = "capped"
                    self.stats["fairness_capped"] += 1
                    continue
                self._user_rows[ticket.user] = used + 1
            groups.setdefault((ticket.mode, ticket.stamp), []).append(
                (ticket, batch))
        for (mode, stamp), reqs in sorted(groups.items(),
                                          key=lambda kv: kv[0][1]):
            batch_list, placed = self._layout(reqs)
            self.stats["cohort_calls"] += 1
            bank = self.engines[mode].update_cohort(snapshot_fn(stamp),
                                                    batch_list)
            yield mode, stamp, bank, placed
