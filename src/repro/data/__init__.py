from repro.data.federated import (ClientData, make_federated_dataset,  # noqa: F401
                                  sample_batches, synthetic_token_batch)
