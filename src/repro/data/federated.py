"""Heterogeneous federated data pipeline (paper §5 / Appendix D).

The container is offline, so MNIST/CIFAR-10 are replaced by *synthetic*
datasets with matched statistics: 10 classes, 28×28×1 ("mnist-like") or
32×32×3 ("cifar-like") images drawn as class prototype + noise.  What the
paper's claims exercise is the *heterogeneity mechanism* — each client holds
samples from only ``c`` of the 10 classes (c=5 MNIST, c=3 CIFAR) with
unbalanced sizes — which is reproduced exactly (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class ClientData:
    """Per-client train/test arrays."""
    train_x: np.ndarray
    train_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    classes: Tuple[int, ...]

    @property
    def n_train(self) -> int:
        return len(self.train_y)


def make_synthetic_images(seed: int, kind: str = "mnist",
                          per_class: int = 400):
    """-> (x (N,H,W,C) f32, y (N,) int32), 10 classes."""
    rng = np.random.RandomState(seed)
    if kind == "mnist":
        h, w, c = 28, 28, 1
        noise = 1.0
        n_dict = 16
    elif kind == "cifar":
        h, w, c = 32, 32, 3
        noise = 1.3
        n_dict = 24
    else:
        raise ValueError(kind)
    # Classes are sparse combinations of a SHARED feature dictionary
    # ("strokes"), so low-level conv features transfer across classes —
    # collaboration helps (like real MNIST) — while heavy noise keeps the
    # global 10-class problem hard relative to each client's c-class
    # subproblem — personalization pays (DESIGN.md §8).
    dictionary = rng.randn(n_dict, h, w, c).astype(np.float32)
    coeffs = rng.randn(10, n_dict).astype(np.float32)
    coeffs *= (rng.rand(10, n_dict) < 0.3)          # sparse class mixtures
    coeffs /= np.maximum(np.linalg.norm(coeffs, axis=1, keepdims=True), 1e-6)
    protos = np.einsum("kd,dhwc->khwc", coeffs * 2.0, dictionary)
    xs, ys = [], []
    for k in range(10):
        n = per_class
        x = protos[k][None] + noise * rng.randn(n, h, w, c).astype(np.float32)
        xs.append(x)
        ys.append(np.full((n,), k, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def partition_heterogeneous(x, y, *, n_clients: int, classes_per_client: int,
                            seed: int, test_frac: float = 0.2,
                            unbalance: float = 0.6) -> List[ClientData]:
    """Label-skew partition: client i sees only ``classes_per_client`` of the
    10 classes, with log-normal unbalanced sample counts (paper §5)."""
    rng = np.random.RandomState(seed)
    by_class = {k: list(np.where(y == k)[0]) for k in range(10)}
    for k in by_class:
        rng.shuffle(by_class[k])
    clients: List[ClientData] = []
    sizes = np.exp(unbalance * rng.randn(n_clients))
    sizes = sizes / sizes.sum()
    total = len(y)
    for i in range(n_clients):
        cls = tuple(sorted(rng.choice(10, classes_per_client, replace=False)))
        want = max(int(sizes[i] * total), 8 * classes_per_client)
        per_cls = max(want // classes_per_client, 8)
        idx: List[int] = []
        for k in cls:
            pool = by_class[k]
            if len(pool) < per_cls:   # recycle with replacement if exhausted
                take = list(rng.choice(np.where(y == k)[0], per_cls))
            else:
                take = [pool.pop() for _ in range(per_cls)]
            idx.extend(take)
        idx = np.array(idx)
        rng.shuffle(idx)
        n_test = max(int(test_frac * len(idx)), classes_per_client)
        clients.append(ClientData(
            train_x=x[idx[n_test:]], train_y=y[idx[n_test:]],
            test_x=x[idx[:n_test]], test_y=y[idx[:n_test]],
            classes=cls))
    return clients


def sample_batches(client: ClientData, rng: np.random.RandomState,
                   n_batches: int, batch_size: int) -> Dict[str, np.ndarray]:
    """Sample ``n_batches`` iid batches -> leaves (n_batches, B, ...).

    Always samples with replacement at the *fixed* ``batch_size`` so every
    client produces identically-shaped batches (one jit compilation total —
    per-client shapes would recompile per client).
    """
    idx = rng.randint(0, client.n_train, size=(n_batches, batch_size))
    return {"images": client.train_x[idx], "labels": client.train_y[idx]}


def eval_batch(client: ClientData, size: int, seed: int = 0):
    """Fixed-size test batch (resampled with replacement if the client's
    test set is smaller) — keeps the eval jit shape-stable across clients."""
    rng = np.random.RandomState(seed)
    n = len(client.test_y)
    if n >= size:
        idx = rng.choice(n, size, replace=False)
    else:
        idx = rng.choice(n, size, replace=True)
    return {"images": client.test_x[idx], "labels": client.test_y[idx]}


def make_federated_dataset(kind: str, n_clients: int, classes_per_client: int,
                           seed: int = 0) -> List[ClientData]:
    x, y = make_synthetic_images(seed, kind)
    return partition_heterogeneous(x, y, n_clients=n_clients,
                                   classes_per_client=classes_per_client,
                                   seed=seed + 1)


# ---------------------------------------------------------------------------
# token stream for LM smoke tests / examples
# ---------------------------------------------------------------------------

def synthetic_token_batch(seed: int, batch: int, seq: int, vocab: int):
    rng = np.random.RandomState(seed)
    # a learnable synthetic language: tokens follow a noisy linear recurrence
    toks = np.zeros((batch, seq + 1), np.int32)
    toks[:, 0] = rng.randint(0, vocab, batch)
    mult = 31
    for t in range(seq):
        nxt = (toks[:, t] * mult + 7) % vocab
        noise = rng.rand(batch) < 0.1
        toks[:, t + 1] = np.where(noise, rng.randint(0, vocab, batch), nxt)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
