"""End-to-end driver: the paper's §5 MNIST experiment — train the paper's
CNN with asynchronous personalized FL for a few hundred server rounds,
checkpoint the server state, and report the accuracy-vs-time trajectory.

    PYTHONPATH=src python examples/persafl_mnist.py [--rounds 200] [--option C]

(Thin wrapper over ``repro.launch.train --preset paper-mnist`` — the same
driver a real deployment would invoke.)
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = ([sys.argv[0], "--preset", "paper-mnist"]
                + (sys.argv[1:] or ["--rounds", "200", "--option", "C"]))
    main()
