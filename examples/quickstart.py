"""Quickstart: PersA-FL-ME on heterogeneous synthetic MNIST in ~60 lines,
on the declarative Strategy/Scheduler API (PR 4): a registry strategy
composed with a server apply schedule inside one ``FLRun``.

    PYTHONPATH=src python examples/quickstart.py

(Set EXAMPLES_SMOKE=1 to shrink the run for CI.)
"""
import os

import jax
import numpy as np

from repro.configs.paper_models import MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import DelayModel, FLRun, immediate, make_personalized_eval, \
    strategy
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn

SMOKE = bool(int(os.environ.get("EXAMPLES_SMOKE", "0")))


def main():
    # 1. heterogeneous federated data: 10 clients, 5-of-10 classes each
    clients = make_federated_dataset("mnist", n_clients=6 if SMOKE else 10,
                                     classes_per_client=5, seed=0)
    print("client class skews:", [c.classes for c in clients[:3]], "...")

    # 2. the paper's CNN + personalized evaluation (same fine-tuning budget
    #    for every method, §5)
    params = init_cnn(MNIST_CNN, jax.random.PRNGKey(0))
    loss = lambda p, b: cnn_loss(MNIST_CNN, p, b, train=False)
    acc = lambda p, b: cnn_accuracy(MNIST_CNN, p, b)
    evaluate = make_personalized_eval(loss, acc, clients, ft_steps=1,
                                      ft_lr=0.01)
    print(f"personalized accuracy before training: {evaluate(params):.3f}")

    # 3. PersA-FL, Option C (Moreau envelope) × the paper-faithful
    #    immediate-apply asynchronous schedule.  Swapping the baseline is
    #    one argument: strategy("fedprox", mu=0.1), strategy("scaffold"),
    #    …; swapping the scheduler likewise: buffered(8), sync_barrier(5).
    pcfg = PersAFLConfig(option="C", q_local=5 if SMOKE else 10, eta=0.01,
                         lam=25.0, inner_steps=5 if SMOKE else 10,
                         inner_eta=0.02)
    run = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(len(clients)),
                strategy=strategy("persafl", option="C"),
                schedule=immediate(), batch_size=16, seed=0)
    rounds = 20 if SMOKE else 60
    hist = run.run(max_rounds=rounds, eval_every=rounds // 3,
                   eval_fn=evaluate)

    print("accuracy trajectory:", [round(a, 3) for a in hist.acc])
    print(f"mean active-client ratio: {np.mean(hist.active_ratio):.2f} "
          f"(paper Fig. 2a: ~0.8 for async)")
    print(f"max staleness observed: {max(hist.staleness)} "
          f"(Assumption 1's tau)")


if __name__ == "__main__":
    main()
