"""Personalized serving: four concurrent users submit their own token
streams to a PersonalizationServer, which coalesces the Moreau-envelope
prox solves (Option C, θ̃_i(w)) into one cohort call and decodes with the
per-user heads vmapped over the SSM recurrent cache.

    PYTHONPATH=src python examples/serve_personalized.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "mamba2-130m", "--smoke",
                "--personalize", "--mode", "C", "--personalize-len", "32",
                "--requests", "4", "--tokens", "16"]
    main()
