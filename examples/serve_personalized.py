"""Personalized serving: ME-personalize a Mamba2 LM on a client's token
stream (Option C's θ̃_i(w)), then decode batched requests with the SSM
recurrent cache.

    PYTHONPATH=src python examples/serve_personalized.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "mamba2-130m", "--smoke",
                "--personalize", "--requests", "4", "--tokens", "16"]
    main()
