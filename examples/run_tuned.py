"""Replay the tuner's promoted winners: load
``examples/tuned/fig2_winners.json`` (written by
``experiments/sweeps/joint_tune.py`` via
:func:`repro.tune.promote_winners`) and re-run each winning
(strategy, schedule) configuration as a plain :class:`repro.fl.FLRun` —
no tuner in the loop, just the config record the sweep selected.

This is the promotion contract end-to-end: a winner is an ordinary JSON
blob (strategy name + kwargs, schedule spelling, seed), so anything that
can parse JSON can reproduce the tuned run.

    PYTHONPATH=src python examples/run_tuned.py

(Set EXAMPLES_SMOKE=1 to shrink rounds/clients for CI.)
"""
import json
import os

import jax

from repro.configs.paper_models import CIFAR_CNN, MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import DelayModel, FLRun, make_personalized_eval, strategy
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
from repro.tune import parse_schedule

SMOKE = bool(int(os.environ.get("EXAMPLES_SMOKE", "0")))
WINNERS = os.path.join(os.path.dirname(__file__), "tuned",
                       "fig2_winners.json")


def _setup(kind):
    cpc = 5 if kind == "mnist" else 3  # paper §5 class splits
    ccfg = MNIST_CNN if kind == "mnist" else CIFAR_CNN
    clients = make_federated_dataset(kind, n_clients=6 if SMOKE else 20,
                                     classes_per_client=cpc, seed=0)
    params = init_cnn(ccfg, jax.random.PRNGKey(0))
    loss = lambda p, b: cnn_loss(ccfg, p, b, train=False)      # noqa: E731
    acc = lambda p, b: cnn_accuracy(ccfg, p, b)                # noqa: E731
    ev = make_personalized_eval(loss, acc, clients, ft_steps=1, ft_lr=0.01)
    return clients, params, loss, ev


def main():
    blob = json.load(open(WINNERS))
    rounds = 12 if SMOKE else 96
    print("dataset,winner,schedule,rounds,final_acc,tuned_acc")
    for group, win in sorted(blob["winners"].items()):
        if not group.endswith("/selfstop"):
            continue
        kind = group.split("/")[0]
        clients, params, loss, ev = _setup(kind)
        pcfg = PersAFLConfig(option="A", q_local=5, eta=0.002, alpha=0.01,
                             lam=25.0, inner_steps=5, inner_eta=0.02)
        run = FLRun(clients=clients, loss_fn=loss, init_params=params,
                    pcfg=pcfg,
                    delays=DelayModel(len(clients), seed=win["seed"]),
                    strategy=strategy(win["strategy"],
                                      **win["strategy_kwargs"]),
                    schedule=parse_schedule(win["schedule"]),
                    batch_size=16, seed=win["seed"])
        h = run.run(max_rounds=rounds, eval_every=rounds, eval_fn=ev,
                    final_eval=True)
        print(f"{kind},{win['strategy']},{win['schedule']},{rounds},"
              f"{h.acc[-1]:.3f},{win['final_acc']:.3f}", flush=True)


if __name__ == "__main__":
    main()
