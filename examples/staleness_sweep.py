"""Staleness-tolerance sweep (the tau^2/T term of Theorems 1-3): run
FedAsync and PersA-FL-ME under increasing communication-delay spread and
report max staleness vs final personalized accuracy.  The buffered rows
(M=8) show the FedBuff-style scheduler's staleness profile at the same
delay scales — every row is the same ``FLRun`` with a different
``schedule=`` (immediate vs buffered), all on the vectorized cohort engine.

    PYTHONPATH=src python examples/staleness_sweep.py

(Set EXAMPLES_SMOKE=1 to shrink the sweep for CI.)
"""
import os

from repro.configs.paper_models import MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import DelayModel, FLRun, buffered, immediate, \
    make_personalized_eval, strategy
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
import jax

SMOKE = bool(int(os.environ.get("EXAMPLES_SMOKE", "0")))


def main():
    clients = make_federated_dataset("mnist", n_clients=6 if SMOKE else 15,
                                     classes_per_client=5, seed=0)
    params = init_cnn(MNIST_CNN, jax.random.PRNGKey(0))
    loss = lambda p, b: cnn_loss(MNIST_CNN, p, b, train=False)
    acc = lambda p, b: cnn_accuracy(MNIST_CNN, p, b)
    ev = make_personalized_eval(loss, acc, clients, ft_steps=1, ft_lr=0.01)

    rounds = 16 if SMOKE else 80
    scales = (1.0, 4.0) if SMOKE else (1.0, 4.0, 16.0)
    print("option,delay_scale,tau_max,tau_mean,final_acc")
    for option in ("A", "C"):
        for buffer_m in (1, 8):
            for scale in scales:
                pcfg = PersAFLConfig(option=option, q_local=5, eta=0.01,
                                     lam=25.0, inner_steps=5,
                                     inner_eta=0.02)
                run = FLRun(
                    clients=clients, loss_fn=loss, init_params=params,
                    pcfg=pcfg,
                    delays=DelayModel(len(clients), seed=1, scale=scale,
                                      jitter=(0.2, 3.0)),
                    strategy=strategy("persafl", option=option),
                    schedule=immediate() if buffer_m == 1
                    else buffered(buffer_m),
                    batch_size=16, seed=0)
                h = run.run(max_rounds=rounds, eval_every=rounds,
                            eval_fn=ev)
                tau = max(h.staleness)
                tau_mean = sum(h.staleness) / len(h.staleness)
                label = option if buffer_m == 1 else f"{option}-buf{buffer_m}"
                print(f"{label},{scale},{tau},{tau_mean:.2f},"
                      f"{h.acc[-1] if h.acc else float('nan'):.3f}",
                      flush=True)


if __name__ == "__main__":
    main()
