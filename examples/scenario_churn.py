"""Scenario engine tour: a declarative churn spec, the device-resident
scheduler at 10^5 clients, and robust admission against adversaries.

Three stops:

  1. :class:`ScenarioSpec` — describe a traffic shape declaratively
     (device-class speed tiers, diurnal availability, mid-round dropout,
     an adversarial population) and round-trip it through JSON;
  2. :class:`DeviceScheduler` — form per-window cohorts for 10^5
     simulated clients in one jitted call per window (the 10^6-client
     ``scale`` bench is this, bigger);
  3. ``FLRun(..., schedule=buffered(8, robust="clip"))`` — train under
     the same churn with 10% adversarial clients; the robust flush clips
     their inflated rows while the plain flush lets them through.

    PYTHONPATH=src python examples/scenario_churn.py

(Set EXAMPLES_SMOKE=1 to shrink the run for CI.)
"""
import os

import jax
import numpy as np

from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import (Adversarial, DeviceScheduler, Diurnal, FLRun,
                      ScenarioSpec, Tier, buffered, strategy)
from repro.models.cnn import cnn_loss, init_cnn
from repro.configs.paper_models import MNIST_CNN

SMOKE = bool(int(os.environ.get("EXAMPLES_SMOKE", "0")))


def main():
    # 1. a declarative, JSON-round-tripping scenario: half the devices are
    #    fast phones, half slow ones; availability follows a day curve;
    #    2% of cycles drop mid-round; 10% of clients are adversarial
    spec = ScenarioSpec(
        n_clients=10_000 if SMOKE else 100_000, seed=0,
        tiers=(Tier("phone", 0.5, 0.7), Tier("iot", 0.5, 1.8)),
        diurnal=Diurnal(period=86_400.0, floor=0.25), dropout=0.02,
        adversarial=Adversarial(frac=0.1, kinds=("scale", "sign_flip"),
                                magnitude=50.0))
    wire = spec.to_json()
    assert ScenarioSpec.from_json(wire) == spec
    print(f"spec round-trips through {len(wire)} bytes of JSON")
    model = spec.build()
    print(f"population: {model.n_clients} clients, "
          f"{len(model.adversary_ids)} adversarial")

    # 2. device-resident scheduling: each window is ONE jitted call; the
    #    host only ever sees the [cohort_cap] cohort id/time vectors
    sched = DeviceScheduler(model, window_len=1800.0, cohort_cap=256)
    for _ in range(3):
        ids, times = sched.next_window()
    s = sched.stats
    print(f"3 windows: {s['arrivals']} arrivals, {s['dropouts']} dropouts, "
          f"cohort fill max {s['cohort_fill_max']}")

    # 3. the same churn shape driving training, defended by robust
    #    admission (clip); compare scheduler_stats across arms
    n = 8 if SMOKE else 16
    clients = make_federated_dataset("mnist", n_clients=n,
                                     classes_per_client=5, seed=0)
    params = init_cnn(MNIST_CNN, jax.random.PRNGKey(0))
    loss = lambda p, b: cnn_loss(MNIST_CNN, p, b, train=False)  # noqa: E731
    pcfg = PersAFLConfig(option="A", q_local=2 if SMOKE else 5, eta=0.002,
                         lam=25.0, inner_steps=3, inner_eta=0.02)
    train_spec = ScenarioSpec(
        n_clients=n, seed=0, tiers=spec.tiers, dropout=0.05,
        adversarial=spec.adversarial)
    rounds = 16 if SMOKE else 48
    for robust in (None, "clip"):
        run = FLRun(clients=clients, loss_fn=loss, init_params=params,
                    pcfg=pcfg, delays=train_spec.build(),
                    strategy=strategy("persafl", option="A"),
                    schedule=buffered(8, robust=robust), batch_size=16,
                    seed=0)
        run.run(max_rounds=rounds)
        st = run.stats
        finite = all(np.isfinite(np.asarray(x)).all()
                     for x in jax.tree.leaves(run.state.params))
        print(f"robust={robust!r:8} corrupted={st['corrupted_rows']:3d} "
              f"clipped={st['robust_clipped']:3d} "
              f"dropouts={st['dropouts']:3d} params_finite={finite}")


if __name__ == "__main__":
    main()
