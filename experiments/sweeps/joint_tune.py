"""Joint self-stopping tune (the `repro.tune` subsystem's flagship driver,
closing the ROADMAP "convergence hillclimb + self-stopping sweep harness"
item): ``buffered(M in {1,4,8})`` × {persafl-B, persafl-C, scaffold,
fedprox} on the fig2 MNIST/CIFAR configurations, at equal simulated time.

Per dataset, the sweep runs the SAME fingerprinted grid twice through
:class:`repro.tune.TuneRunner`:

  * **exhaustive** — every arm to the full simulated-time budget T (set by
    a reference run of persafl-B/buffered(1) to ``ROUNDS`` server rounds);
  * **selfstop**  — identical arms under the default stop-rule bundle
    (loss-spike abort, running-median loss watch, accuracy-plateau
    patience) checked live through ``FLRun.run(on_eval=...)``.

Because arms share seed-paired client/delay streams, a self-stopped trial
is a bit-exact prefix of its exhaustive twin — the comparison isolates
exactly what early stopping gives up.  Gates (recorded in the JSON and
enforced):

  * the selfstop grid selects the same (strategy, schedule) winner per
    dataset as the exhaustive grid;
  * zero host materializations across every arm (all-buffered grids never
    move per-client deltas to the host);
  * full run only: the selfstop grid's total simulated time is ≤ 60% of
    the exhaustive grid's budget.

Artifacts: ``experiments/sweeps/joint_tune.json`` + ``joint_tune.md``
(fig2-style table), ``experiments/sweeps/joint_tune_journal.jsonl`` (the
resumable trial journal — re-running skips completed arms),
``examples/tuned/fig2_winners.json`` (the promoted winning configs, which
``examples/run_tuned.py`` replays), and one JSONL bench row appended to
``experiments/bench/BENCH_tune.json`` (arms run / stopped early /
simulated + wall cost vs the full grid).

    PYTHONPATH=src python experiments/sweeps/joint_tune.py

Env: SWEEP_FAST=1 shrinks the grid/rounds for the CI smoke pass;
SWEEP_FRESH=1 deletes the journal first (forces a from-scratch run).
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.configs.paper_models import CIFAR_CNN, MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import make_personalized_eval
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
from repro.tune import (AnyOf, Arm, LossSpike, SweepSpec, TuneRunner,
                        default_rules, make_report, promote_winners,
                        to_markdown)

FAST = bool(int(os.environ.get("SWEEP_FAST", "0")))
OUT = os.path.join("experiments", "sweeps")
JOURNAL = os.path.join(OUT, "joint_tune_journal.jsonl")
BENCH = os.path.join("experiments", "bench", "BENCH_tune.json")
WINNERS = os.path.join("examples", "tuned", "fig2_winners.json")

DATASETS = ("mnist", "cifar")
ROUNDS = 24 if FAST else 48            # reference-run budget, server rounds
EVALS = 6 if FAST else 12              # eval grid points per budget
STRATEGIES = ({"name": "persafl", "option": "B"},
              {"name": "fedprox"}) if FAST else \
             ({"name": "persafl", "option": "B"},
              {"name": "persafl", "option": "C"},
              {"name": "scaffold"},
              {"name": "fedprox"})
SCHEDULES = ("buffered(1)", "buffered(8)") if FAST else \
            ("buffered(1)", "buffered(4)", "buffered(8)")
# Stop rules.  FAST (the CI smoke) aborts on divergence only: 24-round
# traces are pure noise for plateau/median watches — any constant safe
# for the late-blooming winners stops nothing, and any constant that
# stops something kills a winner (the plateau/median rules are pinned
# deterministically in tests/test_tune.py instead).  The full run's
# constants were calibrated by replaying candidates over the journaled
# exhaustive traces (the selfstop trial is a bit-exact prefix of its
# exhaustive twin, so the replay predicts the live run exactly): a
# demanding plateau watch (+0.05 acc per 2 evals) stops every arm at
# ~45-51% of the budget, where the ranking already agrees with the
# full grid — selection is cheap, and the promoted winner is replayed
# at full budget by examples/run_tuned.py.
RULES = AnyOf((LossSpike(factor=3.0, warmup=1),)) if FAST else \
    default_rules(window=4, median_factor=1.2, spike_factor=3.0,
                  patience=2, min_delta=0.05, warmup=2)


def _problem(kind: str):
    """One problem closure per dataset: data/params/eval built lazily on
    the first live arm (a fully-resumed re-run never builds anything)
    and shared by every arm (the jitted eval amortizes across the
    grid)."""
    cache = {}

    def build(arm):
        if not cache:
            cpc = 5 if kind == "mnist" else 3  # §5: c=5 MNIST, c=3 CIFAR
            ccfg = MNIST_CNN if kind == "mnist" else CIFAR_CNN
            clients = make_federated_dataset(kind, n_clients=10,
                                             classes_per_client=cpc,
                                             seed=0)
            params = init_cnn(ccfg, jax.random.PRNGKey(0))
            loss = lambda p, b: cnn_loss(ccfg, p, b, train=False)  # noqa
            acc = lambda p, b: cnn_accuracy(ccfg, p, b)            # noqa
            cache.update(
                clients=clients, loss_fn=loss, init_params=params,
                eval_fn=make_personalized_eval(loss, acc, clients,
                                               ft_steps=1, ft_lr=0.01,
                                               with_loss=True),
                pcfg=PersAFLConfig(option="A", q_local=5, eta=0.002,
                                   alpha=0.01, lam=25.0, inner_steps=5,
                                   inner_eta=0.02),
                batch_size=16, eval_every=max(ROUNDS // EVALS, 1))
        return cache

    return build


def main():
    if bool(int(os.environ.get("SWEEP_FRESH", "0"))) \
            and os.path.exists(JOURNAL):
        os.remove(JOURNAL)
    all_trials, gates, per_ds = [], {}, {}
    wall0 = time.time()
    for ds in DATASETS:
        problem = _problem(ds)
        # reference run pins the dataset's simulated-time budget T
        ref = TuneRunner(problem, journal=JOURNAL).run_arm(Arm(
            strategy="persafl", strategy_kwargs={"option": "B"},
            schedule="buffered(1)", seed=0, budget=None,
            max_rounds=ROUNDS, group=f"{ds}/ref"))
        budget = ref.sim_time
        grid = dict(strategies=STRATEGIES, schedules=SCHEDULES, seeds=(0,))
        arms_ex = SweepSpec(group=f"{ds}/exhaustive", **grid).arms(
            max_rounds=8 * ROUNDS, budget=budget)
        arms_ss = SweepSpec(group=f"{ds}/selfstop", **grid).arms(
            max_rounds=8 * ROUNDS, budget=budget)

        t0 = time.time()
        ex = TuneRunner(problem, journal=JOURNAL,
                        verbose=True).run_sweep(arms_ex)
        wall_ex = time.time() - t0
        t0 = time.time()
        ss = TuneRunner(problem, journal=JOURNAL, stop_rule=RULES,
                        verbose=True).run_sweep(arms_ss)
        wall_ss = time.time() - t0

        spent_ex = sum(t.sim_time for t in ex)
        spent_ss = sum(t.sim_time for t in ss)
        frac = spent_ss / max(spent_ex, 1e-9)
        win_ex = min(ex, key=lambda t: (-t.final_acc, t.sim_time))
        win_ss = min(ss, key=lambda t: (-t.final_acc, t.sim_time))
        match = (win_ex.arm.strategy, dict(win_ex.arm.strategy_kwargs),
                 win_ex.arm.schedule) == \
                (win_ss.arm.strategy, dict(win_ss.arm.strategy_kwargs),
                 win_ss.arm.schedule)
        per_ds[ds] = {
            "budget": budget, "cost_fraction": frac,
            "sim_spent_exhaustive": spent_ex, "sim_spent_selfstop": spent_ss,
            "wall_exhaustive_s": wall_ex, "wall_selfstop_s": wall_ss,
            "n_stopped": sum(1 for t in ss if t.status == "stopped"),
            "n_arms": len(ss),
            "winner_exhaustive": win_ex.arm.name,
            "winner_selfstop": win_ss.arm.name,
            "winner_acc_exhaustive": win_ex.final_acc,
            "winner_acc_selfstop": win_ss.final_acc,
        }
        gates[f"winner_match_{ds}"] = bool(match)
        if not FAST:
            gates[f"cost_fraction_{ds}"] = frac <= 0.6
        all_trials += [ref] + ex + ss
        print(f"dataset,{ds},budget,{budget:.0f},frac,{frac:.2f},"
              f"winner_ex,{win_ex.arm.name},winner_ss,{win_ss.arm.name}",
              flush=True)

    gates["host_materializations_zero"] = all(
        t.host_materializations == 0 for t in all_trials)
    gates["params_finite"] = all(t.params_finite for t in all_trials)

    report = make_report(all_trials)
    result = {"fast": FAST, "rounds": ROUNDS, "per_dataset": per_ds,
              "gates": gates, "stop_rules": RULES.to_dict(),
              "n_trials": report["n_trials"],
              "n_stopped": report["n_stopped"],
              "n_resumed": report["n_resumed"],
              "wall_s": time.time() - wall0,
              "report": report}
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "joint_tune.json"), "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    with open(os.path.join(OUT, "joint_tune.md"), "w") as f:
        f.write(to_markdown(
            report, title="Joint self-stopping tune "
            "(buffered(M) x strategy, fig2 configs, equal simulated time)"))
    promote_winners(
        {"groups": {g: v for g, v in report["groups"].items()
                    if g.endswith("/selfstop")}},
        WINNERS, extra={"source": "experiments/sweeps/joint_tune.py",
                        "fast": FAST, "rounds": ROUNDS})
    os.makedirs(os.path.dirname(BENCH), exist_ok=True)
    with open(BENCH, "a") as f:
        f.write(json.dumps({
            "bench": "tune", "fast": FAST,
            "arms_total": sum(d["n_arms"] for d in per_ds.values()),
            "arms_stopped": sum(d["n_stopped"] for d in per_ds.values()),
            "cost_fraction": {d: per_ds[d]["cost_fraction"] for d in per_ds},
            "wall_exhaustive_s": sum(d["wall_exhaustive_s"]
                                     for d in per_ds.values()),
            "wall_selfstop_s": sum(d["wall_selfstop_s"]
                                   for d in per_ds.values()),
            "wall_saved_s": sum(d["wall_exhaustive_s"]
                                - d["wall_selfstop_s"]
                                for d in per_ds.values()),
            "gates": {k: bool(v) for k, v in gates.items()},
            "wall_s": time.time() - wall0}, sort_keys=True) + "\n")

    for gate, ok in gates.items():
        print(f"gate,{gate},{ok}")
    bad = [g for g, ok in gates.items() if not ok]
    if bad:
        raise RuntimeError(f"joint_tune gates failed: {bad} "
                           f"({json.dumps(per_ds, default=float)})")


if __name__ == "__main__":
    main()
