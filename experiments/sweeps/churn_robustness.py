"""Churn + adversary robustness sweep (PR 8 scenario engine).

Four arms on the fig2b MNIST configuration (c=5 classes/client, Option A,
buffered(8)), all under the same realistic churn (speed tiers, diurnal
availability, mid-round dropout):

  clean — no adversaries, plain buffered apply (the reference accuracy)
  plain — 5% adversarial clients (deltas scaled x50 / replaced by NaN)
          against the plain buffered apply
  clip  — same adversaries, ``buffered(8, robust="clip")``
  trim  — same adversaries, ``buffered(8, robust="trim")``

Gate (recorded in the JSON and enforced): the robust arms hold final
personalized accuracy within 0.1 of the clean arm while the plain arm
degrades below that band — the defense pays for itself exactly when the
scenario engine's adversarial population is switched on.

The adversary kinds here are the *norm attacks* (``scale``, ``nan``)
that norm-statistic defenses are built for.  The churn model also
supports ``sign_flip`` (−magnitude): its rows carry an inflated norm
too, so clip bounds them and trim discards them, but a *unit*-magnitude
direction flip is norm-indistinguishable from an honest row — defending
that class needs direction-aware aggregation (geometric median / Krum),
which is out of scope for the admission-weight layer.

Emits one JSON row per arm to
``experiments/sweeps/churn_robustness.json`` and CSV lines to stdout.

    PYTHONPATH=src python experiments/sweeps/churn_robustness.py

Env: SWEEP_FAST=1 shrinks clients/rounds for a smoke pass.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.paper_models import MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import (Adversarial, Diurnal, FLRun, ScenarioSpec, Tier,
                      buffered, make_personalized_eval, strategy)
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn

FAST = bool(int(os.environ.get("SWEEP_FAST", "0")))
OUT = os.path.join("experiments", "sweeps")

ADV_FRAC = 0.05
MAGNITUDE = 50.0
# the scenario rolls its own seed, decoupled from the data/init seed: at
# seed 1 the population hash lands one "scale" client and two "nan"
# clients on the 30-client config, so a single run exercises both the
# clip/trim path and the non-finite drop path
SCENARIO_SEED = 1


def _spec(n, *, adversarial):
    return ScenarioSpec(
        n_clients=n, seed=SCENARIO_SEED,
        tiers=(Tier("fast", 0.5, 0.7), Tier("slow", 0.5, 1.6)),
        diurnal=Diurnal(period=300.0, floor=0.3), dropout=0.05,
        adversarial=Adversarial(frac=ADV_FRAC,
                                kinds=("scale", "nan"),
                                magnitude=MAGNITUDE)
        if adversarial else None)


def _setup(seed=0):
    n = 10 if FAST else 30
    clients = make_federated_dataset("mnist", n_clients=n,
                                     classes_per_client=5, seed=seed)
    params = init_cnn(MNIST_CNN, jax.random.PRNGKey(seed))
    loss = lambda p, b: cnn_loss(MNIST_CNN, p, b, train=False)  # noqa: E731
    acc = lambda p, b: cnn_accuracy(MNIST_CNN, p, b)            # noqa: E731
    ev = make_personalized_eval(loss, acc, clients, ft_steps=1, ft_lr=0.01)
    return clients, params, loss, ev


def _run(arm, schedule, *, adversarial, max_rounds, eval_every, seed=0):
    clients, params, loss, ev = _setup(seed)
    pcfg = PersAFLConfig(option="A", q_local=5 if FAST else 10,
                         eta=0.002, lam=25.0,
                         inner_steps=5 if FAST else 10, inner_eta=0.02)
    spec = _spec(len(clients), adversarial=adversarial)
    run = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=spec.build(),
                strategy=strategy("persafl", option="A"),
                schedule=schedule, batch_size=16, seed=seed)
    t0 = time.time()
    hist = run.run(max_rounds=max_rounds, eval_every=eval_every, eval_fn=ev)
    wall = time.time() - t0
    s = run.stats
    finite = all(np.isfinite(np.asarray(x)).all()
                 for x in jax.tree.leaves(run.state.params))
    return {
        "arm": arm,
        "final_acc": hist.acc[-1] if hist.acc else float("nan"),
        "params_finite": finite,
        "staleness_mean": float(np.mean(hist.staleness))
        if hist.staleness else 0.0,
        "dropouts": s["dropouts"],
        "corrupted_rows": s["corrupted_rows"],
        "robust_clipped": s["robust_clipped"],
        "robust_trimmed": s["robust_trimmed"],
        "robust_nonfinite": s["robust_nonfinite"],
        "mean_cohort_fill": s["mean_cohort_fill"],
        "host_materializations": int(s["host_materializations"]),
        "wall_s": wall,
    }


def main():
    rounds = 24 if FAST else 160
    ev_every = max(rounds // 4, 1)
    arms = [
        ("clean", buffered(8), False),
        ("plain", buffered(8), True),
        ("clip", buffered(8, robust="clip"), True),
        ("trim", buffered(8, robust="trim", trim_frac=0.2), True),
    ]
    rows = []
    print("sweep,arm,final_acc,corrupted,clipped,trimmed,dropouts,"
          "host_mat")
    for arm, schedule, adversarial in arms:
        r = _run(arm, schedule, adversarial=adversarial,
                 max_rounds=rounds, eval_every=ev_every)
        rows.append(r)
        print(f"sweep,{arm},{r['final_acc']:.3f},{r['corrupted_rows']},"
              f"{r['robust_clipped']},{r['robust_trimmed']},"
              f"{r['dropouts']},{r['host_materializations']}", flush=True)
    by = {r["arm"]: r for r in rows}
    clean = by["clean"]["final_acc"]
    gates = {
        "adversaries_active": by["plain"]["corrupted_rows"] > 0,
        "robust_params_finite": by["clip"]["params_finite"]
        and by["trim"]["params_finite"],
    }
    if not FAST:
        # accuracy bands need the full 160-round budget — a 24-round
        # smoke hasn't converged anywhere, clean arm included
        gates.update({
            "clip_within_band": by["clip"]["final_acc"] >= clean - 0.1,
            "trim_within_band": by["trim"]["final_acc"] >= clean - 0.1,
            "plain_degrades": by["plain"]["final_acc"] < clean - 0.1,
        })
    out = {"rows": rows, "clean_acc": clean, "adv_frac": ADV_FRAC,
           "magnitude": MAGNITUDE, "rounds": rounds, "fast": FAST,
           "gates": gates}
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "churn_robustness.json"), "w") as f:
        json.dump(out, f, indent=2)
    for gate, ok in gates.items():
        print(f"gate,{gate},{ok}")
        if not ok:
            raise RuntimeError(f"churn_robustness gate failed: {gate} "
                               f"({json.dumps(by, default=float)})")


if __name__ == "__main__":
    main()
