"""Churn + adversary robustness sweep (PR 8 scenario engine).

Four arms on the fig2b MNIST configuration (c=5 classes/client, Option A,
buffered(8)), all under the same realistic churn (speed tiers, diurnal
availability, mid-round dropout):

  clean — no adversaries, plain buffered apply (the reference accuracy)
  plain — 5% adversarial clients (deltas scaled x50 / replaced by NaN)
          against the plain buffered apply
  clip  — same adversaries, ``buffered(8, robust="clip")``
  trim  — same adversaries, ``buffered(8, robust="trim")``

Gate (recorded in the JSON and enforced): the robust arms hold final
personalized accuracy within 0.1 of the clean arm while the plain arm
degrades below that band — the defense pays for itself exactly when the
scenario engine's adversarial population is switched on.

The adversary kinds here are the *norm attacks* (``scale``, ``nan``)
that norm-statistic defenses are built for.  The churn model also
supports ``sign_flip`` (−magnitude): its rows carry an inflated norm
too, so clip bounds them and trim discards them, but a *unit*-magnitude
direction flip is norm-indistinguishable from an honest row — defending
that class needs direction-aware aggregation (geometric median / Krum),
which is out of scope for the admission-weight layer.

Since PR 9 the arms ride :class:`repro.tune.TuneRunner` (no stop rule —
the pinned accuracy bands need full-budget runs): each arm is a
fingerprinted :class:`repro.tune.Arm` carrying its
:class:`~repro.fl.ScenarioSpec`, journaled to
``churn_robustness_journal.jsonl`` so a killed sweep resumes by
fingerprint skip.

Emits one JSON row per arm to
``experiments/sweeps/churn_robustness.json`` and CSV lines to stdout.

    PYTHONPATH=src python experiments/sweeps/churn_robustness.py

Env: SWEEP_FAST=1 shrinks clients/rounds for a smoke pass;
SWEEP_FRESH=1 deletes the journal first.
"""
from __future__ import annotations

import json
import os

import jax

from repro.configs.paper_models import MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import Adversarial, Diurnal, ScenarioSpec, Tier, \
    make_personalized_eval
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
from repro.tune import Arm, TuneRunner

FAST = bool(int(os.environ.get("SWEEP_FAST", "0")))
OUT = os.path.join("experiments", "sweeps")
JOURNAL = os.path.join(OUT, "churn_robustness_journal.jsonl")

ADV_FRAC = 0.05
MAGNITUDE = 50.0
# the scenario rolls its own seed, decoupled from the data/init seed: at
# seed 1 the population hash lands one "scale" client and two "nan"
# clients on the 30-client config, so a single run exercises both the
# clip/trim path and the non-finite drop path
SCENARIO_SEED = 1


def _spec(n, *, adversarial):
    return ScenarioSpec(
        n_clients=n, seed=SCENARIO_SEED,
        tiers=(Tier("fast", 0.5, 0.7), Tier("slow", 0.5, 1.6)),
        diurnal=Diurnal(period=300.0, floor=0.3), dropout=0.05,
        adversarial=Adversarial(frac=ADV_FRAC,
                                kinds=("scale", "nan"),
                                magnitude=MAGNITUDE)
        if adversarial else None)


def _problem(seed=0):
    cache = {}

    def build(arm):
        if not cache:
            n = 10 if FAST else 30
            clients = make_federated_dataset("mnist", n_clients=n,
                                             classes_per_client=5,
                                             seed=seed)
            params = init_cnn(MNIST_CNN, jax.random.PRNGKey(seed))
            loss = lambda p, b: cnn_loss(MNIST_CNN, p, b,        # noqa
                                         train=False)
            acc = lambda p, b: cnn_accuracy(MNIST_CNN, p, b)     # noqa
            rounds = 24 if FAST else 160
            cache.update(
                clients=clients, loss_fn=loss, init_params=params,
                eval_fn=make_personalized_eval(loss, acc, clients,
                                               ft_steps=1, ft_lr=0.01,
                                               with_loss=True),
                pcfg=PersAFLConfig(option="A", q_local=5 if FAST else 10,
                                   eta=0.002, lam=25.0,
                                   inner_steps=5 if FAST else 10,
                                   inner_eta=0.02),
                batch_size=16, eval_every=max(rounds // 4, 1))
        return cache

    return build


def _row(name, t):
    return {
        "arm": name,
        "final_acc": t.final_acc,
        "params_finite": t.params_finite,
        "staleness_mean": t.staleness_mean,
        "dropouts": t.stats["dropouts"],
        "corrupted_rows": t.stats["corrupted_rows"],
        "robust_clipped": t.stats["robust_clipped"],
        "robust_trimmed": t.stats["robust_trimmed"],
        "robust_nonfinite": t.stats["robust_nonfinite"],
        "mean_cohort_fill": t.stats["mean_cohort_fill"],
        "host_materializations": t.host_materializations,
        "wall_s": t.wall_s,
    }


def main():
    if bool(int(os.environ.get("SWEEP_FRESH", "0"))) \
            and os.path.exists(JOURNAL):
        os.remove(JOURNAL)
    rounds = 24 if FAST else 160
    n = 10 if FAST else 30
    arms = [
        ("clean", "buffered(8)", False),
        ("plain", "buffered(8)", True),
        ("clip", "buffered(8, robust=clip)", True),
        ("trim", "buffered(8, robust=trim, trim_frac=0.2)", True),
    ]
    runner = TuneRunner(_problem(), journal=JOURNAL)  # no stop rule:
    rows = []                    # the accuracy bands need full budgets
    print("sweep,arm,final_acc,corrupted,clipped,trimmed,dropouts,"
          "host_mat")
    for name, schedule, adversarial in arms:
        t = runner.run_arm(Arm(
            strategy="persafl", strategy_kwargs={"option": "A"},
            schedule=schedule, scenario=_spec(n, adversarial=adversarial),
            seed=0, max_rounds=rounds, group=f"churn/{name}"))
        r = _row(name, t)
        rows.append(r)
        print(f"sweep,{name},{r['final_acc']:.3f},{r['corrupted_rows']},"
              f"{r['robust_clipped']},{r['robust_trimmed']},"
              f"{r['dropouts']},{r['host_materializations']}", flush=True)
    by = {r["arm"]: r for r in rows}
    clean = by["clean"]["final_acc"]
    gates = {
        "adversaries_active": by["plain"]["corrupted_rows"] > 0,
        "robust_params_finite": by["clip"]["params_finite"]
        and by["trim"]["params_finite"],
    }
    if not FAST:
        # accuracy bands need the full 160-round budget — a 24-round
        # smoke hasn't converged anywhere, clean arm included
        gates.update({
            "clip_within_band": by["clip"]["final_acc"] >= clean - 0.1,
            "trim_within_band": by["trim"]["final_acc"] >= clean - 0.1,
            "plain_degrades": by["plain"]["final_acc"] < clean - 0.1,
        })
    out = {"rows": rows, "clean_acc": clean, "adv_frac": ADV_FRAC,
           "magnitude": MAGNITUDE, "rounds": rounds, "fast": FAST,
           "gates": gates}
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "churn_robustness.json"), "w") as f:
        json.dump(out, f, indent=2)
    for gate, ok in gates.items():
        print(f"gate,{gate},{ok}")
        if not ok:
            raise RuntimeError(f"churn_robustness gate failed: {gate} "
                               f"({json.dumps(by, default=float)})")


if __name__ == "__main__":
    main()
