"""Buffered-async convergence sweep (closes the last pre-PR-4 ROADMAP item):
``buffered(M)`` vs the paper-faithful immediate apply **at equal simulated
communication time**, on the fig2b/2c configurations.

For each (dataset, option) pair the immediate-apply run sets the simulated
time budget T; every buffered(M) run then replays the identical client /
delay streams with ``max_time=T`` (the FLRun knob added for this sweep), so
rows compare what each scheduler *converged to* in the same wall of
simulated communication — the FedBuff-style trade: fewer, fatter server
rounds (higher cohort occupancy, zero per-delta host traffic) against the
staleness each delta accumulates while the buffer fills.

Emits one JSON row per configuration to
``experiments/sweeps/buffered_vs_immediate.json`` and CSV lines to stdout.

    PYTHONPATH=src python experiments/sweeps/buffered_vs_immediate.py

Env: SWEEP_FAST=1 shrinks clients/rounds for a smoke pass.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.paper_models import CIFAR_CNN, MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import DelayModel, FLRun, buffered, immediate, \
    make_personalized_eval, strategy
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn

FAST = bool(int(os.environ.get("SWEEP_FAST", "0")))
OUT = os.path.join("experiments", "sweeps")


def _setup(kind: str, seed: int = 0):
    # fig2b/2c setup (paper §5): c=5 classes/client MNIST, c=3 CIFAR
    cpc = 5 if kind == "mnist" else 3
    ccfg = MNIST_CNN if kind == "mnist" else CIFAR_CNN
    n = 10 if FAST else 30
    clients = make_federated_dataset(kind, n_clients=n,
                                     classes_per_client=cpc, seed=seed)
    params = init_cnn(ccfg, jax.random.PRNGKey(seed))
    loss = lambda p, b: cnn_loss(ccfg, p, b, train=False)      # noqa: E731
    acc = lambda p, b: cnn_accuracy(ccfg, p, b)                # noqa: E731
    ev = make_personalized_eval(loss, acc, clients, ft_steps=1, ft_lr=0.01)
    return clients, params, loss, ev


def _run(kind, option, schedule, *, max_rounds, eval_every, max_time=None,
         seed=0):
    clients, params, loss, ev = _setup(kind, seed)
    pcfg = PersAFLConfig(option=option, q_local=5 if FAST else 10,
                         eta=0.002, lam=25.0,
                         inner_steps=5 if FAST else 10, inner_eta=0.02)
    run = FLRun(clients=clients, loss_fn=loss, init_params=params,
                pcfg=pcfg, delays=DelayModel(len(clients), seed=seed),
                strategy=strategy("persafl", option=option),
                schedule=schedule, batch_size=16, seed=seed)
    t0 = time.time()
    hist = run.run(max_rounds=max_rounds, eval_every=eval_every,
                   eval_fn=ev, max_time=max_time)
    wall = time.time() - t0
    sim_time = hist.end_time        # the loop's true stop time, not the
    rounds_done = int(run.final_stats["server_rounds"])  # 5s-grid quantum
    return {
        "rounds_done": rounds_done,
        "sim_time": sim_time,
        "final_acc": hist.acc[-1] if hist.acc else float("nan"),
        "staleness_mean": float(np.mean(hist.staleness))
        if hist.staleness else 0.0,
        "staleness_max": int(max(hist.staleness)) if hist.staleness else 0,
        # server rounds per unit simulated time: the throughput axis of
        # the trade (buffered flushes advance t by M at once)
        "rounds_per_sim_s": rounds_done / max(sim_time, 1e-9),
        "host_materializations":
            int(run.engine.stats["host_materializations"]),
        "wall_s": wall,
    }


def main():
    rounds = 24 if FAST else 160
    rows = []
    print("sweep,dataset,option,schedule,rounds_done,final_acc,"
          "tau_mean,tau_max,rounds_per_sim_s,host_mat")
    ev_every = max(rounds // 4, 1)
    for kind in ("mnist", "cifar"):
        for option in ("A", "C"):
            base = _run(kind, option, immediate(), max_rounds=rounds,
                        eval_every=ev_every)
            budget = base["sim_time"]
            variants = [("immediate", base)]
            for m in (4, 8):
                # equal simulated time: cap by the immediate run's budget,
                # generous round cap so time (not rounds) is the binding
                # constraint; eval cadence matches the immediate run's
                variants.append((f"buffered({m})", _run(
                    kind, option, buffered(m), max_rounds=8 * rounds,
                    eval_every=ev_every, max_time=budget)))
            for name, r in variants:
                row = {"dataset": kind, "option": option,
                       "schedule": name, "sim_time_budget": budget, **r}
                rows.append(row)
                print(f"sweep,{kind},{option},{name},{r['rounds_done']},"
                      f"{r['final_acc']:.3f},{r['staleness_mean']:.2f},"
                      f"{r['staleness_max']},{r['rounds_per_sim_s']:.3f},"
                      f"{r['host_materializations']}", flush=True)
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "buffered_vs_immediate.json"), "w") as f:
        json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
