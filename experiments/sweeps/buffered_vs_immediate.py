"""Buffered-async convergence sweep (closes the last pre-PR-4 ROADMAP item):
``buffered(M)`` vs the paper-faithful immediate apply **at equal simulated
communication time**, on the fig2b/2c configurations.

For each (dataset, option) pair the immediate-apply run sets the simulated
time budget T; every buffered(M) run then replays the identical client /
delay streams with ``max_time=T`` (the FLRun knob added for this sweep), so
rows compare what each scheduler *converged to* in the same wall of
simulated communication — the FedBuff-style trade: fewer, fatter server
rounds (higher cohort occupancy, zero per-delta host traffic) against the
staleness each delta accumulates while the buffer fills.

Since PR 9 the sweep rides :class:`repro.tune.TuneRunner`: each row is a
fingerprinted :class:`repro.tune.Arm` journaled to
``buffered_vs_immediate_journal.jsonl`` (re-running skips completed rows),
and final accuracy is read through ``FLRun.run(final_eval=True)`` — the
end-of-budget eval — rather than the last *grid* eval, which could be
stale (or absent entirely when ``eval_every`` exceeds the rounds a budget
admits; regression-pinned in ``tests/test_tune.py``).

Emits one JSON row per configuration to
``experiments/sweeps/buffered_vs_immediate.json`` and CSV lines to stdout.

    PYTHONPATH=src python experiments/sweeps/buffered_vs_immediate.py

Env: SWEEP_FAST=1 shrinks clients/rounds for a smoke pass;
SWEEP_FRESH=1 deletes the journal first.
"""
from __future__ import annotations

import json
import os

import jax

from repro.configs.paper_models import CIFAR_CNN, MNIST_CNN
from repro.core import PersAFLConfig
from repro.data import make_federated_dataset
from repro.fl import make_personalized_eval
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
from repro.tune import Arm, TuneRunner

FAST = bool(int(os.environ.get("SWEEP_FAST", "0")))
OUT = os.path.join("experiments", "sweeps")
JOURNAL = os.path.join(OUT, "buffered_vs_immediate_journal.jsonl")


def _problem(kind: str, seed: int = 0):
    """Problem closure per dataset (fig2b/2c setup, paper §5: c=5
    classes/client MNIST, c=3 CIFAR) — built lazily so resumed rows cost
    nothing, then shared by every arm of the (dataset, option) grids."""
    cache = {}

    def build(arm):
        if not cache:
            cpc = 5 if kind == "mnist" else 3
            ccfg = MNIST_CNN if kind == "mnist" else CIFAR_CNN
            n = 10 if FAST else 30
            clients = make_federated_dataset(kind, n_clients=n,
                                             classes_per_client=cpc,
                                             seed=seed)
            params = init_cnn(ccfg, jax.random.PRNGKey(seed))
            loss = lambda p, b: cnn_loss(ccfg, p, b, train=False)  # noqa
            acc = lambda p, b: cnn_accuracy(ccfg, p, b)            # noqa
            rounds = 24 if FAST else 160
            cache.update(
                clients=clients, loss_fn=loss, init_params=params,
                eval_fn=make_personalized_eval(loss, acc, clients,
                                               ft_steps=1, ft_lr=0.01,
                                               with_loss=True),
                pcfg=PersAFLConfig(option="A", q_local=5 if FAST else 10,
                                   eta=0.002, lam=25.0,
                                   inner_steps=5 if FAST else 10,
                                   inner_eta=0.02),
                batch_size=16, eval_every=max(rounds // 4, 1))
        return cache

    return build


def _row(kind, option, name, budget, t):
    return {
        "dataset": kind, "option": option, "schedule": name,
        "sim_time_budget": budget,
        "rounds_done": t.rounds,
        "sim_time": t.sim_time,
        "final_acc": t.final_acc,
        "staleness_mean": t.staleness_mean,
        "staleness_max": t.staleness_max,
        # server rounds per unit simulated time: the throughput axis of
        # the trade (buffered flushes advance t by M at once)
        "rounds_per_sim_s": t.rounds / max(t.sim_time, 1e-9),
        "host_materializations": t.host_materializations,
        "wall_s": t.wall_s,
    }


def main():
    if bool(int(os.environ.get("SWEEP_FRESH", "0"))) \
            and os.path.exists(JOURNAL):
        os.remove(JOURNAL)
    rounds = 24 if FAST else 160
    rows = []
    print("sweep,dataset,option,schedule,rounds_done,final_acc,"
          "tau_mean,tau_max,rounds_per_sim_s,host_mat")
    for kind in ("mnist", "cifar"):
        runner = TuneRunner(_problem(kind), journal=JOURNAL)
        for option in ("A", "C"):
            def arm(schedule, **kw):
                return Arm(strategy="persafl",
                           strategy_kwargs={"option": option},
                           schedule=schedule, pcfg={"option": option},
                           seed=0, group=f"{kind}/{option}", **kw)

            base = runner.run_arm(arm("immediate", max_rounds=rounds))
            budget = base.sim_time
            variants = [("immediate", base)]
            for m in (4, 8):
                # equal simulated time: cap by the immediate run's budget,
                # generous round cap so time (not rounds) is the binding
                # constraint; eval cadence matches the immediate run's
                variants.append((f"buffered({m})", runner.run_arm(
                    arm(f"buffered({m})", max_rounds=8 * rounds,
                        budget=budget))))
            for name, t in variants:
                r = _row(kind, option, name, budget, t)
                rows.append(r)
                print(f"sweep,{kind},{option},{name},{r['rounds_done']},"
                      f"{r['final_acc']:.3f},{r['staleness_mean']:.2f},"
                      f"{r['staleness_max']},{r['rounds_per_sim_s']:.3f},"
                      f"{r['host_materializations']}", flush=True)
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "buffered_vs_immediate.json"), "w") as f:
        json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
